"""The event-driven SchedulerSession (core/session.py).

* session-vs-batch driver equivalence on the full 9-scenario x 6-scheduler
  matrix: identical job_completions (bit-identical floats), twct, and
  reschedule counts — offline scenarios get Poisson releases injected so
  the equivalence is exercised on genuinely online traces;
* frontier-append plan repair: the fast path fires on clean-cut arrivals,
  chains across consecutive appends, is results-identical to the full
  replan (and to the batch reference), and correctly REJECTS mid-window
  arrivals;
* the event API itself: submit/advance/frontier/snapshot/result semantics;
* scheduler option validation (`make_scheduler` rejects typos with the
  valid option list — the silent `**_ignored`/`**opts` swallowing is gone);
* a pinned golden for one online_poisson shape under BOTH drivers (the
  `session-equivalence` CI job runs this file).
"""
import json
import math
import os
from pathlib import Path

import numpy as np
import pytest

from repro import scenarios
from repro.core import (Coflow, Instance, Job, SchedulerSession,
                        available_schedulers, make_scheduler, plan_online,
                        poisson_releases, scheduler_options, simulate_online,
                        theta0)

SCHEDULERS = sorted(available_schedulers())
GOLDEN_PATH = Path(__file__).parent / "goldens" / "session_equivalence.json"

# tiny per-scenario sizes (mirrors tests/test_scenarios.py): the doubled
# 9 x 6 online matrix must stay CI-cheap
TINY = {
    "fb_like": dict(m=6, scale=0.03),
    "fb_like_rt": dict(m=6, scale=0.03),
    "alibaba_sparse": dict(m=6, scale=0.15),
    "incast": dict(m=6, scale=0.1),
    "shuffle_heavy": dict(m=6, scale=0.2),
    "wide_shallow": dict(m=6, scale=0.2),
    "deep_chain": dict(m=6, scale=0.25),
    "online_poisson": dict(m=6, scale=0.03),
    "dist_collectives": dict(m=8, scale=0.5),
}


def _online_instance(name: str):
    """The scenario's instance with releases: native for poisson scenarios,
    Poisson-injected for offline ones (so every cell really reschedules)."""
    built = scenarios.build(name, seed=0, **TINY[name])
    inst = built.instance
    if built.meta.arrival == "offline":
        inst = poisson_releases(inst, theta=2 * theta0(inst), seed=0)
    return inst, built.meta


# --- session-vs-batch equivalence: the full matrix ---------------------------

@pytest.mark.parametrize("sched", SCHEDULERS)
@pytest.mark.parametrize("scen", scenarios.names())
def test_matrix_session_batch_equivalence(scen, sched):
    inst, meta = _online_instance(scen)
    opts = scenarios.scheduler_opts(sched, meta)
    a = simulate_online(inst, sched, driver="batch", seed=0, **opts)
    b = simulate_online(inst, sched, driver="session", seed=0, **opts)
    assert a.job_completions == b.job_completions, \
        f"{scen}/{sched}: drivers diverged"
    assert a.twct() == b.twct()
    assert a.reschedules == b.reschedules
    s = b.stats["session"]
    assert s["reschedules"] == b.reschedules
    assert s["repairs"] + s["full_replans"] == s["reschedules"]


def test_unknown_driver_rejected():
    inst, _ = _online_instance("fb_like")
    with pytest.raises(ValueError):
        simulate_online(inst, "gdm", driver="batch_v2")


def test_plan_online_session_and_batch_drivers_agree():
    inst, _ = _online_instance("online_poisson")
    a = plan_online(inst, "gdm", seed=0, driver="session")
    b = plan_online(inst, "gdm", seed=0, driver="batch")
    assert a.twct() == b.twct()
    assert a.job_completions == b.job_completions
    assert "session" in a.stats and "session" not in b.stats
    assert a.stats["driver"] == "session"


# --- frontier-append plan repair ---------------------------------------------

def _append_workload(m=6, appends=3):
    """Two base jobs at t=0 plus `appends` arrivals landing exactly on the
    clean cuts of the O(m)Alg sequential schedule, sized/weighted so
    Algorithm 5 appends each new job at the tail — the repair fast path
    fires (and chains) on every arrival.

    om_alg order on the base pair is [1, 0] (job1 [0,8), job0 [8,20)); the
    first append lands at t=8, each later one when the job planned before
    it finishes."""
    jobs = []
    d0 = np.zeros((m, m), np.int64)
    d0[0, 1] = 12
    d1 = np.zeros((m, m), np.int64)
    d1[2, 3] = 8
    jobs.append(Job(0, [Coflow(0, 0, d0)], [], weight=1.0, release=0))
    jobs.append(Job(1, [Coflow(1, 0, d1)], [], weight=1.0, release=0))
    t, size, w, prev = 8, 20, 0.4, 12
    for a in range(appends):
        jid = 2 + a
        d = np.zeros((m, m), np.int64)
        d[(a % 3) * 2, (a % 3) * 2 + 1] = size
        jobs.append(Job(jid, [Coflow(jid, 0, d)], [], weight=w, release=t))
        t += prev
        prev, size, w = size, size + 4, w / 2
    return Instance(m, jobs)


def test_frontier_append_repair_fires_and_matches_full_replan():
    inst = _append_workload()
    on = simulate_online(inst, "om_alg", driver="session")
    off = simulate_online(inst, "om_alg", driver="session", repair=False)
    bat = simulate_online(inst, "om_alg", driver="batch")
    s_on, s_off = on.stats["session"], off.stats["session"]
    # the fast path fires on every append and chains across repaired epochs
    assert s_on["repairs"] == 3 and s_on["repair_rejects"] == 0
    assert s_on["full_replans"] == 1
    assert s_on["repair_hit_rate"] == pytest.approx(0.75)
    assert s_off["repairs"] == 0 and s_off["full_replans"] == 4
    # and it is results-identical to the full replan and the batch reference
    assert on.job_completions == off.job_completions == bat.job_completions
    assert on.twct() == off.twct() == bat.twct()
    assert on.reschedules == off.reschedules == bat.reschedules == 4


def test_repair_rejects_mid_window_arrival():
    """An arrival that interrupts a coflow mid-window leaves it partially
    executed — the soundness checks must reject the splice and fall back,
    and the fallback must still match the batch reference."""
    inst = _append_workload(appends=1)
    # shift the append off the clean cut, into job0's window
    import dataclasses
    jobs = [dataclasses.replace(j, release=13) if j.jid == 2 else j
            for j in inst.jobs]
    inst = Instance(inst.m, jobs)
    on = simulate_online(inst, "om_alg", driver="session")
    bat = simulate_online(inst, "om_alg", driver="batch")
    s = on.stats["session"]
    assert s["repairs"] == 0 and s["repair_rejects"] >= 1
    assert on.job_completions == bat.job_completions


def test_repair_never_fires_for_interleaving_schedulers():
    """Randomized G-DM groups re-derive random delays per plan; the repair
    path must not pretend to splice them (it is only certified for the
    job-sequential baseline and for deterministic spread-mode G-DM /
    G-DM-RT)."""
    inst = _append_workload()
    on = simulate_online(inst, "gdm", driver="session", seed=0)
    bat = simulate_online(inst, "gdm", driver="batch", seed=0)
    assert on.stats["session"]["repairs"] == 0
    assert on.job_completions == bat.job_completions


def _geometric_append_workload(m=10, base=4, appends=3, scheduler="gdm",
                               chain=False):
    """Geometrically growing jobs: prefix aggregate sizes roughly triple per
    job, so every G-DM geometric group is a singleton in Algorithm 5 order;
    appends land on the live frontier's clean cuts (probe session, as in the
    kernels_bench session_repair workload).  chain=True gives every job a
    two-coflow chain (a rooted tree), exercising DMA-SRT layouts under
    G-DM-RT."""
    rng = np.random.default_rng(0)

    def perm_demand(units):
        d = np.zeros((m, m), np.int64)
        for _ in range(2):
            d[np.arange(m), rng.permutation(m)] += units
        np.fill_diagonal(d, 0)
        return d

    def make_job(jid, units, release):
        if chain:
            coflows = [Coflow(jid, 0, perm_demand(units)),
                       Coflow(jid, 1, perm_demand(units))]
            return Job(jid, coflows, [(0, 1)], weight=2.0 ** -jid,
                       release=release)
        return Job(jid, [Coflow(jid, 0, perm_demand(units))], [],
                   weight=2.0 ** -jid, release=release)

    jobs = [make_job(k, 4 * 3 ** k, 0) for k in range(base)]
    opts = {"delays": "spread", "seed": 0}
    probe = SchedulerSession(m, scheduler, **opts)
    for j in jobs:
        probe.submit(j)
    size = 4 * 3 ** base
    for a in range(appends):
        t = min(probe.frontier().completions.values())
        job = make_job(base + a, size, int(t))
        jobs.append(job)
        probe.advance(until=t)
        probe.submit(job)
        size *= 3
    return Instance(m, jobs)


def test_repair_fires_for_spread_mode_gdm():
    """The ROADMAP item: de-randomized (spread) delays make G-DM's
    group-boundary cuts splice-certifiable.  On a singleton-group workload
    every append takes the fast path; results must match the repair-off
    session and the batch reference exactly."""
    inst = _geometric_append_workload()
    on = simulate_online(inst, "gdm", driver="session", delays="spread")
    off = simulate_online(inst, "gdm", driver="session", repair=False,
                          delays="spread")
    bat = simulate_online(inst, "gdm", driver="batch", delays="spread")
    s_on = on.stats["session"]
    assert s_on["repairs"] == 3 and s_on["repair_rejects"] == 0
    assert s_on["full_replans"] == 1
    assert s_on["groups_reused"] >= 3
    assert on.job_completions == off.job_completions == bat.job_completions
    assert on.twct() == off.twct() == bat.twct()


@pytest.mark.parametrize("chain", [False, True])
def test_repair_fires_for_spread_mode_gdm_rt(chain):
    """The G-DM-RT certification gap: spread-mode G-DM-RT sessions used to
    fall back to a full replan on every arrival.  The grouped repair reuses
    untouched group blocks and rebuilds dirty groups with dma_rt itself, so
    appends at clean cuts now take the fast path — for single-coflow jobs
    and for real two-coflow chain trees (DMA-SRT path layouts)."""
    inst = _geometric_append_workload(scheduler="gdm_rt", chain=chain)
    on = simulate_online(inst, "gdm_rt", driver="session", delays="spread")
    off = simulate_online(inst, "gdm_rt", driver="session", repair=False,
                          delays="spread")
    bat = simulate_online(inst, "gdm_rt", driver="batch", delays="spread")
    s_on = on.stats["session"]
    assert s_on["repairs"] >= 1 and s_on["groups_reused"] >= 1
    assert on.job_completions == off.job_completions == bat.job_completions
    assert on.twct() == off.twct() == bat.twct()


def test_spread_repair_reuses_non_singleton_group_block():
    """The non-singleton certification gap: when G-DM grouping merges jobs,
    the old singleton check rejected every repair.  A retained multi-job
    group whose residuals and chain position are untouched is now reused as
    ONE block (shifted_expanded), bit-identical to the full replan."""
    m = 8
    sizes = {0: 16, 1: 60, 2: 64}   # jobs 1, 2 share a geometric group
    dems = {}
    for jid, size in sizes.items():
        d = np.zeros((m, m), np.int64)
        d[2 * jid, 2 * jid + 1] = size
        dems[jid] = d
    jobs = [Job(jid, [Coflow(jid, 0, dems[jid])], [],
                weight=1.0 - 0.1 * jid, release=0) for jid in sizes]
    inst0 = Instance(m, jobs)
    from repro.core.gdm import gdm

    plan0 = gdm(inst0, delays="spread")
    groups0 = plan0.meta["groups"]
    assert any(len(g) > 1 for g in groups0), \
        "workload must produce a non-singleton geometric group"
    # arrival on job0's completion boundary: the merged group is untouched.
    # The new job carries a 16-unit flow so the residual instance keeps the
    # same gamma (min positive flow) and hence the same geometric buckets.
    probe = SchedulerSession(m, "gdm", delays="spread", seed=0)
    for j in jobs:
        probe.submit(j)
    t = min(probe.frontier().completions.values())
    d_new = np.zeros((m, m), np.int64)
    d_new[6, 7] = 3000
    d_new[7, 6] = 16
    jobs.append(Job(3, [Coflow(3, 0, d_new)], [], weight=0.05,
                    release=int(t)))
    inst = Instance(m, jobs)
    on = simulate_online(inst, "gdm", driver="session", delays="spread")
    off = simulate_online(inst, "gdm", driver="session", repair=False,
                          delays="spread")
    bat = simulate_online(inst, "gdm", driver="batch", delays="spread")
    s = on.stats["session"]
    assert s["repairs"] == 1 and s["groups_reused"] >= 1
    assert on.job_completions == off.job_completions == bat.job_completions
    assert on.twct() == off.twct() == bat.twct()


def test_spread_repair_recomputes_inflight_group_and_reuses_rest():
    """A mid-window arrival leaves the in-flight group partially executed:
    the grouped repair recomputes that group from its residual (whose
    effective size shrinks by exactly the executed prefix on this integral
    workload) and still reuses the untouched downstream blocks."""
    m = 8
    sizes = [16, 48, 144]
    jobs = []
    for jid, size in enumerate(sizes):
        d = np.zeros((m, m), np.int64)
        d[2 * jid, 2 * jid + 1] = size
        jobs.append(Job(jid, [Coflow(jid, 0, d)], [],
                        weight=2.0 ** -jid, release=0))
    d_new = np.zeros((m, m), np.int64)
    d_new[6, 7] = 500
    jobs.append(Job(3, [Coflow(3, 0, d_new)], [], weight=0.05, release=8))
    inst = Instance(m, jobs)
    on = simulate_online(inst, "gdm", driver="session", delays="spread")
    bat = simulate_online(inst, "gdm", driver="batch", delays="spread")
    s = on.stats["session"]
    assert s["repairs"] == 1 and s["groups_reused"] >= 1
    assert s["groups_replanned"] >= 1
    assert on.job_completions == bat.job_completions
    assert on.twct() == bat.twct()


def test_legacy_repair_mode_keeps_old_gate():
    """repair="legacy" reproduces the pre-generalization behaviour (the
    before side of the serve bench's hit-rate delta): G-DM-RT never
    repairs, and results stay identical either way."""
    inst = _geometric_append_workload(scheduler="gdm_rt")
    new = simulate_online(inst, "gdm_rt", driver="session", delays="spread")
    old = simulate_online(inst, "gdm_rt", driver="session", repair="legacy",
                          delays="spread")
    assert new.stats["session"]["repairs"] >= 1
    assert old.stats["session"]["repairs"] == 0
    assert new.job_completions == old.job_completions


# --- the event API -----------------------------------------------------------

def _two_jobs(m=4):
    d0 = np.zeros((m, m), np.int64)
    d0[0, 1] = 6
    d1 = np.zeros((m, m), np.int64)
    d1[2, 3] = 4
    return (Job(0, [Coflow(0, 0, d0)], [], weight=1.0, release=0),
            Job(1, [Coflow(1, 0, d1)], [], weight=1.0, release=5))


def test_session_event_loop_submit_advance_result():
    j0, j1 = _two_jobs()
    s = SchedulerSession(4, "om_alg")
    s.submit(j0)
    s.submit(j1)         # future release: admitted when advance reaches it
    assert not s.done
    with pytest.raises(RuntimeError):
        s.result()       # not drained yet
    s.advance()
    assert s.done
    res = s.result()
    ref = simulate_online(Instance(4, [j0, j1]), "om_alg", driver="batch")
    assert res.job_completions == ref.job_completions
    assert res.reschedules == ref.reschedules
    assert s.now == pytest.approx(res.makespan)


def test_session_incremental_advance_matches_one_shot():
    """Advancing in arrival-aligned steps is the batch protocol; the final
    state matches a single drain."""
    j0, j1 = _two_jobs()
    a = SchedulerSession(4, "om_alg")
    for j in (j0, j1):
        a.submit(j)
    a.advance(until=5.0)   # executes epoch 1 up to the arrival
    assert a.now == 5.0
    snap = a.snapshot()
    assert snap.remaining_total() < 10   # work was executed
    a.advance()
    b = SchedulerSession(4, "om_alg")
    for j in (j0, j1):
        b.submit(j)
    b.advance()
    assert a.result().job_completions == b.result().job_completions


def test_session_prunes_drained_jobs_from_active_set():
    """Long-lived sessions (serve keeps one per batch stream) must not scan
    every job ever submitted: drained jobs retire from the active set and
    land in frontier().finished."""
    j0, j1 = _two_jobs()
    s = SchedulerSession(4, "om_alg")
    s.submit(j0)
    s.submit(j1)
    s.advance()
    assert s.snapshot().active == ()
    f = s.frontier()
    assert set(f.finished) == {0, 1} and f.completions == {}
    # a fresh arrival after the prune still plans and drains normally
    d = np.zeros((4, 4), np.int64)
    d[1, 2] = 3
    s.submit(Job(2, [Coflow(2, 0, d)], [], weight=1.0, release=0))
    s.advance()
    assert set(s.frontier().finished) == {0, 1, 2}
    assert len(s.result().job_completions) == 3


def test_planner_shared_session_multi_phase():
    """The advertised follow-up-phase flow: coflows_from_step numbers every
    phase 0..n-1, so a shared session must remap colliding jids internally
    and still hand back the order in the caller's jid space — downstream
    bucket_order_from_plan keeps working."""
    from repro.dist.planner import (bucket_order_from_plan, coflows_from_step,
                                    plan as dist_plan,
                                    synthetic_collective_ops)

    inst = coflows_from_step(synthetic_collective_ops(n_ops=4, seed=0),
                             rows=2, cols=2, n_buckets=2)
    out = dist_plan(inst)
    with pytest.raises(ValueError):
        dist_plan(inst, beta=5.0, session=out.session)  # opts fixed at creation
    # phase 2: identical jid numbering on the SAME session
    inst2 = coflows_from_step(synthetic_collective_ops(n_ops=4, seed=1),
                              rows=2, cols=2, n_buckets=2)
    again = dist_plan(inst2, session=out.session)
    assert sorted(again.order) == [0, 1]                # caller jid space
    paths = [f"p{i}" for i in range(6)]
    buckets = bucket_order_from_plan(again, paths)
    assert sorted(x for b in buckets for x in b) == paths
    assert again.session is out.session and again.session.done


def test_planner_order_total_despite_early_drain():
    """A job that drains before a later reschedule is missing from the last
    plan's Algorithm 5 permutation — plan() must still return a total
    permutation (prepending drained jobs in completion order) so
    bucket_order_from_plan can index every bucket."""
    from repro.dist.planner import bucket_order_from_plan, plan as dist_plan

    m = 4
    d0 = np.zeros((m, m), np.int64)
    d0[0, 1] = 4
    d1 = np.zeros((m, m), np.int64)
    d1[2, 3] = 6
    inst = Instance(m, [Job(0, [Coflow(0, 0, d0)], [], weight=1.0, release=0),
                        Job(1, [Coflow(1, 0, d1)], [], weight=1.0,
                            release=100)])
    out = dist_plan(inst)
    assert sorted(out.order) == [0, 1]
    buckets = bucket_order_from_plan(out, ["a", "b", "c", "d"])
    assert sorted(x for b in buckets for x in b) == ["a", "b", "c", "d"]


def test_planner_rejects_plan_less_session():
    from repro.core import om_alg
    from repro.dist.planner import plan as dist_plan

    s = SchedulerSession(4, lambda sub: om_alg(sub).transcript())
    d = np.zeros((4, 4), np.int64)
    d[0, 1] = 2
    inst = Instance(4, [Job(0, [Coflow(0, 0, d)], [], weight=1.0, release=0)])
    with pytest.raises(ValueError, match="no engine plan"):
        dist_plan(inst, session=s)


def test_session_retires_coflowless_jobs():
    s = SchedulerSession(4, "om_alg")
    s.submit(Job(0, [], [], weight=1.0, release=3))
    s.advance()
    assert s.snapshot().active == ()
    assert s.frontier().completion(0) == 3.0
    assert s.result().job_completions[0] == 3.0


def test_session_frontier_reports_planned_completions():
    j0, j1 = _two_jobs()
    s = SchedulerSession(4, "om_alg")
    s.submit(j0)
    f = s.frontier()
    assert f.now == 0.0
    assert f.completions[0] == pytest.approx(6.0)   # planned, not executed
    assert f.busy_until == pytest.approx(6.0)
    assert f.pending == ()
    s.submit(j1)
    assert s.frontier().pending == (1,)
    s.advance()
    f = s.frontier()
    assert f.completions == {}
    assert f.finished[0] == pytest.approx(6.0)
    assert f.order()[0] == 0
    assert f.completion(99) == math.inf


def test_session_rejects_duplicate_and_mismatched_jobs():
    j0, _ = _two_jobs()
    s = SchedulerSession(4, "om_alg")
    s.submit(j0)
    with pytest.raises(ValueError):
        s.submit(j0)
    with pytest.raises(ValueError):
        s.advance(until=-1.0)
    d = np.zeros((6, 6), np.int64)
    d[0, 1] = 1
    with pytest.raises(ValueError):
        s.submit(Job(7, [Coflow(7, 0, d)], []))


def test_session_backfilled_plan_entry():
    j0, j1 = _two_jobs()
    s = SchedulerSession(4, "om_alg")
    s.submit(j0)
    s.submit(j1)
    bf = s.backfilled_plan()            # current epoch: job 0 alone
    assert bf.executor == "packet"
    assert bf.job_completions[0] == pytest.approx(6.0)
    idle = SchedulerSession(4, "om_alg")
    with pytest.raises(ValueError):
        idle.backfilled_plan()


def test_session_accepts_plain_callables():
    from repro.core import om_alg

    j0, j1 = _two_jobs()
    inst = Instance(4, [j0, j1])
    res = simulate_online(inst, lambda sub: om_alg(sub).transcript(),
                          driver="session")
    ref = simulate_online(inst, lambda sub: om_alg(sub).transcript(),
                          driver="batch")
    assert res.job_completions == ref.job_completions


# --- option validation (no more silent swallowing) ---------------------------

def test_make_scheduler_rejects_unknown_options():
    with pytest.raises(TypeError) as ei:
        make_scheduler("om_alg", execc="ledger")   # the ISSUE's typo
    msg = str(ei.value)
    assert "execc" in msg and "valid options" in msg and "decompose" in msg
    # exec is a *_bf option only; om_alg's old **_ignored swallowed it
    with pytest.raises(TypeError):
        make_scheduler("om_alg", exec="ledger")
    with pytest.raises(TypeError):
        make_scheduler("gdm", require_tree=False)  # gdm_rt-only option
    # valid spellings still bind
    assert make_scheduler("om_alg_bf", exec="ledger").opts == {"exec": "ledger"}
    assert make_scheduler("gdm_rt", require_tree=False).opts == \
        {"require_tree": False}


def test_option_validation_reaches_online_and_session_paths():
    inst, _ = _online_instance("fb_like")
    with pytest.raises(TypeError):
        simulate_online(inst, "gdm_bf", excc="ledger")
    with pytest.raises(TypeError):
        SchedulerSession(inst.m, "gdm", beta2=3.0)
    with pytest.raises(TypeError):
        plan_online(inst, "gdm", sseed=1)


def test_scheduler_options_listing():
    opts = scheduler_options("gdm_rt_bf")
    assert "exec" in opts and "require_tree" in opts and "beta" in opts
    with pytest.raises(KeyError):
        scheduler_options("nope")


# --- pinned golden: one online_poisson shape under both drivers --------------

def test_session_equivalence_online_poisson_golden():
    """The `session-equivalence` CI job pins this shape: both drivers must
    produce the same completions AND match the checked-in golden (refresh
    intentionally with REPRO_UPDATE_GOLDENS=1)."""
    built = scenarios.build("online_poisson", m=6, seed=0, scale=0.03)
    rows = {}
    for driver in ("batch", "session"):
        r = simulate_online(built.instance, "gdm", driver=driver, seed=0)
        rows[driver] = {
            "twct": r.twct(),
            "reschedules": r.reschedules,
            "job_completions": {str(k): v for k, v in
                                sorted(r.job_completions.items())},
        }
    assert rows["batch"] == rows["session"]
    if os.environ.get("REPRO_UPDATE_GOLDENS"):
        GOLDEN_PATH.parent.mkdir(exist_ok=True)
        GOLDEN_PATH.write_text(
            json.dumps(rows["session"], indent=1, sort_keys=True) + "\n")
    want = json.loads(GOLDEN_PATH.read_text())
    assert rows["session"] == want
