"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp ref oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coflow_merge import interval_alphas
from repro.kernels.coflow_merge.ref import alphas_ref, build_delta
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_decode_step, ssd_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("shape", [
    (1, 2, 2, 16, 16, 32),    # MHA square
    (2, 4, 2, 33, 33, 24),    # GQA, ragged seq
    (1, 8, 2, 64, 128, 48),   # cross-length (prefill-with-prefix)
    (1, 4, 1, 1, 96, 64),     # decode shape (q_len = 1)
    (1, 4, 4, 48, 48, 128),   # MXU-aligned head dim
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, dtype, tol, causal):
    B, Hq, Hkv, Sq, Sk, d = shape
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=causal)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    assert err < tol, err


@pytest.mark.parametrize("shape,chunk", [
    ((1, 16, 2, 1, 8, 16), 8),
    ((2, 33, 4, 2, 16, 32), 16),    # ragged + state groups
    ((1, 64, 2, 2, 32, 64), 32),
    ((1, 40, 8, 1, 16, 8), 64),     # chunk > seq
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4)])
def test_ssd_scan_sweep(shape, chunk, dtype, tol):
    B, S, H, G, N, P = shape
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), dtype)
    a = jnp.asarray(RNG.uniform(0.55, 1.0, size=(B, S, H)), dtype)
    b = jnp.asarray(RNG.normal(size=(B, S, G, N)), dtype) * 0.3
    c = jnp.asarray(RNG.normal(size=(B, S, G, N)), dtype) * 0.3
    out = ssd_scan(x, a, b, c, chunk=chunk)
    ref = ssd_ref(x, a, b, c)
    rel = float(jnp.abs(out - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < tol, rel


def test_ssd_decode_step_matches_scan_tail():
    B, S, H, G, N, P = 1, 12, 2, 1, 8, 16
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    a = jnp.asarray(RNG.uniform(0.6, 1.0, size=(B, S, H)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    full = ssd_ref(x, a, b, c)
    h = jnp.zeros((B, H, N, P), jnp.float32)
    rep = H // G
    for t in range(S):
        h, y = ssd_decode_step(h, x[:, t], a[:, t], b[:, t], c[:, t])
        assert float(jnp.abs(y - full[:, t]).max()) < 1e-4


@pytest.mark.parametrize("seed", range(6))
def test_coflow_merge_sweep(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 40))
    E = int(rng.integers(1, 500))
    t0 = rng.integers(0, 300, E)
    t1 = t0 + rng.integers(1, 60, E)
    events = np.unique(np.concatenate([t0, t1]))
    si = np.searchsorted(events, t0)
    ei = np.searchsorted(events, t1)
    s = rng.integers(0, m, E)
    r = rng.integers(0, m, E)
    K = events.size - 1
    got = interval_alphas(si, ei, s, r, K, m, block_k=64)
    ref = np.asarray(alphas_ref(build_delta(
        jnp.asarray(si), jnp.asarray(ei), jnp.asarray(s), jnp.asarray(r), K, m)))
    assert (got == ref).all()


def test_coflow_merge_empty():
    assert interval_alphas(np.zeros(0, int), np.zeros(0, int),
                           np.zeros(0, int), np.zeros(0, int), 0, 4).size == 0


def _random_bna_state(rng, B, w):
    """A batch of BNA-step states: demands with consistent row/col/D and a
    partial matching (the kernel's arithmetic contract doesn't require the
    matching to be perfect — parity must hold on any state, including the
    drained all-zero matrices the batch loop leaves in place)."""
    d = rng.integers(0, 40, size=(B, w, w))
    d[rng.random((B, w, w)) > 0.6] = 0
    d[0] = 0                                      # a drained matrix
    row = d.sum(axis=2)
    col = d.sum(axis=1)
    D = np.maximum(row.max(axis=1), col.max(axis=1))
    match = np.full((B, w), -1, dtype=np.int64)
    for i in range(B):
        perm = rng.permutation(w)
        keep = rng.random(w) < 0.8
        match[i, keep] = perm[keep]
    match[0] = -1
    return (d.astype(np.int64), row.astype(np.int64), col.astype(np.int64),
            D.astype(np.int64), match)


@pytest.mark.parametrize("B,w", [(1, 1), (3, 2), (8, 8), (17, 13), (40, 32)])
@pytest.mark.parametrize("seed", [0, 1])
def test_bna_step_kernel_bit_identical(B, w, seed):
    from repro.kernels.bna_step import bna_step_batch
    from repro.kernels.bna_step.ref import bna_step_ref

    rng = np.random.default_rng(seed)
    state = _random_bna_state(rng, B, w)
    got = bna_step_batch(*state)
    want = bna_step_ref(*state)
    names = ("t", "piece", "d", "row", "col", "D", "invalid")
    for name, g, r in zip(names, got, want):
        assert np.array_equal(np.asarray(g, dtype=np.int64),
                              np.asarray(r, dtype=np.int64)), \
            f"bna_step {name} diverged (B={B}, w={w})"


def test_bna_step_int32_guard():
    from repro.kernels.bna_step.ops import bna_step_batch

    d = np.zeros((1, 2, 2), np.int64)
    d[0, 0, 0] = 2**40
    row = d.sum(axis=2)
    col = d.sum(axis=1)
    D = row.max(axis=1)
    match = np.full((1, 2), -1, np.int64)
    with pytest.raises(ValueError, match="int32"):
        bna_step_batch(d, row, col, D, match)


@pytest.mark.parametrize("seed", range(4))
def test_merge_fix_step_matches_ref(seed):
    from repro.kernels.merge_fix import merge_fix_step
    from repro.kernels.merge_fix.ref import merge_fix_ref

    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 30))
    E = int(rng.integers(1, 400))
    t0 = rng.integers(0, 250, E)
    t1 = t0 + rng.integers(1, 50, E)
    s = rng.integers(0, m, E)
    r = rng.integers(0, m, E)
    events = np.unique(np.concatenate([t0, t1]))
    for use_kernel in (True, False):
        al, de = merge_fix_step(events, t0, t1, s, r, m,
                                use_kernel=use_kernel, block_k=64)
        ral, rde = merge_fix_ref(events, t0, t1, s, r, m)
        assert np.array_equal(al, ral) and np.array_equal(de, rde), \
            f"merge_fix diverged (m={m}, E={E}, kernel={use_kernel})"


def test_merge_fix_step_empty_and_int64_lens():
    from repro.kernels.merge_fix import merge_fix_step
    from repro.kernels.merge_fix.ref import merge_fix_ref

    z = np.zeros(0, np.int64)
    al, de = merge_fix_step(np.array([0], np.int64), z, z, z, z, 4)
    assert al.size == 0 and de.size == 0
    # interval lengths too big for the in-graph int32 product: the host
    # int64 fallback must still match the oracle exactly
    t0 = np.array([0, 0], np.int64)
    t1 = np.array([2**33, 2**32], np.int64)
    s = np.array([0, 1], np.int64)
    r = np.array([1, 0], np.int64)
    events = np.unique(np.concatenate([t0, t1]))
    al, de = merge_fix_step(events, t0, t1, s, r, 2)
    ral, rde = merge_fix_ref(events, t0, t1, s, r, 2)
    assert np.array_equal(al, ral) and np.array_equal(de, rde)
    assert de.dtype == np.int64 and de.max() > 2**31
