"""Gamma-stable incremental replanning (PR 10): the GammaEpoch pinning
policy, the exact integer geometric bucketing, the backend's group-block /
grouping-prefix caches, and the relaxed any-offset block-reuse gate.

Pins: geometric_bucket against a float-log reference; pinned-vs-residual
grouping bit-identity when gamma is unchanged; 9x6-matrix feasibility and
backfill-no-worse under pinned gamma; group-block-cache on/off schedule
identity; pinned stream == batch bit-identity; the sustained-arrivals
pure-mode hit-rate floor with rescale accounting; and pinned
snapshot/restore continuation.
"""
import math
from fractions import Fraction

import numpy as np
import pytest

from repro.core import (GammaEpoch, Instance, SchedulerSession, backfill,
                        gdm, geometric_bucket, group_jobs, run_stream,
                        simulate_online, stream_jobs, verify_schedule)
from repro.core import backend
from repro.core.ordering import cached_job_order
from repro.core.stream import StreamDriver

from test_algorithms import rand_instance

M = 8


def _trace(n=40, seed=7, process="poisson", load=1.1):
    return stream_jobs(M, n, seed, process=process, load=load, mu=2)


# --- exact integer bucketing ------------------------------------------------

def test_geometric_bucket_matches_float_reference():
    """b = smallest b >= 0 with key <= gamma * 2^b — the old float-log
    computation (plus its guard loops) as the oracle."""
    gammas = [Fraction(1), Fraction(2), Fraction(3), Fraction(5, 2),
              Fraction(7, 4), Fraction(1, 8), Fraction(1000)]
    for gamma in gammas:
        for key in list(range(1, 300)) + [2**40, 2**40 + 1]:
            b = geometric_bucket(key, gamma)
            # exact rational checks of the defining inequalities
            assert key <= gamma * 2**b
            assert b == 0 or key > gamma * 2**(b - 1)
            # float-log reference (guarded the way the old code was)
            ref = max(0, math.ceil(math.log2(key / float(gamma))))
            while key > float(gamma) * 2**ref:
                ref += 1
            while ref > 0 and key <= float(gamma) * 2**(ref - 1):
                ref -= 1
            assert b == ref, (key, gamma)
    assert geometric_bucket(0, Fraction(3)) == 0
    assert geometric_bucket(-5, Fraction(3)) == 0


# --- GammaEpoch policy ------------------------------------------------------

def test_gamma_epoch_monotone_downward_and_roundtrip():
    e = GammaEpoch()
    assert e.observe(5) == Fraction(5) and e.rescales == 0
    assert e.observe(7) == Fraction(5)          # never rescales upward
    assert e.observe(2) == Fraction(5, 4) and e.rescales == 2
    assert e.observe(1) == Fraction(5, 8) and e.rescales == 3
    assert e.observe(1) == Fraction(5, 8)       # converged: stays put
    e2 = GammaEpoch.from_state(e.state())
    assert e2.pinned == e.pinned and e2.rescales == e.rescales
    assert not e2.fixed

    fixed = GammaEpoch.from_policy(Fraction(3, 2))
    assert fixed.fixed and fixed.observe(1) == Fraction(3, 2)
    assert GammaEpoch.from_policy("residual") is None
    assert GammaEpoch.from_policy("pinned").pinned is None
    for bad in ("sticky", 0, -1, True, 1.5):
        with pytest.raises(ValueError, match="gamma"):
            GammaEpoch.from_policy(bad)
    with pytest.raises(ValueError, match="natural"):
        GammaEpoch().observe(0)


def test_gamma_epoch_pin_is_path_independent():
    """Observing a superset sequence of naturals lands on the same pin —
    the property that keeps the stream driver's extra zero-time replans
    bit-identical to the batch driver's coarser replan sequence."""
    a = GammaEpoch()
    for nat in (12, 9, 9, 5, 5, 2):
        a.observe(nat)
    b = GammaEpoch()
    for nat in (12, 2):
        b.observe(nat)
    assert a.pinned == b.pinned
    assert a.rescales == b.rescales


# --- grouping under pinned gamma -------------------------------------------

def test_group_jobs_pinned_equals_residual_when_gamma_unchanged():
    for seed in range(3):
        inst = rand_instance(seed + 9, n_jobs=6, releases=True)
        order = cached_job_order(inst).order
        residual = group_jobs(inst, order)
        pinned = group_jobs(inst, order, gamma=Fraction(inst.gamma()))
        assert residual == pinned
        # a finer pin only splits groups; every job stays grouped
        finer = group_jobs(inst, order, gamma=Fraction(inst.gamma(), 2))
        assert sorted(j for g in finer for j in g) == \
            sorted(j for g in residual for j in g)
    with pytest.raises(ValueError, match="gamma"):
        group_jobs(inst, order, gamma=0)


@pytest.mark.parametrize("rooted", [False, True])
def test_gdm_pinned_gamma_feasible_and_backfill_no_worse_9x6(rooted):
    """The 9x6 random-DAG matrix (releases on): pinned-gamma plans stay
    capacity/precedence-feasible and backfill still never hurts."""
    inst = rand_instance(9, n_jobs=6, rooted=rooted, releases=True)
    nat = Fraction(inst.gamma())
    for gamma in (nat, nat / 2, Fraction(nat, 4)):
        s = gdm(inst, rooted=rooted, delays="spread", gamma=gamma)
        verify_schedule(inst, s)
        assert s.meta["gamma"] == gamma
        bf = backfill(s)
        assert bf.twct() <= s.twct() + 1e-6
        assert bf.makespan <= s.makespan + 1e-6


def test_group_block_cache_identity():
    """Spread-mode gdm through the group-block cache is bit-identical to
    the cache-bypassing construction."""
    inst = rand_instance(4, n_jobs=6, releases=True)
    backend.clear_caches()
    cached = gdm(inst, delays="spread")
    again = gdm(inst, delays="spread")           # fully cache-served
    with backend.no_caches():
        direct = gdm(inst, delays="spread")
    for other in (again, direct):
        assert cached.job_completions() == other.job_completions()
        assert [(e.t0, e.t1, e.jid, e.cid) for e in
                cached.transcript().entries] == \
            [(e.t0, e.t1, e.jid, e.cid) for e in
             other.transcript().entries]
    st = backend.cache_stats()["group"]
    assert st["hits"] > 0


def test_group_block_rejects_randomized_modes():
    inst = rand_instance(4, n_jobs=2)
    with pytest.raises(ValueError, match="spread"):
        backend.group_block("gdm", inst.jobs, inst.m, delays="random")
    with pytest.raises(ValueError, match="kind"):
        backend.group_block("om_alg", inst.jobs, inst.m, delays="spread")


# --- session integration ----------------------------------------------------

@pytest.mark.parametrize("sched,opts", [
    ("gdm", {"delays": "spread", "seed": 0}),
    ("gdm_rt", {"delays": "spread", "seed": 0}),
])
def test_pinned_stream_is_bit_identical_to_batch(sched, opts):
    jobs = _trace()
    inst = Instance(M, list(jobs))
    res = run_stream(jobs, M, sched, gamma="pinned", **opts)
    batch = simulate_online(inst, sched, driver="batch", gamma="pinned",
                            **opts)
    assert res.online.job_completions == batch.job_completions
    assert res.online.twct() == batch.twct()


def test_gamma_needs_engine_gdm_scheduler():
    with pytest.raises(ValueError, match="gamma"):
        SchedulerSession(M, "om_alg", gamma="pinned")
    with pytest.raises(ValueError, match="gamma"):
        simulate_online(Instance(M, _trace(n=3)), "om_alg", driver="batch",
                        gamma="pinned")
    SchedulerSession(M, "gdm", gamma="pinned", delays="spread")  # fine


@pytest.mark.parametrize("sched", ["gdm", "gdm_rt"])
def test_sustained_pinned_hit_rate_floor_and_rescale_accounting(sched):
    """The tentpole's payoff, as a fixed-seed CI floor: pinning gamma must
    lift the pure-mode (no admission policy) repair hit rate to >= 0.4 on
    the sustained-arrivals trace, strictly above the residual-gamma run,
    while staying bit-identical to its own batch comparator."""
    jobs = _trace(n=60)
    pinned = run_stream(jobs, M, sched, gamma="pinned", delays="spread",
                        seed=0)
    residual = run_stream(jobs, M, sched, delays="spread", seed=0)
    sp = pinned.online.stats["session"]
    sr = residual.online.stats["session"]
    assert sp["repair_hit_rate"] >= 0.4
    assert sp["repair_hit_rate"] > sr["repair_hit_rate"]
    assert sp["groups_reused"] > sr["groups_reused"]
    # rescale accounting: heavy-tail sizes drain through small residuals,
    # so the pin must halve at least once — and only the pinned run counts
    assert sp["gamma_rescales"] > 0
    assert sr["gamma_rescales"] == 0


def test_pinned_snapshot_restore_continues_bit_identically():
    jobs = _trace(n=30)
    opts = {"delays": "spread", "seed": 0}
    ref = run_stream(jobs, M, "gdm", gamma="pinned", **opts)

    drv = StreamDriver(M, "gdm", gamma="pinned", **opts)
    for j in jobs[:11]:
        drv.feed(j)
    snap = drv.session.snapshot()
    assert snap.gamma_epoch is not None     # the pin rides the snapshot

    resumed = SchedulerSession.restore(snap, jobs[:11], "gdm",
                                       gamma="pinned", **opts)
    assert resumed._gamma_epoch.state() == snap.gamma_epoch
    for j in jobs[11:]:
        resumed.submit(j)
    resumed.advance()
    out = resumed.result()
    assert out.job_completions == ref.online.job_completions
    assert out.twct() == ref.online.twct()

    # a residual-gamma snapshot carries no epoch
    drv2 = StreamDriver(M, "gdm", **opts)
    for j in jobs[:5]:
        drv2.feed(j)
    assert drv2.session.snapshot().gamma_epoch is None


def test_grouping_prefix_extends_cached_cumsum():
    """Appending jobs to an already-planned order extends the cached
    prefix cumsum (the 'extended' counter) instead of recomputing it."""
    from repro.core.ordering import job_load_vectors

    inst = rand_instance(11, n_jobs=5)
    order = cached_job_order(inst).order
    by_id = {j.jid: j for j in inst.jobs}
    sub = Instance(inst.m, [by_id[jid] for jid in order[:4]])
    backend.clear_caches()
    D4 = backend.grouping_prefix(sub, order[:4])
    assert dict(backend.cache_stats()["gkey"]["prefix"]) == \
        {"exact": 0, "extended": 0, "cold": 1}
    D5 = backend.grouping_prefix(inst, order)       # appended-arrival shape
    assert backend.cache_stats()["gkey"]["prefix"]["extended"] == 1
    assert np.array_equal(D5[:4], D4)
    # exact against the monolithic cumsum of per-job load vectors
    rows = job_load_vectors([by_id[jid] for jid in order], inst.m)
    ref = np.cumsum(rows, axis=0).max(axis=1).astype(np.int64)
    assert np.array_equal(D5, ref)
    assert np.array_equal(backend.grouping_prefix(inst, order), D5)
    assert backend.cache_stats()["gkey"]["prefix"]["exact"] == 1
