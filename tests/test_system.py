"""End-to-end behaviour tests: the paper pipeline (workload -> schedule ->
metrics -> verification) and the framework drivers (train N steps with
checkpointing on a real reduced model; batched serving)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"))


def test_paper_pipeline_end_to_end():
    from repro.core import (backfill, gdm, om_alg, paper_workload,
                            verify_schedule, workload_stats)
    inst = paper_workload(m=15, mu_bar=4, seed=0, scale=0.06, rooted=True)
    st = workload_stats(inst)
    assert st["n_jobs"] >= 2 and st["min_flow"] >= 1
    g = gdm(inst, rng=np.random.default_rng(0), rooted=True, decompose=True)
    verify_schedule(inst, g)
    o = om_alg(inst, decompose=True)
    verify_schedule(inst, o)
    bf = backfill(g)
    assert bf.makespan <= g.makespan + 1e-6
    assert g.twct() > 0 and o.twct() > 0 and bf.twct() > 0


def test_train_driver_end_to_end(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
         "--smoke", "--steps", "8", "--seq-len", "32", "--global-batch", "4",
         "--ckpt-dir", str(tmp_path)],
        env=ENV, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    stats = json.loads(out.stdout.strip().splitlines()[-1])
    assert stats["steps"] == 8 and np.isfinite(stats["last_loss"])


def test_serve_driver_end_to_end():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-1.7b",
         "--requests", "4", "--max-new", "4"],
        env=ENV, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    stats = json.loads(out.stdout.strip().splitlines()[-1])
    assert stats["completed"] == 4
