"""Jitted planning pipeline (core/pipeline.py + the REPRO_PLAN_BACKEND
dispatch in core/backend.py):

  * plan identity — the 9-scenario x 6-scheduler matrix planned under
    ``jit`` must be results-identical (twct, per-job and per-coflow
    completions) to the ``python`` path, including with the pallas
    alpha/BNA backends layered on top (reduced grid);
  * decomposition bit-identity at the pipeline level — pieces equal the
    scalar ``bna`` and relative edge intervals equal the python RLE on the
    padding/width-bucket edge cases: zero-demand coflows, 1x1 singletons,
    widths straddling the power-of-two bucket cuts;
  * structural edge cases at the instance level — singleton levels (a
    chain of one-coflow levels), forest residuals (a job whose Starts-After
    DAG is a multi-root forest), zero-demand coflows inside a job;
  * session repair-path equivalence — the event-driven driver under jit
    replays the online protocol bit-identically (repair on and off);
  * backend knob + cache plumbing — validation, context-manager restore,
    prefetch warming the edge cache, ``cache_stats()['plan']`` exposure.

Compile cost discipline: tests never clear the compile cache (executables
are data-independent), so the suite pays each (B_pad, w, T_cap) signature
once.
"""
import functools

import numpy as np
import pytest

from repro import scenarios
from repro.core import (available_schedulers, backend, bna, cache_stats,
                        clear_caches, plan, prefetch_plan, simulate_online,
                        use_plan_backend)
from repro.core import pipeline
from repro.core.backend import config, resolve_plan_backend, set_plan_backend
from repro.core.timeline import bna_pieces_to_edge_intervals
from repro.core.types import Coflow, Instance, Job

SCHEDULERS = sorted(available_schedulers())
# tiny sizes so the full matrix stays CI-cheap (mirrors tests/test_matching)
TINY = {
    "fb_like": dict(m=6, scale=0.03),
    "fb_like_rt": dict(m=6, scale=0.03),
    "alibaba_sparse": dict(m=6, scale=0.15),
    "incast": dict(m=6, scale=0.1),
    "shuffle_heavy": dict(m=6, scale=0.2),
    "wide_shallow": dict(m=6, scale=0.2),
    "online_poisson": dict(m=6, scale=0.03),
    "deep_chain": dict(m=6, scale=0.25),
    "dist_collectives": dict(m=8, scale=0.5),
}


@functools.lru_cache(maxsize=None)
def _tiny(name):
    return scenarios.build(name, seed=0, **TINY[name])


def _fingerprint(p):
    """twct + per-job completions + the full transcript, canonicalized
    (flows within an entry sorted) so edge emission order is immaterial."""
    entries = tuple(sorted(
        (e.jid, e.cid, round(float(e.t0), 9), round(float(e.t1), 9),
         tuple(sorted(zip(np.asarray(e.srcs).tolist(),
                          np.asarray(e.dsts).tolist(),
                          np.round(np.asarray(e.units, dtype=float), 9)
                          .tolist()))))
        for e in p.transcript().entries))
    return (p.twct(), p.makespan, tuple(sorted(p.job_completions().items())),
            entries)


@functools.lru_cache(maxsize=None)
def _ref_plan(scen, sched):
    """Python-path reference, caches cold."""
    built = _tiny(scen)
    opts = scenarios.scheduler_opts(sched, built.meta)
    with use_plan_backend("python"):
        clear_caches()
        p = plan(built.instance, sched, seed=0, **opts)
    return _fingerprint(p)


# --------------------------------------------------------------------------
# plan identity: 9 scenarios x 6 schedulers, jit vs python
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sched", SCHEDULERS)
@pytest.mark.parametrize("scen", sorted(TINY))
def test_plan_identity_jit(scen, sched):
    built = _tiny(scen)
    opts = scenarios.scheduler_opts(sched, built.meta)
    ref = _ref_plan(scen, sched)
    with use_plan_backend("jit"):
        clear_caches()
        p = plan(built.instance, sched, seed=0, **opts)
    assert _fingerprint(p) == ref, f"{scen}/{sched}: jit plan diverged"


@pytest.mark.parametrize("sched", ("gdm", "om_alg_bf"))
@pytest.mark.parametrize("scen", ("wide_shallow", "incast", "deep_chain"))
def test_plan_identity_jit_pallas_stack(scen, sched):
    """jit plan backend with the pallas alpha AND BNA backends layered on
    top (the fused merge_fix path engages where it applies)."""
    built = _tiny(scen)
    opts = scenarios.scheduler_opts(sched, built.meta)
    ref = _ref_plan(scen, sched)
    with use_plan_backend("jit"), backend.use_alpha_backend("pallas"), \
            backend.use_bna_backend("pallas"):
        clear_caches()
        p = plan(built.instance, sched, seed=0, **opts)
    assert _fingerprint(p) == ref, f"{scen}/{sched}: pallas stack diverged"


# --------------------------------------------------------------------------
# decomposition bit-identity: padding / width-bucket edge cases
# --------------------------------------------------------------------------

def _edge_set(t0, t1, s, r):
    return sorted(zip(np.asarray(t0).tolist(), np.asarray(t1).tolist(),
                      np.asarray(s).tolist(), np.asarray(r).tolist()))


def _assert_decomp_matches(demands):
    pieces_list, edges_list = pipeline._plan_decompositions(demands)
    for i, (dem, pieces, rel) in enumerate(zip(demands, pieces_list,
                                               edges_list)):
        ref = bna(np.asarray(dem, np.int64))
        assert len(pieces) == len(ref), f"demand {i}: piece count"
        for (t1, p1), (t2, p2) in zip(pieces, ref):
            assert t1 == t2 and np.array_equal(p1, p2), \
                f"demand {i}: pieces diverged"
        ei = bna_pieces_to_edge_intervals(ref, 0)
        assert _edge_set(*rel) == _edge_set(ei.t0, ei.t1, ei.s, ei.r), \
            f"demand {i}: edge intervals diverged"


def test_decompose_width_bucket_edges():
    rng = np.random.default_rng(7)
    demands = [np.zeros((4, 4), np.int64),            # zero-demand coflow
               np.array([[5]], np.int64),             # 1x1 singleton
               np.zeros((1, 1), np.int64)]            # 1x1 zero
    for m in (2, 3, 7, 8, 9, 16, 17):                 # bucket cuts 8|9, 16|17
        d = rng.integers(0, 25, size=(m, m))
        d[rng.random((m, m)) > 0.5] = 0
        demands.append(d)
    demands.append(np.diag(rng.integers(1, 9, 6)))    # permutation support
    demands.append(np.eye(5, dtype=np.int64) * 3)     # another diagonal
    _assert_decomp_matches(demands)


def test_decompose_sparse_support_padding():
    # support restriction: dense rows scattered through a mostly-zero
    # matrix, so the packed sub-matrix is much smaller than m
    rng = np.random.default_rng(11)
    demands = []
    for m, k in ((12, 2), (16, 3), (20, 5)):
        d = np.zeros((m, m), np.int64)
        rows = rng.choice(m, size=k, replace=False)
        cols = rng.choice(m, size=k, replace=False)
        for a in rows:
            for b in cols:
                if rng.random() < 0.7:
                    d[a, b] = int(rng.integers(1, 30))
        demands.append(d)
    _assert_decomp_matches(demands)


# --------------------------------------------------------------------------
# structural instance-level edge cases
# --------------------------------------------------------------------------

def _plan_both(inst, sched="gdm", **opts):
    with use_plan_backend("python"):
        clear_caches()
        ref = _fingerprint(plan(inst, sched, seed=0, **opts))
    with use_plan_backend("jit"):
        clear_caches()
        got = _fingerprint(plan(inst, sched, seed=0, **opts))
    assert got == ref


def _rand_demand(rng, m, density=0.5, hi=15):
    d = rng.integers(0, hi, size=(m, m))
    d[rng.random((m, m)) > density] = 0
    return d


@pytest.mark.parametrize("sched", ("gdm", "om_alg"))
def test_singleton_levels_chain(sched):
    # one coflow per level: the degenerate DAG shape where every group is
    # a singleton
    rng = np.random.default_rng(0)
    m, depth = 5, 6
    cofs = [Coflow(0, k, _rand_demand(rng, m)) for k in range(depth)]
    edges = [(k, k + 1) for k in range(depth - 1)]
    inst = Instance(m, [Job(0, cofs, edges, weight=1.0, release=0)])
    _plan_both(inst, sched)


@pytest.mark.parametrize("sched", ("gdm", "om_alg"))
def test_forest_residual_dag(sched):
    # multi-root forest inside one job plus an isolated coflow — the
    # residual shapes geometric grouping leaves behind
    rng = np.random.default_rng(1)
    m = 6
    cofs = [Coflow(0, k, _rand_demand(rng, m)) for k in range(5)]
    edges = [(0, 1), (2, 3)]  # two trees + coflow 4 isolated
    jobs = [Job(0, cofs, edges, weight=2.0, release=0),
            Job(1, [Coflow(1, 0, _rand_demand(rng, m))], [], weight=0.5,
                release=3)]
    inst = Instance(m, jobs)
    _plan_both(inst, sched)


def test_zero_demand_coflow_in_job():
    rng = np.random.default_rng(2)
    m = 4
    cofs = [Coflow(0, 0, _rand_demand(rng, m)),
            Coflow(0, 1, np.zeros((m, m), np.int64)),
            Coflow(0, 2, _rand_demand(rng, m))]
    inst = Instance(m, [Job(0, cofs, [(0, 1), (1, 2)], weight=1.0,
                            release=0)])
    _plan_both(inst, "gdm")


# --------------------------------------------------------------------------
# session repair-path equivalence under jit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("repair", (True, False))
def test_session_equivalence_jit(repair):
    built = _tiny("online_poisson")
    with use_plan_backend("python"):
        clear_caches()
        ref = simulate_online(built.instance, "gdm", driver="session",
                              seed=0, repair=repair)
    with use_plan_backend("jit"):
        clear_caches()
        got = simulate_online(built.instance, "gdm", driver="session",
                              seed=0, repair=repair)
    assert got.job_completions == ref.job_completions
    assert got.twct() == ref.twct()
    assert got.reschedules == ref.reschedules


# --------------------------------------------------------------------------
# backend knob + cache plumbing
# --------------------------------------------------------------------------

def test_plan_backend_knob_validation():
    with pytest.raises(ValueError):
        set_plan_backend("bogus")
    prev = config.plan_backend
    with use_plan_backend("jit"):
        assert config.plan_backend == "jit"
        assert resolve_plan_backend() == "jit"
    assert config.plan_backend == prev
    assert resolve_plan_backend("python") == "python"
    assert resolve_plan_backend("auto") in ("python", "jit")


def test_prefetch_warms_edge_cache_and_stats():
    rng = np.random.default_rng(5)
    demands = [_rand_demand(rng, 5) for _ in range(6)]
    with use_plan_backend("jit"):
        pipeline.clear_pipeline_caches()
        prefetch_plan(demands)
        st = cache_stats()["plan"]
        assert st["edges"]["size"] > 0
        assert st["compile"]["batches"] >= 1
        before = st["edges"]["hits"]
        for d in demands:  # every per-coflow lookup must now hit
            assert pipeline.coflow_edges_rel(d) is not None
        st = cache_stats()["plan"]
        assert st["edges"]["hits"] >= before + len(demands)


def test_prefetch_python_backend_untouched():
    rng = np.random.default_rng(6)
    demands = [_rand_demand(rng, 4) for _ in range(3)]
    with use_plan_backend("python"):
        pipeline.clear_pipeline_caches()
        prefetch_plan(demands)  # routes to prefetch_bna, not the pipeline
        assert cache_stats()["plan"]["edges"]["size"] == 0
        assert backend.plan_edges(demands[0]) is None
