"""DAG-shape statistics in `workload_stats` and the generalized trace
primitives (width/size distributions, port-skew maps, DAG-family sampler)."""
import numpy as np
import pytest

from repro.core import (Coflow, Instance, Job, dag_edges, port_skew,
                        sample_coflows, sample_sizes, sample_width,
                        workload_stats)


def _job(jid, n, edges, m=4, fill=1):
    d = np.full((m, m), fill, dtype=np.int64)
    np.fill_diagonal(d, 0)
    return Job(jid, [Coflow(jid, k, d.copy()) for k in range(n)], edges)


def test_stats_chain_shape():
    job = _job(0, 5, [(k, k + 1) for k in range(4)])
    st = workload_stats(Instance(4, [job]))
    assert st["dag_depth_max"] == 4
    assert st["max_fan_in"] == 1 and st["max_fan_out"] == 1
    assert st["tree_fraction"] == 1.0  # a chain is a (degenerate) rooted tree


def test_stats_star_shape():
    job = _job(0, 6, [(a, 5) for a in range(5)])  # wide-and-shallow fan-in
    st = workload_stats(Instance(4, [job]))
    assert st["dag_depth_max"] == 1
    assert st["max_fan_in"] == 5 and st["max_fan_out"] == 1
    assert st["tree_fraction"] == 1.0


def test_stats_mixed_tree_fraction_and_depth():
    tree = _job(0, 3, [(0, 2), (1, 2)])
    diamond = _job(1, 4, [(0, 1), (0, 2), (1, 3), (2, 3)])  # not a tree
    st = workload_stats(Instance(4, [tree, diamond]))
    assert st["tree_fraction"] == pytest.approx(0.5)
    assert st["dag_depth_max"] == 2
    assert st["max_fan_out"] == 2  # diamond's source
    assert st["dag_depth_mean"] == pytest.approx(1.5)


def test_stats_edgeless_jobs():
    st = workload_stats(Instance(4, [_job(0, 2, [])]))
    assert st["dag_depth_max"] == 0
    assert st["max_fan_in"] == 0 and st["max_fan_out"] == 0


# --- generalized primitives --------------------------------------------------

def test_sample_width_distributions():
    rng = np.random.default_rng(0)
    for dist, lo, hi in ((("fixed", 7), 7, 7),
                         (("uniform", 2, 9), 2, 9),
                         (("loguniform", 1, 50), 1, 50)):
        for _ in range(50):
            w = sample_width(rng, dist, cap=100)
            assert lo <= w <= hi
    assert sample_width(rng, ("fixed", 500), cap=12) == 12  # capped
    with pytest.raises(ValueError):
        sample_width(rng, ("zeta", 1), cap=10)


def test_sample_sizes_clipped_and_integer():
    rng = np.random.default_rng(1)
    for dist in (("lognormal", 3.0, 1.6), ("uniform", 1, 9),
                 ("pareto", 1.5, 2.0), ("fixed", 4)):
        s = sample_sizes(rng, 200, dist, clip=(1, 9))
        assert s.dtype == np.int64 and s.min() >= 1 and s.max() <= 9
    with pytest.raises(ValueError):
        sample_sizes(rng, 5, ("weird", 1))


def test_port_skew_shapes():
    assert port_skew(8, "uniform") is None
    hot = port_skew(8, "hotspot", hot=2, hot_mass=0.9)
    assert hot.shape == (8,) and hot.sum() == pytest.approx(1.0)
    assert hot[:2].sum() == pytest.approx(0.9)
    z = port_skew(8, "zipf", a=1.5)
    assert z.sum() == pytest.approx(1.0)
    assert (np.diff(z) < 0).all()  # strictly decreasing with rank
    with pytest.raises(ValueError):
        port_skew(8, "bimodal")


def test_sample_coflows_respects_skew_and_bounds():
    m = 8
    skew = port_skew(m, "hotspot", hot=1, hot_mass=0.95)
    demands = sample_coflows(m, 20, seed=3,
                             width_dist=("uniform", m, 2 * m),
                             size_dist=("uniform", 1, 9), size_clip=(1, 9),
                             dst_skew=skew)
    for d in demands:
        assert d.shape == (m, m) and (np.diag(d) == 0).all()
        assert d[d > 0].min() >= 1
    # hot receiver draws the bulk of the traffic
    col = sum(d.sum(axis=0) for d in demands)
    assert col[0] > 0.5 * col.sum()


def test_dag_edges_families():
    rng = np.random.default_rng(0)
    assert dag_edges(5, "chain", rng) == [(k, k + 1) for k in range(4)]
    assert dag_edges(5, "star", rng) == [(a, 4) for a in range(4)]
    assert dag_edges(5, "independent", rng) == []
    tree = dag_edges(5, "tree", rng)
    assert len(tree) == 4 and all(a < b for a, b in tree)
    gen = dag_edges(5, "general", rng)
    assert all(a < b for a, b in gen)
    assert dag_edges(1, "general", rng) == []
    with pytest.raises(ValueError):
        dag_edges(5, "torus", rng)
