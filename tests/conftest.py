import os
import sys
from pathlib import Path

# tests see the default single CPU device (the 512-device override is only
# ever set inside repro.launch.dryrun / dedicated subprocess tests)
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
