"""Data pipeline and scheduling-determinism invariants that the fault
tolerance story depends on."""
import jax
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.core import Coflow, Instance, Job, dma, gdm, om_alg
from repro.core.dma import draw_delays
from repro.data.pipeline import DataConfig, SyntheticTokens

CFG = get_config("tinyllama-1.1b").smoke()


def test_batches_are_pure_functions_of_step():
    a = SyntheticTokens(CFG, DataConfig(seq_len=64, global_batch=8, seed=3))
    b = SyntheticTokens(CFG, DataConfig(seq_len=64, global_batch=8, seed=3))
    for step in (0, 7, 123):
        ba, bb = a.batch_at(step), b.batch_at(step)
        for k in ba:
            assert np.array_equal(np.asarray(ba[k]), np.asarray(bb[k]))


def test_host_sharded_rows_match_global_batch():
    data = SyntheticTokens(CFG, DataConfig(seq_len=32, global_batch=8, seed=0))
    full = data.batch_at(5)
    lo = data.batch_at(5, lo=0, hi=4)
    hi = data.batch_at(5, lo=4, hi=8)
    got = np.concatenate([np.asarray(lo["tokens"]), np.asarray(hi["tokens"])])
    assert np.array_equal(got, np.asarray(full["tokens"]))


def test_labels_are_shifted_tokens():
    data = SyntheticTokens(CFG, DataConfig(seq_len=16, global_batch=2, seed=1))
    b = data.batch_at(0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    assert np.array_equal(labels[:, :-1], toks[:, 1:])
    assert (labels[:, -1] == -1).all()


def test_data_has_learnable_structure():
    data = SyntheticTokens(CFG, DataConfig(seq_len=512, global_batch=4, seed=0))
    toks = np.asarray(data.batch_at(0)["tokens"])
    v = CFG.vocab
    pred = (toks[:, :-1] * 31 + 7) % (v - 1) + 1
    frac = (pred == toks[:, 1:]).mean()
    assert frac > 0.3  # ~half the transitions follow the affine rule


def test_spread_delays_deterministic():
    # rng=None selects the deterministic de-randomized mode (§IV-C stand-in)
    d1 = draw_delays([1, 2, 3, 4], delta=100, beta=2.0, rng=None)
    d2 = draw_delays([1, 2, 3, 4], delta=100, beta=2.0, rng=None)
    assert d1 == d2
    assert min(d1.values()) == 0 and max(d1.values()) == 100 // 2


def test_gdm_deterministic_given_rng_seed():
    from repro.core import paper_workload
    inst = paper_workload(m=10, mu_bar=3, seed=4, scale=0.04)
    a = gdm(inst, rng=np.random.default_rng(9)).twct()
    b = gdm(inst, rng=np.random.default_rng(9)).twct()
    assert a == b


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500))
def test_om_alg_is_delay_free_deterministic(seed):
    rng = np.random.default_rng(seed)
    jobs = []
    for j in range(3):
        d = rng.integers(0, 9, size=(5, 5)).astype(np.int64)
        jobs.append(Job(j, [Coflow(j, 0, d)], [], weight=1.0))
    inst = Instance(5, jobs)
    assert om_alg(inst).twct() == om_alg(inst).twct()
