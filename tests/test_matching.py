"""Batched matching subsystem (core/matching.py + the REPRO_BNA_BACKEND
dispatch in core/backend.py):

  * piece-level bit-identity — ``bna_many`` must equal scalar ``bna`` per
    coflow across the width/dtype/zero-demand grid (property tests via the
    hypothesis shim), on BOTH backends (pallas runs the bna_step kernel in
    interpret mode);
  * plan identity — the 9-scenario x 6-scheduler matrix planned with the
    batch prefetch on (each backend) must be results-identical to the
    scalar path (batch off);
  * LRU key hardening — (shape, dtype, bytes) keys: differently-typed or
    differently-shaped demands neither collide nor spuriously hit;
  * batch cache behaviour — ``bna_pieces_many`` consults the LRU first,
    deduplicates in-batch repeats, and surfaces per-batch hit/miss in
    ``cache_stats()``;
  * the spread-delay registry option (``make_scheduler("gdm",
    delays="spread")``) — deterministic, seed-independent, validated.
"""
import functools

import numpy as np
import pytest

from repro import scenarios
from repro.core import (available_schedulers, backend, bna, bna_many,
                        bna_pieces_many, cache_stats, clear_caches, plan,
                        prefetch_bna)
from repro.core.backend import bna_pieces, config
from repro.core.matching import bucket_width
from repro.testing.hypothesis_compat import given, settings, strategies as st

SCHEDULERS = sorted(available_schedulers())
# tiny sizes so the full matrix stays CI-cheap (mirrors tests/test_scenarios)
TINY = {
    "fb_like": dict(m=6, scale=0.03),
    "fb_like_rt": dict(m=6, scale=0.03),
    "alibaba_sparse": dict(m=6, scale=0.15),
    "incast": dict(m=6, scale=0.1),
    "shuffle_heavy": dict(m=6, scale=0.2),
    "wide_shallow": dict(m=6, scale=0.2),
    "online_poisson": dict(m=6, scale=0.03),
    "deep_chain": dict(m=6, scale=0.25),
    "dist_collectives": dict(m=8, scale=0.5),
}


def _assert_pieces_equal(got, want, ctx=""):
    assert len(got) == len(want), f"{ctx}: piece count {len(got)} != {len(want)}"
    for i, ((t1, p1), (t2, p2)) in enumerate(zip(got, want)):
        assert t1 == t2, f"{ctx}: piece {i} duration {t1} != {t2}"
        assert np.array_equal(p1, p2), f"{ctx}: piece {i} matching differs"


def _random_demands(seed, n, m_max, density, hi):
    """Mixed-width, mixed-dtype batch; density 0 yields all-zero demands
    (the zero-demand grid point)."""
    rng = np.random.default_rng(seed)
    dtypes = (np.int64, np.int32, np.int16)
    out = []
    for i in range(n):
        m = int(rng.integers(1, m_max + 1))
        d = rng.integers(0, hi + 1, size=(m, m))
        d[rng.random((m, m)) > density] = 0
        out.append(d.astype(dtypes[i % len(dtypes)]))
    return out


# --------------------------------------------------------------------------
# bit-identity vs the scalar reference
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(1, 14),
    m_max=st.integers(1, 12),
    density=st.floats(0.0, 1.0),
    hi=st.integers(1, 50),
)
def test_bna_many_bit_identity_numpy(seed, n, m_max, density, hi):
    demands = _random_demands(seed, n, m_max, density, hi)
    with backend.use_bna_backend("numpy"):
        many = bna_many(demands, validate=True)
    for i, (dem, pieces) in enumerate(zip(demands, many)):
        _assert_pieces_equal(pieces, bna(np.asarray(dem, np.int64)),
                             ctx=f"demand {i}")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bna_many_bit_identity_pallas(seed):
    demands = _random_demands(seed, n=24, m_max=10, density=0.6, hi=40)
    demands.append(np.zeros((4, 4), np.int64))
    with backend.use_bna_backend("pallas"):
        many = bna_many(demands)
    for i, (dem, pieces) in enumerate(zip(demands, many)):
        _assert_pieces_equal(pieces, bna(np.asarray(dem, np.int64)),
                             ctx=f"demand {i}")


def test_bna_many_wide_bucket_boundaries():
    # widths straddling the power-of-two bucket cuts (8|9, 16|17)
    rng = np.random.default_rng(3)
    demands = []
    for m in (7, 8, 9, 15, 16, 17):
        d = rng.integers(0, 20, size=(m, m))
        d[rng.random((m, m)) > 0.5] = 0
        demands.append(d)
    many = bna_many(demands, validate=True, force="numpy")
    for dem, pieces in zip(demands, many):
        _assert_pieces_equal(pieces, bna(dem))


def test_bucket_width():
    assert [bucket_width(k) for k in (1, 2, 3, 4, 5, 8, 9, 16, 17)] == \
        [1, 2, 4, 4, 8, 8, 16, 16, 32]


def test_bna_many_rejects_bad_demands():
    with pytest.raises(ValueError):
        bna_many([np.array([[-1, 0], [0, 0]])])
    with pytest.raises(ValueError):
        bna_many([np.zeros((2, 3), np.int64)])


# --------------------------------------------------------------------------
# plan identity: 9 scenarios x 6 schedulers x both backends
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _tiny(name):
    return scenarios.build(name, seed=0, **TINY[name])


@functools.lru_cache(maxsize=None)
def _ref_plan(scen, sched):
    """Scalar-path reference: batch prefetch off, caches cold."""
    built = _tiny(scen)
    opts = scenarios.scheduler_opts(sched, built.meta)
    prev = config.bna_batch
    try:
        config.bna_batch = False
        clear_caches()
        p = plan(built.instance, sched, seed=0, **opts)
    finally:
        config.bna_batch = prev
    return p.twct(), p.job_completions()


@pytest.mark.parametrize("sched", SCHEDULERS)
@pytest.mark.parametrize("scen", sorted(TINY))
def test_plan_identity_batched_backends(scen, sched):
    built = _tiny(scen)
    opts = scenarios.scheduler_opts(sched, built.meta)
    ref_twct, ref_comp = _ref_plan(scen, sched)
    for name in ("numpy", "pallas"):
        with backend.use_bna_backend(name):
            clear_caches()
            p = plan(built.instance, sched, seed=0, **opts)
        assert p.twct() == ref_twct, f"{scen}/{sched}/{name}: twct diverged"
        assert p.job_completions() == ref_comp, \
            f"{scen}/{sched}/{name}: completions diverged"


# --------------------------------------------------------------------------
# backend knob + cache behaviour
# --------------------------------------------------------------------------

def test_bna_backend_knob_validation():
    with pytest.raises(ValueError):
        backend.set_bna_backend("bogus")
    prev = config.bna_backend
    with backend.use_bna_backend("numpy"):
        assert config.bna_backend == "numpy"
        assert backend.resolve_bna_backend() == "numpy"
    assert config.bna_backend == prev
    assert backend.resolve_bna_backend("pallas") == "pallas"


def test_bna_cache_key_shape_dtype_hardening():
    clear_caches()
    d64 = np.array([[3, 0], [0, 2]], dtype=np.int64)
    d32 = d64.astype(np.int32)
    p1 = bna_pieces(d64)
    before = cache_stats()["bna"]
    # same values, different dtype: must MISS (no spurious hit), and still
    # produce the same decomposition
    p2 = bna_pieces(d32)
    after = cache_stats()["bna"]
    assert after["misses"] == before["misses"] + 1
    _assert_pieces_equal(p2, p1)
    # same bytes, different shape: keys differ (no collision)
    flat = np.frombuffer(d64.tobytes(), dtype=np.int64)
    k_sq = backend._bna_key(d64)
    k_fl = backend._bna_key(flat)
    assert k_sq != k_fl and k_sq[2] == k_fl[2]
    # identical array: hit
    b2 = cache_stats()["bna"]["hits"]
    bna_pieces(d64.copy())
    assert cache_stats()["bna"]["hits"] == b2 + 1


def test_bna_pieces_many_batches_only_misses():
    clear_caches()
    rng = np.random.default_rng(0)
    a = rng.integers(0, 9, size=(5, 5)).astype(np.int64)
    b = rng.integers(0, 9, size=(6, 6)).astype(np.int64)
    out = bna_pieces_many([a, b, a.copy()])   # in-batch duplicate: one miss
    _assert_pieces_equal(out[0], bna(a))
    _assert_pieces_equal(out[1], bna(b))
    assert out[2] is out[0], "in-batch duplicate should share pieces"
    s = cache_stats()["bna"]
    assert s["batch"] == {"batches": 1, "hits": 0, "misses": 2, "deduped": 1}
    assert len(backend.bna_cache) == 2
    out2 = bna_pieces_many([a, b])            # fully warm: all hits
    assert out2[0] is out[0] and out2[1] is out[1]
    s = cache_stats()["bna"]["batch"]
    assert s == {"batches": 2, "hits": 2, "misses": 2, "deduped": 1}


def test_prefetch_bna_gating():
    clear_caches()
    d = np.eye(3, dtype=np.int64) * 4
    prev = config.bna_batch
    try:
        config.bna_batch = False
        prefetch_bna([d])
        assert len(backend.bna_cache) == 0, "prefetch must no-op when off"
        config.bna_batch = True
        prefetch_bna([d])
        assert len(backend.bna_cache) == 1
    finally:
        config.bna_batch = prev
    with backend.no_caches():
        prefetch_bna([d])   # disabled cache: must not raise, must not store
        assert len(backend.bna_cache) == 0


def test_prefetch_bna_skips_when_batch_exceeds_cache():
    """More distinct demands than the LRU can hold: a batch bigger than
    maxsize necessarily evicts some of its own entries (refreshed hits
    included) before the scheduler reads them (sequential-LRU thrash), so
    the prefetch must decline and leave the scalar path to fill the cache
    on the fly — even when only one member is actually uncached."""
    clear_caches()
    rng = np.random.default_rng(0)
    demands = [rng.integers(1, 9, size=(3, 3)).astype(np.int64)
               for _ in range(5)]
    prev = config.bna_cache_size
    try:
        config.bna_cache_size = 4
        backend.bna_cache.maxsize = 4
        prefetch_bna(demands)
        assert len(backend.bna_cache) == 0
        assert cache_stats()["bna"]["batch"]["batches"] == 0
        prefetch_bna(demands[:4])   # fits: batches normally
        assert len(backend.bna_cache) == 4
        prefetch_bna(demands)       # 4 cached + 1 new = 5 distinct: decline
        assert cache_stats()["bna"]["batch"]["batches"] == 1
        # duplicates don't count against the budget
        prefetch_bna(demands[:4] + [demands[0].copy()])
        assert cache_stats()["bna"]["batch"]["batches"] == 2
    finally:
        config.bna_cache_size = prev
        backend.bna_cache.maxsize = prev
        clear_caches()


# --------------------------------------------------------------------------
# spread-delay registry option (satellite)
# --------------------------------------------------------------------------

def test_gdm_spread_deterministic_and_seed_independent():
    built = _tiny("fb_like")
    a = plan(built.instance, "gdm", delays="spread", seed=0)
    b = plan(built.instance, "gdm", delays="spread", seed=1234)
    assert a.twct() == b.twct()
    assert a.job_completions() == b.job_completions()


def test_gdm_rt_spread_runs():
    built = _tiny("fb_like_rt")
    a = plan(built.instance, "gdm_rt", delays="spread", seed=0)
    b = plan(built.instance, "gdm_rt", delays="spread", seed=7)
    assert a.twct() == b.twct()


def test_delays_mode_validated():
    built = _tiny("fb_like")
    with pytest.raises(ValueError, match="delays mode"):
        plan(built.instance, "gdm", delays="bogus")
