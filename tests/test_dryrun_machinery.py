"""Dry-run machinery validated in-process on small meshes via subprocesses
(the 512-device production sweep runs through repro.launch.dryrun itself):
  * collective-bytes HLO parsing
  * depth-1/2 unrolled cost extrapolation == truly-unrolled full-depth cost
  * elastic checkpoint restore onto a different device count
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"))


def run_py(code: str, devices: int = 8) -> str:
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", prog], env=ENV,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %all-reduce.5 = bf16[2048]{0} all-reduce(%a), replica_groups={{0,1}}
  %ag-start = (f32[128]{0}, f32[1024]{0}) all-gather-start(%b)
  %cp.1 = f32[64,4]{1,0} collective-permute(%c)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 2048 * 2
    assert got["all-gather"] == 128 * 4 + 1024 * 4
    assert got["collective-permute"] == 64 * 4 * 4
    assert got["total"] == sum(v for k, v in got.items()
                               if k not in ("total", "n_ops"))


@pytest.mark.slow
def test_cost_extrapolation_matches_unrolled():
    out = run_py("""
        import json, jax
        from repro.configs import get_config
        from repro.launch.dryrun import _compile_cell, _extract_cost, cost_probe
        cfg = get_config('tinyllama-1.1b').smoke().replace(
            n_periods=5, remat='none')
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        # ground truth: fully unrolled at full depth, loop-free settings
        full = cfg.replace(attn_impl='ref', loss_chunk=0, scan_unroll=True)
        # build a tiny train cell directly
        from repro.launch.dryrun import build_cell
        compiled = _compile_cell(full, 'train_4k', mesh,
                                 {'config': {}})
        truth = _extract_cost(compiled)
        est, _ = cost_probe(cfg, 'train_4k', mesh, None)
        print(json.dumps({'truth': truth['flops'], 'est': est['flops']}))
    """, devices=8)
    # the smoke train_4k shape is huge for a smoke config; patch: use a tiny
    # custom shape via SHAPES? -> simpler: compare ratio
    got = json.loads(out.strip().splitlines()[-1])
    rel = abs(got["est"] - got["truth"]) / got["truth"]
    # not bit-exact: XLA CSEs shared subcomputations (rope tables, iotas)
    # differently across unroll depths; a few percent is well within what
    # the roofline analysis needs
    assert rel < 0.06, got


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    out = run_py(f"""
        import json, numpy as np, jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.train.step import init_train_state
        from repro.ckpt import save, restore
        from repro.dist.partition import param_pspecs, shardings
        cfg = get_config('tinyllama-1.1b').smoke()
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        # save under a (2, 4) mesh placement
        mesh_a = jax.make_mesh((2, 4), ('data', 'model'))
        sh_a = shardings(param_pspecs(state.params), mesh_a)
        params_a = jax.device_put(state.params, sh_a)
        save(state, r'{tmp_path}', 3)
        # restore onto a DIFFERENT mesh shape (4, 2) — elastic path
        mesh_b = jax.make_mesh((4, 2), ('data', 'model'))
        sh_b = shardings(param_pspecs(state.params), mesh_b)
        like = jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
        restored, manifest = restore(like, r'{tmp_path}')
        ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(jax.tree.leaves(restored),
                                 jax.tree.leaves(state)))
        pb = jax.device_put(restored.params, sh_b)   # re-shard onto mesh B
        jax.block_until_ready(pb)
        print(json.dumps({{'ok': bool(ok), 'step': manifest['step']}}))
    """, devices=8)
    got = json.loads(out.strip().splitlines()[-1])
    assert got["ok"] and got["step"] == 3
