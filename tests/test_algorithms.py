"""Scheduler correctness: DMA / DMA-SRT / DMA-RT / G-DM / O(m)Alg all
produce feasible schedules (capacity + precedence + release + conservation)
and the analytical artifacts (gap instance, FSP reduction, Algorithm 5
duals, grouping) match the paper exactly."""
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core import (Coflow, Instance, Job, dma, dma_rt, dma_srt,
                        fsp_to_coflow_job, gap_bounds, gap_instance,
                        gap_optimal_schedule_length, gdm, group_jobs,
                        is_rooted_tree, job_order, om_alg, paper_workload,
                        verify_schedule)
from repro.core.dma_srt import path_subjobs, srt_start_times
from repro.core.gap_instance import gap_hand_schedule


def rand_instance(seed: int, m: int = 8, n_jobs: int = 4, rooted: bool = False,
                  releases: bool = False) -> Instance:
    rng = np.random.default_rng(seed)
    jobs = []
    for j in range(n_jobs):
        mu = int(rng.integers(1, 5))
        coflows = []
        for c in range(mu):
            d = rng.integers(0, 12, size=(m, m))
            d[rng.random((m, m)) < 0.6] = 0
            coflows.append(Coflow(j, c, d.astype(np.int64)))
        edges = []
        if rooted and mu > 1:
            for a in range(mu - 1):
                edges.append((a, int(rng.integers(a + 1, mu))))
        elif mu > 1:
            for a in range(mu):
                for b in range(a + 1, mu):
                    if rng.random() < 0.4:
                        edges.append((a, b))
        jobs.append(Job(j, coflows, edges,
                        weight=float(rng.uniform(0.1, 2.0)),
                        release=int(rng.integers(0, 30)) if releases else 0))
    return Instance(m, jobs)


@pytest.mark.parametrize("seed", range(4))
def test_dma_feasible(seed):
    inst = rand_instance(seed)
    sched = dma(inst.jobs, inst.m, rng=np.random.default_rng(seed),
                decompose=True)
    verify_schedule(inst, sched)


@pytest.mark.parametrize("seed", range(4))
def test_dma_rt_feasible(seed):
    inst = rand_instance(seed + 100, rooted=True)
    sched = dma_rt(inst.jobs, inst.m, rng=np.random.default_rng(seed),
                   decompose=True)
    verify_schedule(inst, sched)


def test_dma_srt_single_tree():
    inst = rand_instance(7, n_jobs=1, rooted=True)
    job = inst.jobs[0]
    if job.mu > 1:
        assert is_rooted_tree(job)
    sched = dma_srt(job, inst.m, rng=np.random.default_rng(0),
                    require_tree=job.mu > 1)
    verify_schedule(Instance(inst.m, [job]), sched)


def test_srt_start_times_respect_precedence():
    inst = rand_instance(11, n_jobs=1, rooted=True)
    job = inst.jobs[0]
    if job.mu < 2:
        pytest.skip("degenerate")
    starts = srt_start_times(job, 2.0, np.random.default_rng(0))
    sizes = [c.D for c in job.coflows]
    for a, b in job.edges:
        assert starts[b] >= starts[a] + sizes[a]


def test_path_subjobs_count():
    inst = rand_instance(13, n_jobs=1, rooted=True)
    job = inst.jobs[0]
    paths = path_subjobs(job)
    indeg = [0] * job.mu
    for _, b in job.edges:
        indeg[b] += 1
    assert len(paths) == sum(1 for i in indeg if i == 0)


@pytest.mark.parametrize("rooted", [False, True])
@pytest.mark.parametrize("releases", [False, True])
def test_gdm_feasible(rooted, releases):
    inst = rand_instance(3, rooted=rooted, releases=releases)
    sched = gdm(inst, rng=np.random.default_rng(0), rooted=rooted,
                decompose=True)
    verify_schedule(inst, sched)


def test_om_alg_feasible_and_sequential():
    inst = rand_instance(5, releases=True)
    sched = om_alg(inst, decompose=True)
    verify_schedule(inst, sched)
    assert (sched.parts[0].alphas <= 1).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_gdm_conservation(seed):
    inst = rand_instance(seed, m=6, n_jobs=3)
    sched = gdm(inst, rng=np.random.default_rng(seed), decompose=True)
    verify_schedule(inst, sched)


def test_ordering_dual_feasibility():
    inst = rand_instance(9, n_jobs=6, releases=True)
    res = job_order(inst)
    assert sorted(res.order) == sorted(j.jid for j in inst.jobs)
    # residual weights at removal are >= 0 up to float noise (dual feasible)
    assert all(v >= -1e-6 for v in res.residual.values())


def test_grouping_partitions_all_jobs():
    inst = rand_instance(17, n_jobs=6, releases=True)
    order = job_order(inst).order
    groups = group_jobs(inst, order)
    flat = [j for g in groups for j in g]
    assert sorted(flat) == sorted(j.jid for j in inst.jobs)


def test_gdm_beats_or_matches_baseline_in_aggregate():
    # the paper's headline: across instances G-DM(-RT) improves on O(m)Alg
    gains = []
    for seed in range(3):
        inst = paper_workload(m=20, mu_bar=4, seed=seed, scale=0.1)
        g = gdm(inst, rng=np.random.default_rng(seed))
        o = om_alg(inst)
        gains.append(1 - g.twct() / o.twct())
    assert np.mean(gains) > -0.25  # sanity bound; figures track the trend


# --- analytical artifacts --------------------------------------------------

def test_gap_instance_lemma2():
    for K in (2, 3):
        inst = gap_instance(K, d=2)
        delta, T = gap_bounds(inst)
        assert delta == T == 2 * K * 2
        assert gap_optimal_schedule_length(K, 2) == (2 * K + 1) * K * 2
        # the hand schedule is feasible: precedence + one coflow per port set
        rounds = gap_hand_schedule(K, d=2)
        job = inst.jobs[0]
        parents = {c: set() for c in range(job.mu)}
        for a, b in job.edges:
            parents[b].add(a)
        done = set()
        for t, ids in rounds:
            for c in ids:
                assert parents[c] <= done, f"round at {t} violates precedence"
            # simultaneous coflows must not share a port side
            senders = [np.nonzero(job.coflows[c].demand)[0][0] for c in ids]
            receivers = [np.nonzero(job.coflows[c].demand)[1][0] for c in ids]
            assert len(set(senders)) == len(senders)
            assert len(set(receivers)) == len(receivers)
            done |= set(ids)
        assert done == set(range(job.mu))
        # hand-schedule makespan matches the paper's (2K+1)Kd
        assert rounds[-1][0] + 2 == gap_optimal_schedule_length(K, 2)


def test_fsp_reduction_structure():
    p = np.array([[3, 1], [2, 4], [5, 2]])  # 3 machines x 2 jobs
    inst = fsp_to_coflow_job(p)
    job = inst.jobs[0]
    assert job.mu == 3 * 2 + 1
    assert is_rooted_tree(job)
    # scheduling it is feasible
    sched = dma_srt(job, inst.m, rng=np.random.default_rng(0))
    verify_schedule(inst, sched)
