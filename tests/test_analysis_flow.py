"""Dataflow layer tests: the fixture corpus (known positives the PR-8
syntactic rules cannot see, known negatives the engine must prove), the
interval/symbolic engine primitives, the baseline ratchet, the SARIF
emitter, and the self-check that the real tree is strict-clean."""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.analysis import Finding, Report, scan_paths
from repro.analysis import baseline as baseline_mod
from repro.analysis.__main__ import main
from repro.analysis.flow.intervals import IV, s_add, s_atom, s_const, s_mul
from repro.analysis.sarif import to_sarif

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "analysis_fixtures" / "proj"

DATAFLOW_RULES = ["overflow-range", "tracer-taint", "cache-key"]
SYNTACTIC_RULES = ["rng-discipline", "backend-dispatch", "overflow-guard",
                   "jit-purity", "frozen-core-types", "pragma-discipline"]


@pytest.fixture(scope="module")
def fixture_report():
    return scan_paths([FIXTURES / "src"], root=FIXTURES,
                      rules=DATAFLOW_RULES)


def _by_rule(report, rule):
    return [f for f in report.unsuppressed if f.rule == rule]


# --------------------------------------------------------------------------
# fixture corpus: positives caught, negatives proven
# --------------------------------------------------------------------------

def test_overflow_range_positive(fixture_report):
    hits = _by_rule(fixture_report, "overflow-range")
    assert len(hits) == 1
    f = hits[0]
    assert f.path == "src/repro/kernels/badk/ops.py"
    assert "operand 1 of badk_padded()" in f.message
    # the message names the unproven symbolic count, not just a location
    assert "x.shape[0]" in f.message


def test_overflow_range_negative(fixture_report):
    assert not [f for f in _by_rule(fixture_report, "overflow-range")
                if "goodk" in f.path]


def test_tracer_taint_positive_is_interprocedural(fixture_report):
    hits = _by_rule(fixture_report, "tracer-taint")
    assert len(hits) == 1
    f = hits[0]
    # flagged in the helper module the syntactic rule never inspects,
    # attributed back to the jit boundary it was reached from
    assert f.path == "src/repro/core/helper.py"
    assert "if" in f.message and "staged into jax.jit" in f.message


def test_tracer_taint_negative(fixture_report):
    # the staged body itself is clean: shape branch + static-arg loop
    assert not [f for f in _by_rule(fixture_report, "tracer-taint")
                if f.path.endswith("staged.py")]


def test_cache_key_param_positive(fixture_report):
    msgs = [f.message for f in _by_rule(fixture_report, "cache-key")]
    assert any("cached_plan()" in m and "'scale'" in m for m in msgs)


def test_cache_key_knob_positive(fixture_report):
    msgs = [f.message for f in _by_rule(fixture_report, "cache-key")]
    assert any("cached_env()" in m and "REPRO_FAKE_MODE" in m for m in msgs)


def test_cache_key_negative(fixture_report):
    assert not [f for f in _by_rule(fixture_report, "cache-key")
                if "cached_sound" in f.message]


def test_cache_key_grouping_gamma_positive(fixture_report):
    """The PR-10 bug class: a grouping cache keyed on membership only
    serves groups computed under a stale (since-rescaled) gamma."""
    msgs = [f.message for f in _by_rule(fixture_report, "cache-key")]
    assert any("cached_groups()" in m and "'gamma'" in m for m in msgs)


def test_cache_key_grouping_gamma_negative(fixture_report):
    assert not [f for f in _by_rule(fixture_report, "cache-key")
                if "cached_groups_sound" in f.message]


def test_positives_invisible_to_syntactic_rules():
    """The corpus' whole point: every dataflow positive passes PR-8."""
    rep = scan_paths([FIXTURES / "src"], root=FIXTURES,
                     rules=SYNTACTIC_RULES)
    assert rep.unsuppressed == []


# --------------------------------------------------------------------------
# engine primitives
# --------------------------------------------------------------------------

def test_interval_arithmetic():
    a, b = IV(2, 3), IV(-1, 4)
    assert a.add(b) == IV(1, 7)
    assert a.mul(b) == IV(-3, 12)
    assert a.join(b) == IV(-1, 4)
    assert a.meet(b) == IV(2, 3)


def test_canonical_sym_cancellation():
    x = s_atom("param:x")
    # (x + 1) - x canonicalizes to the constant 1
    assert s_add(s_add(x, s_const(1)), s_mul(s_const(-1), x)) == s_const(1)


def test_canonical_sym_commutes():
    x, y = s_atom("param:x"), s_atom("param:y")
    assert s_mul(x, y) == s_mul(y, x)
    assert s_add(x, y) == s_add(y, x)


# --------------------------------------------------------------------------
# baseline ratchet
# --------------------------------------------------------------------------

def _report(findings):
    return Report(findings=findings, n_files=1)


def test_baseline_diff_new_and_stale():
    f = Finding("r", "src/a.py", 3, "boom")
    d = baseline_mod.diff(_report([f]), [])
    assert [x.message for x in d.new] == ["boom"] and not d.stale
    entry = {"rule": "r", "path": "src/a.py", "message": "boom"}
    d = baseline_mod.diff(_report([f]), [entry])
    assert d.ok()
    d = baseline_mod.diff(_report([]), [entry])
    assert not d.new and d.stale == [entry]


def test_baseline_is_line_insensitive_but_multiset_aware():
    entry = {"rule": "r", "path": "src/a.py", "message": "boom"}
    moved = Finding("r", "src/a.py", 99, "boom")
    assert baseline_mod.diff(_report([moved]), [entry]).ok()
    # a second identical finding is NOT absorbed by a single entry
    d = baseline_mod.diff(_report([moved, Finding("r", "src/a.py", 7,
                                                  "boom")]), [entry])
    assert len(d.new) == 1


def test_baseline_roundtrip_and_cli_update(tmp_path, capsys):
    bl = tmp_path / "bl.json"
    bl.write_text(json.dumps({"version": 1, "findings": [
        {"rule": "ghost", "path": "src/x.py", "message": "gone"}]}))
    # stale entry fails strict even with zero findings
    assert main(["--strict", "--baseline", str(bl),
                 str(FIXTURES / "src" / "repro" / "kernels" / "goodk"),
                 "--root", str(FIXTURES)]) == 1
    assert "stale" in capsys.readouterr().out
    # --update-baseline rewrites it and strict passes again
    assert main(["--update-baseline", "--baseline", str(bl),
                 str(FIXTURES / "src" / "repro" / "kernels" / "goodk"),
                 "--root", str(FIXTURES)]) == 0
    capsys.readouterr()
    assert baseline_mod.load(bl) == []
    assert main(["--strict", "--baseline", str(bl),
                 str(FIXTURES / "src" / "repro" / "kernels" / "goodk"),
                 "--root", str(FIXTURES)]) == 0


def test_baseline_rejects_malformed(tmp_path):
    bl = tmp_path / "bl.json"
    bl.write_text("[]")
    with pytest.raises(ValueError):
        baseline_mod.load(bl)
    bl.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError):
        baseline_mod.load(bl)


# --------------------------------------------------------------------------
# SARIF + GitHub annotations
# --------------------------------------------------------------------------

def test_sarif_structure():
    f = Finding("overflow-range", "src/a.py", 12, "too big", hint="guard it")
    sup = Finding("cache-key", "src/b.py", 3, "knob", suppressed=True)
    log = to_sarif(_report([f, sup]), {"overflow-range": "doc",
                                       "cache-key": "doc2"})
    assert log["version"] == "2.1.0"
    run = log["runs"][0]
    ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert "overflow-range" in ids and "cache-key" in ids
    res = {r["ruleId"]: r for r in run["results"]}
    loc = res["overflow-range"]["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"] == "src/a.py"
    assert loc["region"]["startLine"] == 12
    assert "fix: guard it" in res["overflow-range"]["message"]["text"]
    assert res["cache-key"]["suppressions"][0]["kind"] == "inSource"


def test_cli_sarif_and_github(tmp_path, capsys):
    out = tmp_path / "log.sarif"
    assert main(["--sarif", str(out), "--github",
                 str(FIXTURES / "src"), "--root", str(FIXTURES),
                 "--baseline", str(tmp_path / "none.json")]) == 0
    log = json.loads(out.read_text())
    results = log["runs"][0]["results"]
    assert {r["ruleId"] for r in results} >= {"overflow-range",
                                              "tracer-taint", "cache-key"}
    text = capsys.readouterr().out
    assert "::error file=src/repro/kernels/badk/ops.py" in text
    assert "title=repro-analysis overflow-range" in text


def test_cli_new_rules_listed(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("overflow-range", "tracer-taint", "cache-key"):
        assert rule in out


# --------------------------------------------------------------------------
# self-check: the real tree is clean at --strict
# --------------------------------------------------------------------------

def test_repo_is_strict_clean():
    assert main(["--strict", str(REPO / "src"), str(REPO / "benchmarks"),
                 "--root", str(REPO)]) == 0
