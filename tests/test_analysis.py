"""Static-analysis subsystem tests: per-rule failing + passing fixtures
(the CLI must flag the former and stay quiet on the latter), pragma
semantics, inspect-based registry drift, and the self-scan contract that
the repo's own tree is clean under --strict."""
from __future__ import annotations

import textwrap
from pathlib import Path

import pytest

from repro.analysis import scan_paths
from repro.analysis.__main__ import main

REPO = Path(__file__).resolve().parents[1]


def write(tmp_path: Path, rel: str, text: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(text))
    return p


def rule_findings(tmp_path: Path, rule: str):
    rep = scan_paths([tmp_path], root=tmp_path, project=False)
    return [f for f in rep.findings if f.rule == rule and not f.suppressed]


def assert_cli_flags(tmp_path: Path, rule: str, capsys) -> None:
    """The CLI itself (not just the library) must flag the fixture."""
    rc = main([str(tmp_path), "--root", str(tmp_path), "--strict"])
    out = capsys.readouterr().out
    assert rc == 1
    assert rule in out


# ---------------------------------------------------------------------------
# rng-discipline
# ---------------------------------------------------------------------------

def test_rng_discipline_failing_fixture(tmp_path, capsys):
    write(tmp_path, "src/repro/core/rngbad.py", """
        import numpy as np
        import random

        np.random.seed(0)
        x = np.random.rand(3)
        rng = np.random.default_rng()
        y = random.choice([1, 2])
    """)
    found = rule_findings(tmp_path, "rng-discipline")
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 4
    assert "np.random.seed" in msgs and "rand" in msgs
    assert "without a seed" in msgs and "stdlib" in msgs
    assert_cli_flags(tmp_path, "rng-discipline", capsys)


def test_rng_discipline_passing_fixture(tmp_path):
    write(tmp_path, "src/repro/core/rngok.py", """
        import numpy as np

        def build(seed=0, rng=None):
            rng = np.random.default_rng(seed) if rng is None else rng
            random = object()   # local name shadows nothing imported
            return rng.normal(), random
    """)
    # tests/ and repro/testing/ are exempt even with global draws
    write(tmp_path, "tests/test_x.py", """
        import numpy as np
        np.random.seed(0)
    """)
    assert rule_findings(tmp_path, "rng-discipline") == []


# ---------------------------------------------------------------------------
# backend-dispatch
# ---------------------------------------------------------------------------

def test_backend_dispatch_failing_fixture(tmp_path, capsys):
    write(tmp_path, "src/repro/serve/bad.py", """
        from repro.kernels.bna_step.ops import bna_step_batch
        import repro.kernels.coflow_merge
    """)
    found = rule_findings(tmp_path, "backend-dispatch")
    assert len(found) == 2
    assert_cli_flags(tmp_path, "backend-dispatch", capsys)


def test_backend_dispatch_passing_fixture(tmp_path):
    src = "from repro.kernels.bna_step.ops import bna_step_batch\n"
    # the four sanctioned homes for direct kernel imports
    write(tmp_path, "src/repro/core/backend.py", src)
    write(tmp_path, "src/repro/core/pipeline.py", src)
    write(tmp_path, "src/repro/kernels/other/ops.py", src)
    write(tmp_path, "tests/test_k.py", src)
    write(tmp_path, "benchmarks/kbench.py", src)
    assert rule_findings(tmp_path, "backend-dispatch") == []


# ---------------------------------------------------------------------------
# overflow-guard
# ---------------------------------------------------------------------------

def test_overflow_guard_failing_fixture(tmp_path, capsys):
    write(tmp_path, "src/repro/kernels/fake/ops.py", """
        def fake_kernel(x):
            return x + 1
    """)
    found = rule_findings(tmp_path, "overflow-guard")
    assert len(found) == 1 and "no int32 overflow guard" in found[0].message
    assert_cli_flags(tmp_path, "overflow-guard", capsys)


def test_overflow_guard_needs_escape(tmp_path):
    # sentinel + guard branch, but neither a ref fallback nor a raise
    write(tmp_path, "src/repro/kernels/fake/ops.py", """
        import numpy as np
        _I32_MAX = int(np.iinfo(np.int32).max)

        def fake_kernel(x, n):
            if n >= _I32_MAX:
                n = 0
            return x
    """)
    found = rule_findings(tmp_path, "overflow-guard")
    assert len(found) == 1 and "no escape" in found[0].message


def test_overflow_guard_passing_fixtures(tmp_path):
    # the bna_step shape: guard + raise
    write(tmp_path, "src/repro/kernels/fake/ops.py", """
        import numpy as np
        _I32_MAX = int(np.iinfo(np.int32).max)

        def fake_kernel(x, n):
            if n >= _I32_MAX:
                raise ValueError("too large for int32 kernel")
            return x
    """)
    # the merge_fix shape: guard + ref fallback import
    write(tmp_path, "src/repro/kernels/fake2/ops.py", """
        import numpy as np
        from .ref import fake_ref
        _INT32_MAX = np.int64(2**31 - 1)

        def fake_kernel(x, n):
            if n >= _INT32_MAX:
                return fake_ref(x)
            return x
    """)
    # non-ops kernel files are out of scope
    write(tmp_path, "src/repro/kernels/fake/helpers.py", "def h(x): return x\n")
    assert rule_findings(tmp_path, "overflow-guard") == []


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------

def test_jit_purity_failing_fixture(tmp_path, capsys):
    write(tmp_path, "src/repro/core/jitbad.py", """
        import jax
        import numpy as np
        from jax import lax

        state = {}

        def body(c):
            print(c)
            x = np.cumsum(c)
            state["last"] = x
            if c:
                x = x + 1
            return x

        stepped = jax.jit(body)
        looped = lax.while_loop(lambda c: np.any(c), body, 0)
    """)
    found = rule_findings(tmp_path, "jit-purity")
    msgs = " | ".join(f.message for f in found)
    assert "print" in msgs
    assert "numpy" in msgs
    assert "closed-over" in msgs
    assert "truthiness" in msgs
    assert_cli_flags(tmp_path, "jit-purity", capsys)


def test_jit_purity_passing_fixture(tmp_path):
    write(tmp_path, "src/repro/core/jitok.py", """
        import jax
        import jax.numpy as jnp
        import numpy as np

        _CAP = int(np.iinfo(np.int32).max)   # trace-time constant: fine

        def body(c):
            d = dict(c)          # local mutation is fine
            d["x"] = jnp.sum(c["x"])
            return d

        stepped = jax.jit(body)

        def host(c):
            print(c)             # not staged into any jit entry
            return np.sum(c)
    """)
    assert rule_findings(tmp_path, "jit-purity") == []


# ---------------------------------------------------------------------------
# frozen-core-types
# ---------------------------------------------------------------------------

def test_frozen_core_types_failing_fixture(tmp_path, capsys):
    write(tmp_path, "src/repro/dist/bad.py", """
        from repro.core.types import Instance
        from repro.core.timeline import FinalSchedule

        def tweak(inst: Instance, events, m):
            inst.jobs = []
            sched = FinalSchedule(m, 0.0, events, None, None)
            sched.ledger.append((0, 1.0))
            return sched
    """)
    found = rule_findings(tmp_path, "frozen-core-types")
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 2
    assert "Instance" in msgs and "FinalSchedule" in msgs
    assert_cli_flags(tmp_path, "frozen-core-types", capsys)


def test_frozen_core_types_passing_fixture(tmp_path):
    # defining modules own in-place construction; untracked vars are free
    write(tmp_path, "src/repro/core/timeline.py", """
        class FinalSchedule:
            pass

        def build(m):
            sched = FinalSchedule()
            sched.ledger = []
            sched.ledger.append((0, 1.0))
            return sched
    """)
    write(tmp_path, "src/repro/dist/ok.py", """
        from repro.core.types import Instance
        import dataclasses

        def reweight(inst: Instance, w):
            alphas = inst.alphas          # reads are fine
            other = {}
            other["x"] = 1                # untracked mutation is fine
            return dataclasses.replace(inst, weights=w)
    """)
    assert rule_findings(tmp_path, "frozen-core-types") == []


# ---------------------------------------------------------------------------
# pragma-discipline + suppression semantics
# ---------------------------------------------------------------------------

def test_pragma_suppresses_with_justification(tmp_path):
    write(tmp_path, "src/repro/serve/ok.py", """
        # repro: allow(backend-dispatch): fixture exercising the resolved dispatch site exemption
        from repro.kernels.bna_step.ops import bna_step_batch

        from repro.kernels.coflow_merge.ops import edge_interval_alphas  # repro: allow(backend-dispatch): same-line pragma fixture justification
    """)
    rep = scan_paths([tmp_path], root=tmp_path, project=False)
    assert [f.rule for f in rep.unsuppressed] == []
    assert len([f for f in rep.suppressed
                if f.rule == "backend-dispatch"]) == 2
    assert main([str(tmp_path), "--root", str(tmp_path), "--strict"]) == 0


def test_pragma_without_justification_suppresses_nothing(tmp_path, capsys):
    write(tmp_path, "src/repro/serve/bad.py", """
        # repro: allow(backend-dispatch)
        from repro.kernels.bna_step.ops import bna_step_batch
    """)
    rep = scan_paths([tmp_path], root=tmp_path, project=False)
    rules = {f.rule for f in rep.unsuppressed}
    # the original finding survives AND the bare pragma is itself flagged
    assert rules == {"backend-dispatch", "pragma-discipline"}
    assert_cli_flags(tmp_path, "pragma-discipline", capsys)


def test_pragma_unknown_rule_flagged(tmp_path):
    write(tmp_path, "src/repro/core/x.py", """
        x = 1  # repro: allow(not-a-rule): justification long enough here
    """)
    found = rule_findings(tmp_path, "pragma-discipline")
    assert len(found) == 1 and "unknown rule" in found[0].message


# ---------------------------------------------------------------------------
# registry-consistency (live-registry drift, injected and cleaned up)
# ---------------------------------------------------------------------------

def _scan_registry_rule(tmp_path):
    write(tmp_path, "placeholder.py", "x = 1\n")
    return scan_paths([tmp_path], root=tmp_path,
                      rules=["registry-consistency"], project=True)


def test_registry_consistency_clean_on_real_registries(tmp_path):
    rep = _scan_registry_rule(tmp_path)
    assert [f.message for f in rep.unsuppressed] == []


def test_registry_consistency_flags_scheduler_drift(tmp_path):
    from repro.core import engine

    def _base(instance, *, decompose=False):
        return None

    @engine.register_scheduler("zz_drift_fixture", "drift fixture",
                               options=("decompose", "seed", "exec"))
    def _drift(instance, *, exec="packet", **opts):
        return _base(instance, **opts)

    try:
        rep = _scan_registry_rule(tmp_path)
        hits = [f for f in rep.unsuppressed
                if "zz_drift_fixture" in f.message]
        # `seed` is declared but nothing in the chain accepts it
        assert len(hits) == 1 and "'seed'" in hits[0].message
        assert hits[0].path.endswith("tests/test_analysis.py")
    finally:
        del engine._REGISTRY["zz_drift_fixture"]


def test_registry_consistency_flags_scenario_drift(tmp_path):
    from repro.scenarios import registry as sreg

    @sreg.register("zz_scen_fixture", "drift fixture")
    def _scen(*, m=None, seed=0):   # violates the m/seed/scale convention
        raise AssertionError("never built")

    try:
        rep = _scan_registry_rule(tmp_path)
        hits = [f for f in rep.unsuppressed
                if "zz_scen_fixture" in f.message]
        assert any("'scale'" in f.message for f in hits)
    finally:
        del sreg._REGISTRY["zz_scen_fixture"]


# ---------------------------------------------------------------------------
# CLI surface + self-scan
# ---------------------------------------------------------------------------

def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("rng-discipline", "backend-dispatch", "overflow-guard",
                 "jit-purity", "frozen-core-types", "registry-consistency",
                 "pragma-discipline"):
        assert rule in out


def test_cli_non_strict_exits_zero_on_findings(tmp_path, capsys):
    write(tmp_path, "src/repro/serve/bad.py",
          "from repro.kernels.bna_step.ops import x\n")
    assert main([str(tmp_path), "--root", str(tmp_path)]) == 0
    assert "backend-dispatch" in capsys.readouterr().out


def test_self_scan_repo_is_clean_under_strict(capsys):
    rc = main([str(REPO / "src"), str(REPO / "benchmarks"),
               "--root", str(REPO), "--strict"])
    out = capsys.readouterr().out
    assert rc == 0, f"repo not clean under --strict:\n{out}"
    assert "0 finding(s)" in out
