"""BNA (Algorithm 1) unit + property tests: optimality (length == effective
size), matching validity, demand conservation — on adversarial and random
demand matrices."""
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core import bna, effective_size
from repro.core.bna import schedule_total_time, verify_bna_schedule


def test_empty():
    assert bna(np.zeros((4, 4), dtype=np.int64)) == []


def test_single_flow():
    d = np.zeros((3, 3), dtype=np.int64)
    d[0, 2] = 7
    pieces = bna(d, validate=True)
    assert schedule_total_time(pieces) == 7


def test_permutation_matrix():
    d = np.eye(5, dtype=np.int64) * 13
    pieces = bna(d, validate=True)
    assert schedule_total_time(pieces) == 13
    assert len(pieces) == 1  # one matching suffices


def test_dense_uniform():
    m = 6
    d = np.full((m, m), 3, dtype=np.int64)
    pieces = bna(d, validate=True)
    assert schedule_total_time(pieces) == effective_size(d) == 3 * m


def test_skewed_row():
    d = np.zeros((4, 4), dtype=np.int64)
    d[0] = [10, 20, 30, 40]   # one hot sender
    d[2, 0] = 5
    pieces = bna(d, validate=True)
    assert schedule_total_time(pieces) == 100


@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(2, 9),
    seed=st.integers(0, 10_000),
    density=st.floats(0.1, 1.0),
    hi=st.integers(1, 50),
)
def test_property_random(m, seed, density, hi):
    rng = np.random.default_rng(seed)
    d = rng.integers(0, hi + 1, size=(m, m))
    d[rng.random((m, m)) > density] = 0
    pieces = bna(d.astype(np.int64))
    verify_bna_schedule(d.astype(np.int64), pieces)  # matching+conservation
    assert schedule_total_time(pieces) == effective_size(d)  # optimality


def test_diagonal_conflict():
    # all senders target the same receiver: serialization forced
    m = 5
    d = np.zeros((m, m), dtype=np.int64)
    d[:, 0] = 4
    pieces = bna(d, validate=True)
    assert schedule_total_time(pieces) == 4 * m


def test_rejects_negative():
    with pytest.raises(ValueError):
        bna(np.array([[-1, 0], [0, 0]]))
