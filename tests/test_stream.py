"""Sustained-arrivals stream driver (core/stream.py).

Pins: arrival-process determinism and MMPP burstiness; stream-vs-batch
bit-identity across the session-native scheduler matrix; kill-the-driver
mid-stream determinism (snapshot/restore at arbitrary arrival events);
backpressure deferral/reject accounting; and a repair-hit-rate floor on
the CI-sized fixed-seed trace.
"""
import numpy as np
import pytest

from repro.core import (AdmissionPolicy, Instance, SchedulerSession,
                        arrival_times, run_stream, simulate_online,
                        stream_jobs)
from repro.core.stream import StreamDriver

M = 8

MATRIX = [
    ("om_alg", {}),
    ("gdm", {"delays": "spread", "seed": 0}),
    ("gdm_rt", {"delays": "spread", "seed": 0}),
]


def _trace(n=30, seed=3, process="poisson", load=0.9):
    return stream_jobs(M, n, seed, process=process, load=load, mu=2)


# --- arrival processes ------------------------------------------------------

def test_arrival_times_deterministic_and_sorted():
    for process in ("poisson", "mmpp"):
        a = arrival_times(200, 0.05, seed=9, process=process)
        b = arrival_times(200, 0.05, seed=9, process=process)
        assert a.dtype == np.int64
        assert np.array_equal(a, b)
        assert (np.diff(a) >= 0).all()
        assert not np.array_equal(a, arrival_times(200, 0.05, seed=10,
                                                   process=process))


def test_mmpp_matches_mean_rate_but_is_burstier():
    rate, n = 0.1, 4000
    poi = arrival_times(n, rate, seed=1, process="poisson")
    mmpp = arrival_times(n, rate, seed=1, process="mmpp", burst=16.0,
                         p_enter_burst=0.05, p_exit_burst=0.05)
    # same long-run rate (horizon within 20%)
    assert poi[-1] == pytest.approx(n / rate, rel=0.2)
    assert mmpp[-1] == pytest.approx(n / rate, rel=0.2)
    # burstier: inter-arrival coefficient of variation well above Poisson's 1
    cv = lambda t: np.diff(t).std() / max(np.diff(t).mean(), 1e-9)
    assert cv(mmpp) > cv(poi) * 1.2


def test_arrival_times_validation():
    with pytest.raises(ValueError, match="rate"):
        arrival_times(10, 0.0)
    with pytest.raises(ValueError, match="process"):
        arrival_times(10, 1.0, process="weibull")
    with pytest.raises(ValueError, match="burst"):
        arrival_times(10, 1.0, process="mmpp", burst=1.0)


def test_stream_jobs_deterministic_and_calibrated():
    jobs = _trace(n=20, seed=5)
    again = _trace(n=20, seed=5)
    assert [j.release for j in jobs] == [j.release for j in again]
    assert all(
        np.array_equal(c.demand, c2.demand)
        for j, j2 in zip(jobs, again)
        for c, c2 in zip(j.coflows, j2.coflows))
    # load calibration: horizon ~ max_port_work / load
    total = np.zeros((M, M), dtype=np.int64)
    for j in jobs:
        for c in j.coflows:
            total += c.demand
    bottleneck = max(total.sum(axis=1).max(), total.sum(axis=0).max())
    horizon = max(j.release for j in jobs)
    assert horizon == pytest.approx(bottleneck / 0.9, rel=0.5)


# --- stream vs batch bit-identity ------------------------------------------

@pytest.mark.parametrize("sched,opts", MATRIX)
@pytest.mark.parametrize("process", ["poisson", "mmpp"])
def test_stream_identical_to_batch_driver(sched, opts, process):
    jobs = _trace(process=process)
    res = run_stream(jobs, M, sched, **opts)
    batch = simulate_online(Instance(M, list(jobs)), sched, driver="batch",
                            **opts)
    assert res.online.job_completions == batch.job_completions
    assert res.online.twct() == batch.twct()
    assert res.offered == res.admitted == len(jobs)
    assert res.deferred == 0 and res.rejected == ()
    assert res.latencies_s.shape == (len(jobs),)
    assert res.p50_ms <= res.p95_ms <= res.p99_ms
    assert res.jobs_per_sec > 0


# --- kill-the-driver mid-stream --------------------------------------------

@pytest.mark.parametrize("sched,opts", MATRIX)
@pytest.mark.parametrize("kill_at", [1, 7, 19])
def test_kill_and_resume_mid_stream_is_bit_identical(sched, opts, kill_at):
    """A stream killed at an arbitrary arrival event and resumed from
    snapshot() state completes bit-identically to the uninterrupted run."""
    jobs = _trace()
    ref = run_stream(jobs, M, sched, **opts)

    drv = StreamDriver(M, sched, **opts)
    for j in jobs[:kill_at]:
        drv.feed(j)
    snap = drv.session.snapshot()          # ... the driver dies here ...

    resumed = SchedulerSession.restore(snap, jobs[:kill_at], sched, **opts)
    for j in jobs[kill_at:]:
        resumed.submit(j)
    resumed.advance()
    out = resumed.result()

    assert out.job_completions == ref.online.job_completions
    assert out.twct() == ref.online.twct()


def test_restore_missing_job_raises():
    jobs = _trace(n=5)
    drv = StreamDriver(M, "om_alg")
    for j in jobs:
        drv.feed(j)
    snap = drv.session.snapshot()
    with pytest.raises(ValueError, match="missing jids"):
        SchedulerSession.restore(snap, jobs[:-1], "om_alg")


# --- backpressure -----------------------------------------------------------

def _overload_run(policy):
    jobs = stream_jobs(M, 60, 5, process="mmpp", load=2.5, mu=2)
    drv = StreamDriver(M, "gdm", admission=policy, delays="spread", seed=0)
    outcomes = [drv.feed(j) for j in jobs]
    res = drv.result()
    return outcomes, res


def test_backpressure_defers_and_rejects_under_overload():
    policy = AdmissionPolicy(max_pending=4, replan_budget=0.3, window=8)
    outcomes, res = _overload_run(policy)
    assert "deferred" in outcomes and "rejected" in outcomes
    s = res.online.stats["session"]
    assert s["admission_deferred"] == res.deferred > 0
    assert s["admission_rejects"] == len(res.rejected) > 0
    assert res.admitted == res.offered - len(res.rejected)
    assert 0.0 <= s["replan_debt"] <= 1.0
    # rejected jobs never enter the session
    assert set(res.rejected).isdisjoint(res.online.job_completions)
    # every admitted job still drains
    assert len(res.online.job_completions) == res.admitted


def test_no_policy_means_no_backpressure():
    outcomes, res = _overload_run(None)
    assert set(outcomes) == {"submitted"}
    assert res.deferred == 0 and res.rejected == ()


def test_deferral_improves_repair_hit_rate_under_overload():
    """Deferring arrivals to planned-completion boundaries lands them as
    clean frontier appends — the policy's raison d'etre."""
    policy = AdmissionPolicy(max_pending=32, replan_budget=0.3, window=8)
    _, pure = _overload_run(None)
    _, held = _overload_run(policy)
    assert held.online.stats["session"]["repair_hit_rate"] > \
        pure.online.stats["session"]["repair_hit_rate"]


# --- repair hit-rate floor (the certification-bugfix payoff) ----------------

@pytest.mark.parametrize("sched", ["gdm", "gdm_rt"])
def test_spread_repair_hit_rate_floor_on_stream(sched):
    """Fixed-seed CI floor: grouped certification must repair some of the
    sustained-arrivals replans where the legacy gate repaired none."""
    jobs = stream_jobs(M, 60, 7, process="poisson", load=1.1, mu=2)
    res = run_stream(jobs, M, sched, delays="spread", seed=0)
    legacy = run_stream(jobs, M, sched, repair="legacy", delays="spread",
                        seed=0)
    s, sl = res.online.stats["session"], legacy.online.stats["session"]
    assert s["repair_hit_rate"] > 0.02
    assert s["groups_reused"] > 0
    assert s["repair_hit_rate"] > sl["repair_hit_rate"]
    # legacy stays results-identical (it is a restriction of the same
    # certified path), just with fewer repairs
    assert legacy.online.job_completions == res.online.job_completions
