"""Scenario registry + workload zoo: the scenario x scheduler cross-product
invariant harness.

Every registered scenario is run against every registered scheduler and held
to the repo's core invariants:

  * precedence-feasibility  — transcripts respect Starts-After edges,
    releases, and demand conservation;
  * capacity-feasibility    — packet-level (decompose=True) for the plain
    schedulers; exact transcript-level for the backfilled ones;
  * simulator-replay        — the scheduler's reported completion times
    match an independent replay of its transcript;
  * backfill-never-worse    — the packet-level executor (exec="packet",
    the default) re-executes the plan's timed-matching decomposition, so
    backfilling is POINTWISE no worse than the planned TWCT on every
    scenario x scheduler cell — the paper's premise, restored; the ledger
    executor (exec="ledger") keeps only fill-monotonicity vs its
    null-backfill comparator (see backfill.py for why ledger window-ends
    are not pointwise comparable);
  * fixed-seed determinism  — generators and schedulers are bit-stable
    under a fixed seed;
  * online == offline       — the §VII-C.2 online protocol reproduces the
    offline schedule when every release is 0.

Plus: metadata-bound property tests (via the hypothesis shim), golden TWCT
regressions per scheduler (refresh with REPRO_UPDATE_GOLDENS=1), and the
seed-determinism satellite for the trace primitives.
"""
import functools
import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro import scenarios
from repro.core import (available_schedulers, backfill, build_jobs,
                        fb_like_coflows, make_scheduler, paper_workload, plan,
                        poisson_releases, simulate_online, theta0, twct,
                        verify_schedule, verify_transcript)
from repro.testing.hypothesis_compat import given, settings, strategies as st

SCHEDULERS = sorted(available_schedulers())
GOLDEN_PATH = Path(__file__).parent / "goldens" / "scenario_goldens.json"

# tiny per-scenario sizes: the full 9 x 6 matrix must stay CI-cheap
TINY = {
    "fb_like": dict(m=6, scale=0.03),
    "fb_like_rt": dict(m=6, scale=0.03),
    "alibaba_sparse": dict(m=6, scale=0.15),
    "incast": dict(m=6, scale=0.1),
    "shuffle_heavy": dict(m=6, scale=0.2),
    "wide_shallow": dict(m=6, scale=0.2),
    "deep_chain": dict(m=6, scale=0.25),
    "online_poisson": dict(m=6, scale=0.03),
    "dist_collectives": dict(m=8, scale=0.5),
}
# mid sizes for the slow full matrix
MID = {
    "fb_like": dict(m=14, scale=0.06),
    "fb_like_rt": dict(m=14, scale=0.06),
    "alibaba_sparse": dict(m=14, scale=0.3),
    "incast": dict(m=14, scale=0.25),
    "shuffle_heavy": dict(m=12, scale=0.35),
    "wide_shallow": dict(m=14, scale=0.3),
    "deep_chain": dict(m=12, scale=0.4),
    "online_poisson": dict(m=14, scale=0.06),
    "dist_collectives": dict(m=12, scale=1.0),
}


@functools.lru_cache(maxsize=None)
def tiny(name: str) -> scenarios.BuiltScenario:
    return scenarios.build(name, seed=0, **TINY[name])


def _opts(name: str, sched: str) -> dict:
    return scenarios.scheduler_opts(sched, tiny(name).meta)


@functools.lru_cache(maxsize=None)
def tiny_plan(name: str, sched: str, decompose: bool = False):
    opts = _opts(name, sched)
    if decompose:
        opts["decompose"] = True
    return plan(tiny(name).instance, sched, seed=0, **opts)


def _instances_equal(a, b) -> bool:
    if a.m != b.m or a.n != b.n:
        return False
    for ja, jb in zip(a.jobs, b.jobs):
        if (ja.jid, ja.edges, ja.weight, ja.release) != \
                (jb.jid, jb.edges, jb.weight, jb.release):
            return False
        if ja.mu != jb.mu:
            return False
        for ca, cb in zip(ja.coflows, jb.coflows):
            if ca.cid != cb.cid or not np.array_equal(ca.demand, cb.demand):
                return False
    return True


def _assert_invariants(built: scenarios.BuiltScenario, sched: str,
                       seed: int = 0) -> None:
    """The per-pair invariant bundle (shared by the tiny matrix and the
    slow mid-size matrix)."""
    inst = built.instance
    opts = scenarios.scheduler_opts(sched, built.meta)
    p = plan(inst, sched, seed=seed, **opts)

    # fixed-seed determinism (scheduler)
    q = plan(inst, sched, seed=seed, **opts)
    assert p.twct() == q.twct()
    assert p.job_completions() == q.job_completions()

    # simulator-replay agreement
    replay = p.transcript().job_completions()
    for jid, t in p.job_completions().items():
        assert replay[jid] == pytest.approx(t, abs=1e-6), \
            f"{sched}: job {jid} reported {t} but transcript replays {replay[jid]}"

    # precedence/conservation/release at the transcript level; backfilled
    # transcripts are additionally exactly capacity-feasible there and
    # their makespan must cover every completion (zero-demand markers too)
    verify_transcript(inst, p.transcript(),
                      check_capacity=sched.endswith("_bf"),
                      makespan=p.makespan if sched.endswith("_bf") else None)

    if not sched.endswith("_bf"):
        # packet-level capacity-feasibility (matchings, time-disjoint)
        pd = plan(inst, sched, seed=seed, decompose=True, **opts)
        verify_schedule(inst, pd.schedule)
        # backfill-never-worse, POINTWISE vs the planned TWCT (the packet
        # executor re-executes the plan's own decomposition, so step 1 is
        # never capacity-capped and filling can only help)
        planned = p.twct()
        filled = plan(inst, sched + "_bf", seed=seed, **opts).twct()
        assert filled <= planned * (1 + 1e-9) + 1e-9, \
            f"{sched}_bf (packet) twct {filled} > planned {planned}"
        # the ledger executor keeps its weaker guarantee: monotone in fill
        led = backfill(p.schedule, exec="ledger").twct()
        null = backfill(p.schedule, fill=False, exec="ledger").twct()
        assert led <= null * (1 + 1e-9) + 1e-9, \
            f"{sched}_bf (ledger) twct {led} > null-backfill {null}"

    # online == offline when all releases are 0
    inst0 = scenarios.strip_releases(inst)
    onl = simulate_online(inst0, make_scheduler(sched, seed=seed, **opts))
    off = p if built.meta.arrival == "offline" else \
        plan(inst0, sched, seed=seed, **opts)
    offline_twct = twct(off.transcript().job_completions(), inst0)
    assert onl.twct() == pytest.approx(offline_twct, abs=1e-6), \
        f"{sched}: online {onl.twct()} != offline {offline_twct}"


# --- registry API (mirrors the scheduler registry) ---------------------------

def test_registry_lists_required_scenarios():
    names = scenarios.names()
    assert len(names) >= 7
    assert {"fb_like", "alibaba_sparse", "incast", "shuffle_heavy",
            "wide_shallow", "deep_chain", "online_poisson"} <= set(names)
    assert set(scenarios.available()) == set(names)
    assert all(scenarios.available().values()), "scenario without a doc line"


def test_registry_get_and_unknown():
    s = scenarios.get("incast")
    assert s.name == "incast" and callable(s.builder)
    with pytest.raises(KeyError):
        scenarios.get("nope")


def test_registry_rejects_duplicates():
    with pytest.raises(ValueError):
        scenarios.register("fb_like")(lambda **kw: None)


def test_dist_collectives_honors_requested_port_count():
    assert scenarios.build("dist_collectives", m=8).instance.m == 8
    with pytest.raises(ValueError):
        scenarios.build("dist_collectives", m=9)


def test_verify_transcript_handles_zero_demand_child():
    """A zero-demand coflow with an incoming Starts-After edge only carries
    an instantaneous marker entry in the transcript; precedence checking
    must not choke on it."""
    from repro.core import Coflow, Instance, Job

    d = np.zeros((4, 4), dtype=np.int64)
    d[0, 1] = 5
    job = Job(0, [Coflow(0, 0, d),
                  Coflow(0, 1, np.zeros((4, 4), dtype=np.int64))], [(0, 1)])
    inst = Instance(4, [job])
    for sched in ("gdm", "gdm_rt", "om_alg"):
        verify_transcript(inst, plan(inst, sched, seed=0).transcript())


def test_fb_like_scenario_matches_legacy_paper_workload():
    built = scenarios.build("fb_like", m=10, seed=2, scale=0.05)
    legacy = paper_workload(m=10, mu_bar=5, seed=2, scale=0.05)
    assert _instances_equal(built.instance, legacy), \
        "generalized build_jobs changed the legacy fb_like RNG stream"


# --- the cross-product matrix ------------------------------------------------

@pytest.mark.parametrize("sched", SCHEDULERS)
@pytest.mark.parametrize("scen", scenarios.names())
def test_matrix_invariants(scen, sched):
    _assert_invariants(tiny(scen), sched)


@pytest.mark.slow
@pytest.mark.parametrize("scen", scenarios.names())
def test_matrix_invariants_mid_scale(scen):
    built = scenarios.build(scen, seed=1, **MID[scen])
    scenarios.check_bounds(built)
    for sched in SCHEDULERS:
        _assert_invariants(built, sched, seed=1)


# --- metadata bounds (property tests via the hypothesis shim) ---------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_generated_instances_satisfy_declared_bounds(seed):
    for name in scenarios.names():
        built = scenarios.build(name, seed=seed, **TINY[name])
        scenarios.check_bounds(built)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_scenario_generators_deterministic(seed):
    for name in scenarios.names():
        a = scenarios.build(name, seed=seed, **TINY[name])
        b = scenarios.build(name, seed=seed, **TINY[name])
        assert _instances_equal(a.instance, b.instance), \
            f"{name} is not seed-deterministic"


# --- backfill executors (packet vs ledger) ----------------------------------

def test_backfill_comparator_deep_chain_larger_m():
    """The exact PR-2 regression shape: on deep_chain at larger m the
    ledger executor's capacity capping defers work past its planned windows
    and its re-executed TWCT EXCEEDS the plan's, while the packet executor
    — re-executing the plan's own timed-matching decomposition — is
    pointwise never worse.  CI runs this as its own `backfill-comparator`
    step so the restored guarantee stays pinned to the shape that broke it."""
    built = scenarios.build("deep_chain", seed=0, m=12, scale=0.4)
    inst = built.instance
    ledger_excess = {}
    for sched in ("gdm", "gdm_rt", "om_alg"):
        opts = scenarios.scheduler_opts(sched, built.meta)
        p = plan(inst, sched, seed=0, **opts)
        planned = p.twct()
        packet = backfill(p.schedule, exec="packet").twct()
        ledger = backfill(p.schedule, exec="ledger").twct()
        assert packet <= planned * (1 + 1e-9) + 1e-9, \
            f"{sched}: packet backfill {packet} > planned {planned}"
        ledger_excess[sched] = ledger - planned
    # the comparator is non-vacuous: the ledger executor really does exceed
    # the plan here (this is the shape the packet executor exists to fix)
    assert max(ledger_excess.values()) > 0, ledger_excess


@pytest.mark.parametrize("exec_", ["packet", "ledger"])
def test_zero_demand_tail_coflow_completes_with_parents(exec_):
    """A job whose LAST coflow is empty must complete when its parents do
    (plus release), not at the empty coflow's planned window end — stamping
    the planned end inflates job completion (and TWCT) whenever backfilling
    finishes the parents early."""
    from repro.core import Coflow, Instance, Job

    d0 = np.zeros((4, 4), dtype=np.int64)
    d0[0, 1] = 4
    d1 = np.zeros((4, 4), dtype=np.int64)
    d1[2, 3] = 4
    jobs = [
        Job(0, [Coflow(0, 0, d0),
                Coflow(0, 1, np.zeros((4, 4), dtype=np.int64))], [(0, 1)],
            weight=1.0),
        Job(1, [Coflow(1, 0, d1)], [], weight=50.0),
    ]
    inst = Instance(4, jobs)
    p = plan(inst, "om_alg", seed=0)
    planned_job0 = p.job_completions()[0]
    bf = backfill(p.schedule, exec=exec_)
    comp = bf.coflow_completions
    assert comp[(0, 1)] == comp[(0, 0)], \
        "empty tail coflow must complete with its parent"
    assert bf.job_completions[0] == comp[(0, 0)]
    # backfilling finished job 0 early into job 1's window; the empty tail
    # must not drag completion back to its planned end
    assert bf.job_completions[0] < planned_job0
    verify_transcript(inst, bf.transcript, check_capacity=True,
                      makespan=bf.makespan)


@pytest.mark.parametrize("exec_", ["packet", "ledger"])
def test_makespan_covers_zero_demand_completions(exec_):
    """An instance whose jobs all have zero-demand coflows transmits
    nothing, but its completions are positive (release-stamped markers) —
    makespan must cover them instead of reporting 0.0."""
    from repro.core import Coflow, Instance, Job

    z = np.zeros((4, 4), dtype=np.int64)
    jobs = [
        Job(0, [Coflow(0, 0, z.copy())], [], release=5),
        Job(1, [Coflow(1, 0, z.copy()), Coflow(1, 1, z.copy())], [(0, 1)],
            release=7),
    ]
    inst = Instance(4, jobs)
    p = plan(inst, "om_alg", seed=0)
    bf = backfill(p.schedule, exec=exec_)
    assert bf.coflow_completions[(0, 0)] == 5.0
    assert bf.coflow_completions[(1, 1)] == 7.0
    assert bf.makespan >= max(bf.coflow_completions.values())
    verify_transcript(inst, bf.transcript, makespan=bf.makespan)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_packet_executor_capacity_and_replay(seed):
    """Property: the packet executor's per-interval port load never exceeds
    capacity (exact transcript-level feasibility), its makespan covers every
    completion, and its reported completions agree with an independent
    replay of the executed transcript."""
    names = scenarios.names()
    name = names[seed % len(names)]
    built = scenarios.build(name, seed=seed, **TINY[name])
    inst = built.instance
    sched = ("gdm", "gdm_rt", "om_alg")[seed % 3]
    opts = scenarios.scheduler_opts(sched, built.meta)
    p = plan(inst, sched, seed=seed % 17, **opts)
    bf = backfill(p.schedule, exec="packet")
    verify_transcript(inst, bf.transcript, check_capacity=True,
                      makespan=bf.makespan)
    replay = bf.transcript.job_completions()
    for jid, t in bf.job_completions.items():
        assert replay[jid] == pytest.approx(t, abs=1e-6), \
            f"{name}/{sched}: job {jid} reported {t}, replay {replay[jid]}"
    # and filling is monotone for the packet executor too: fill=False is an
    # exact replay of the plan, so it can only be slower
    assert bf.twct() <= backfill(p.schedule, fill=False,
                                 exec="packet").twct() * (1 + 1e-9) + 1e-9


# --- seed determinism of the trace primitives (satellite) -------------------

def test_trace_primitives_seed_deterministic():
    d1 = fb_like_coflows(m=8, n_coflows=6, seed=7, scale=0.1)
    d2 = fb_like_coflows(m=8, n_coflows=6, seed=7, scale=0.1)
    assert len(d1) == len(d2)
    assert all(np.array_equal(a, b) for a, b in zip(d1, d2))

    i1 = build_jobs(d1, mu_bar=3, seed=7, weights="random")
    i2 = build_jobs(d2, mu_bar=3, seed=7, weights="random")
    assert _instances_equal(i1, i2)

    p1 = poisson_releases(i1, theta=theta0(i1) * 3, seed=7)
    p2 = poisson_releases(i2, theta=theta0(i2) * 3, seed=7)
    assert _instances_equal(p1, p2)


# --- golden TWCT regressions ------------------------------------------------

def test_golden_twct_per_scheduler():
    """Checked-in goldens: total weighted completion time of every
    registered scheduler on the small fixed-seed fb_like scenario.  A
    refactor that silently changes any schedule fails here; refresh
    intentionally with REPRO_UPDATE_GOLDENS=1."""
    got = {sched: tiny_plan("fb_like", sched).twct() for sched in SCHEDULERS}
    if os.environ.get("REPRO_UPDATE_GOLDENS"):
        GOLDEN_PATH.parent.mkdir(exist_ok=True)
        GOLDEN_PATH.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
    want = json.loads(GOLDEN_PATH.read_text())
    assert set(want) == set(got), "scheduler registry changed; refresh goldens"
    for sched, val in want.items():
        assert got[sched] == pytest.approx(val, rel=1e-9), \
            f"{sched}: twct {got[sched]} != golden {val}"
