"""Timeline machinery, backfilling, and the online driver."""
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core import (backfill, gdm, om_alg, paper_workload,
                        poisson_releases, simulate_online, theta0, twct)
from repro.core.timeline import EdgeIntervals, _alphas_vectorized


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 5000), m=st.integers(2, 12), e=st.integers(1, 80))
def test_alpha_sweep_matches_bruteforce(seed, m, e):
    rng = np.random.default_rng(seed)
    t0 = rng.integers(0, 100, e)
    t1 = t0 + rng.integers(1, 40, e)
    s = rng.integers(0, m, e)
    r = rng.integers(0, m, e)
    edges = EdgeIntervals(t0.astype(np.int64), t1.astype(np.int64),
                          s.astype(np.int64), r.astype(np.int64))
    events = np.unique(np.concatenate([t0, t1]))
    alphas = _alphas_vectorized(events, edges, m, chunk=16)
    # brute force
    for k in range(len(events) - 1):
        mid = (events[k] + events[k + 1]) / 2
        act = (t0 <= mid) & (mid < t1)
        cs = np.bincount(s[act], minlength=m)
        cr = np.bincount(r[act], minlength=m)
        assert alphas[k] == max(cs.max(initial=0), cr.max(initial=0))


def test_backfill_never_hurts_makespan_and_conserves():
    for seed in range(3):
        inst = paper_workload(m=10, mu_bar=3, seed=seed, scale=0.05)
        s = gdm(inst, rng=np.random.default_rng(seed))
        bf = backfill(s)
        assert bf.makespan <= s.makespan + 1e-6
        assert bf.twct() <= s.twct() + 1e-6
        # conservation: transcript totals == demand
        tot = {}
        for e in bf.transcript.entries:
            tot[(e.jid, e.cid)] = tot.get((e.jid, e.cid), 0.0) + float(e.units.sum())
        for j in inst.jobs:
            for c in j.coflows:
                want = float(c.demand.sum())
                assert abs(tot.get((j.jid, c.cid), 0.0) - want) < 1e-6


def test_backfill_respects_precedence_and_release():
    inst = paper_workload(m=10, mu_bar=4, seed=2, scale=0.05, rooted=True)
    import dataclasses
    jobs = [dataclasses.replace(j, release=20 * i) for i, j in enumerate(inst.jobs)]
    from repro.core import Instance
    inst = Instance(inst.m, jobs)
    s = gdm(inst, rng=np.random.default_rng(0), rooted=True)
    bf = backfill(s)
    start = {}
    end = {}
    for e in bf.transcript.entries:
        if e.units.sum() > 0:
            k = (e.jid, e.cid)
            start[k] = min(start.get(k, np.inf), e.t0)
            end[k] = max(end.get(k, 0.0), e.t1)
    by_id = {j.jid: j for j in inst.jobs}
    for (jid, cid), t0 in start.items():
        assert t0 >= by_id[jid].release - 1e-6
        for a, b in by_id[jid].edges:
            if b == cid and (jid, a) in end:
                assert t0 >= end[(jid, a)] - 1e-6


@pytest.mark.parametrize("algo", ["gdm", "om"])
def test_online_completes_everything(algo):
    base = paper_workload(m=8, mu_bar=3, seed=1, scale=0.04)
    inst = poisson_releases(base, theta=theta0(base) * 5, seed=1)
    if algo == "gdm":
        sched = lambda sub: gdm(sub, rng=np.random.default_rng(0)).transcript()
    else:
        sched = lambda sub: om_alg(sub).transcript()
    res = simulate_online(inst, sched)
    assert set(res.job_completions) == {j.jid for j in inst.jobs}
    for j in inst.jobs:
        assert res.job_completions[j.jid] >= j.release
    assert res.twct() > 0


def test_online_response_reasonable_vs_offline():
    base = paper_workload(m=8, mu_bar=3, seed=3, scale=0.04)
    # zero arrivals == offline: same completions as direct scheduling
    res = simulate_online(base, lambda sub: om_alg(sub).transcript())
    direct = om_alg(base)
    for jid, t in direct.job_completions().items():
        assert abs(res.job_completions[jid] - t) < 1e-6
