"""Per-architecture smoke tests (reduced same-family configs, one forward +
train step on CPU, shape/NaN asserts) and serving-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, shape_applicable
from repro.models import (decode_step, encdec_decode_step, encdec_loss,
                          encdec_prefill, init_decode_cache, init_encdec,
                          init_lm, init_vlm, lm_forward, lm_loss, prefill,
                          vlm_loss, vlm_prefill)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch).smoke()
    B, S = 2, 32
    if cfg.family == "encdec":
        params = init_encdec(cfg, KEY)
        frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        loss = encdec_loss(cfg, params, frames, toks, toks)
    elif cfg.family == "vlm":
        params = init_vlm(cfg, KEY)
        patches = jax.random.normal(KEY, (B, cfg.n_image_tokens, cfg.d_model))
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        loss = vlm_loss(cfg, params, patches, toks, toks)
    else:
        params = init_lm(cfg, KEY)
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        logits, aux = lm_forward(cfg, params, toks)
        assert logits.shape == (B, S, cfg.padded_vocab)
        assert jnp.isfinite(logits).all()
        loss = lm_loss(cfg, params, toks, toks)
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "mamba2_2_7b",
                                  "jamba_1_5_large", "qwen3_moe_235b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch).smoke()
    params = init_lm(cfg, KEY)
    B, S, P = 2, 24, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = lm_forward(cfg, params, toks)
    lg, cache = prefill(cfg, params, toks[:, :P])

    def pad_kv(x):
        if x.ndim == 5 and x.shape[2] == P:
            return jnp.pad(x, ((0, 0), (0, 0), (0, S - P), (0, 0), (0, 0)))
        return x

    cache = {"layers": jax.tree.map(pad_kv, cache["layers"]),
             "length": cache["length"]}
    errs = [float(jnp.abs(lg - full[:, P - 1, :cfg.vocab]).max())]
    for t in range(P, S):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg - full[:, t, :cfg.vocab]).max()))
    assert max(errs) < 2e-3, (arch, errs)


def test_encdec_decode_consistency():
    cfg = get_config("whisper_large_v3").smoke()
    params = init_encdec(cfg, KEY)
    B, S = 2, 12
    frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    from repro.models import encdec_forward
    full = encdec_forward(cfg, params, frames, toks)
    lg, cache = encdec_prefill(cfg, params, frames, toks[:, :S - 3],
                               capacity=S)
    errs = [float(jnp.abs(lg - full[:, S - 4, :cfg.vocab]).max())]
    for t in range(S - 3, S):
        lg, cache = encdec_decode_step(cfg, params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg - full[:, t, :cfg.vocab]).max()))
    assert max(errs) < 2e-3, errs


def test_vlm_prefill_shapes():
    cfg = get_config("llava_next_mistral_7b").smoke()
    params = init_vlm(cfg, KEY)
    patches = jax.random.normal(KEY, (1, cfg.n_image_tokens, cfg.d_model))
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    logits, cache = vlm_prefill(cfg, params, patches, toks)
    assert logits.shape == (1, cfg.vocab)
    assert int(cache["length"]) == cfg.n_image_tokens + 8


def test_shape_skip_rules():
    # long_500k runs only for sub-quadratic stacks
    runs = {a: dict((s, ok) for s, ok, _ in cells(a)) for a in ARCH_IDS}
    assert runs["mamba2_2_7b"]["long_500k"]
    assert runs["jamba_1_5_large"]["long_500k"]
    for a in ("qwen2_5_32b", "tinyllama_1_1b", "whisper_large_v3",
              "llava_next_mistral_7b", "qwen3_moe_235b"):
        assert not runs[a]["long_500k"]
    for a in ARCH_IDS:  # every other cell runs everywhere
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert runs[a][s]


def test_exact_assigned_configs():
    # spot-check the assignment table was transcribed exactly
    c = get_config("qwen2.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (64, 5120, 40, 8, 27648, 152064) and c.qkv_bias
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k) == (94, 4096, 128, 8)
    c = get_config("jamba-1.5-large-398b")
    assert c.n_layers == 72 and sum(
        1 for s in c.period if s.kind == "attn") * c.n_periods == 9
    c = get_config("mamba2-2.7b")
    assert c.n_layers == 64 and c.ssm.d_state == 128
    c = get_config("granite-moe-3b-a800m")
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (40, 8, 512)
