"""Training substrate: optimizer, microbatching equivalence, bucket-order
numeric neutrality, compression; checkpoint save/restore; crash/resume
bit-exactness; straggler monitor; elastic restore."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.ckpt import CheckpointManager, latest_step, restore, save
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.ft import FTConfig, StragglerMonitor, TrainRunner
from repro.train.optim import OptConfig, adamw_init, adamw_update, lr_at
from repro.train.step import build_train_step, init_train_state

CFG = get_config("tinyllama-1.1b").smoke()
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)


def _batch(seed=0, B=4, S=32):
    data = SyntheticTokens(CFG, DataConfig(seq_len=S, global_batch=B, seed=seed))
    return data.batch_at(0)


def test_lr_schedule():
    assert float(lr_at(OPT, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(OPT, jnp.asarray(2))) - OPT.lr) < 1e-9
    assert float(lr_at(OPT, jnp.asarray(50))) >= OPT.lr * OPT.min_lr_ratio - 1e-9


def test_adamw_moves_params_and_clips():
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 100.0, state.params)
    new_p, new_s, stats = adamw_update(state.params, grads, state.opt, OPT)
    assert float(stats["grad_norm"]) > OPT.grad_clip  # clip engaged
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(new_p), jax.tree.leaves(state.params))]
    assert max(diffs) > 0


def test_loss_decreases_over_training():
    step = jax.jit(build_train_step(CFG, OPT))
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    data = SyntheticTokens(CFG, DataConfig(seq_len=32, global_batch=4, seed=0))
    losses = []
    for i in range(25):
        state, metrics = step(state, data.batch_at(i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_microbatching_matches_full_batch():
    b = _batch(B=8)
    s1 = init_train_state(CFG, jax.random.PRNGKey(0))
    s2 = init_train_state(CFG, jax.random.PRNGKey(0))
    full = jax.jit(build_train_step(CFG, OPT, micro_steps=1))
    micro = jax.jit(build_train_step(CFG, OPT, micro_steps=4))
    s1, m1 = full(s1, b)
    s2, m2 = micro(s2, b)
    # same tokens, same update up to accumulation-order float noise
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    diff = max(float(jnp.abs(a - b_).max()) for a, b_ in
               zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)))
    assert diff < 5e-3


def test_bucket_order_is_numerically_neutral():
    from repro.dist.partition import _path_str
    b = _batch()
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    paths = [_path_str(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(state.params)[0]]
    order = [paths[len(paths) // 2:], paths[: len(paths) // 2]]  # reversed buckets
    plain = jax.jit(build_train_step(CFG, OPT))
    bucketed = jax.jit(build_train_step(CFG, OPT, bucket_order=order))
    s1, m1 = plain(init_train_state(CFG, jax.random.PRNGKey(0)), b)
    s2, m2 = bucketed(init_train_state(CFG, jax.random.PRNGKey(0)), b)
    diff = max(float(jnp.abs(a - b_).max()) for a, b_ in
               zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)))
    assert diff == 0.0  # ordering barriers must not change the math


def test_grad_compression_trains():
    step = jax.jit(build_train_step(CFG, OPT, grad_compression=True))
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    data = SyntheticTokens(CFG, DataConfig(seq_len=32, global_batch=4, seed=0))
    for i in range(8):
        state, metrics = step(state, data.batch_at(i))
    assert np.isfinite(float(metrics["loss"]))


# --- checkpointing ----------------------------------------------------------

def test_save_restore_roundtrip(tmp_path):
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    save(state, tmp_path, 7, extra={"note": "x"})
    assert latest_step(tmp_path) == 7
    like = jax.eval_shape(lambda: init_train_state(CFG, jax.random.PRNGKey(0)))
    restored, manifest = restore(like, tmp_path)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, every=1, keep=2, async_write=True)
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        mgr.maybe_save(state, s)
    mgr.wait()
    assert latest_step(tmp_path) == 4
    import re
    steps = sorted(p.name for p in tmp_path.iterdir()
                   if re.fullmatch(r"step_\d+", p.name))
    assert len(steps) == 2  # retention


def test_crash_resume_bit_exact(tmp_path):
    class Boom(Exception):
        pass

    def hook(step):
        if step == 7:
            raise Boom()

    def mk(h=None, d="a"):
        return TrainRunner(CFG, OPT,
                           DataConfig(seq_len=32, global_batch=4, seed=0),
                           FTConfig(ckpt_dir=str(tmp_path / d), ckpt_every=3),
                           fault_hook=h)

    r1 = mk(hook)
    with pytest.raises(Boom):
        r1.run(12)
    r2 = mk()
    resumed = r2.run(12)
    assert r2.metrics_log[0]["step"] == 6  # resumed from step-6 checkpoint
    clean = mk(d="b").run(12)
    for a, b in zip(jax.tree.leaves(resumed.params), jax.tree.leaves(clean.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0)
    for s in range(10):
        assert not mon.observe(s, 0.1)
    assert mon.observe(10, 1.0)       # 10x the EWMA -> flagged
    assert mon.flagged == [(10, 1.0)]
    assert not mon.observe(11, 0.1)   # baseline not poisoned


def test_planned_bucket_order_wires_end_to_end(tmp_path):
    """ROADMAP item: bucket_order_from_plan -> TrainRunner, end-to-end.
    The planner's permutation covers every gradient leaf exactly once, the
    runner builds its step with it, and training is numerically identical
    to the unordered runner (the ordering barriers only pin collective
    launch order)."""
    from repro.launch.train import planned_bucket_order

    order, outcome = planned_bucket_order(CFG, n_buckets=4, seed=0)
    assert sorted(outcome.order) == list(range(4))
    assert outcome.session is not None and outcome.session.done
    paths = ["/".join(str(getattr(k, "key", k)) for k in p) for p, _ in
             jax.tree_util.tree_flatten_with_path(
                 init_train_state(CFG, jax.random.PRNGKey(0)).params)[0]]
    flat = [p for bucket in order for p in bucket]
    assert sorted(flat) == sorted(paths)   # a permutation of all leaves

    def mk(bo, d):
        return TrainRunner(CFG, OPT,
                           DataConfig(seq_len=32, global_batch=4, seed=0),
                           FTConfig(ckpt_dir=str(tmp_path / d), ckpt_every=10),
                           bucket_order=bo)

    planned = mk(order, "planned").run(2)
    plain = mk(None, "plain").run(2)
    for a, b in zip(jax.tree.leaves(planned.params),
                    jax.tree.leaves(plain.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
