"""overflow-range NEGATIVE: the guard's product bound covers the launch
operand's element count exactly, so the interval engine proves it."""
import numpy as np

from .goodk import goodk_padded

_I32_MAX = int(np.iinfo(np.int32).max)


def launch(x):
    B, W = x.shape
    w_pad = ((W + 127) // 128) * 128
    if B * w_pad >= _I32_MAX:
        raise ValueError("index space exceeds int32")
    xp = np.zeros((B, w_pad), dtype=np.int32)
    return goodk_padded(xp)
