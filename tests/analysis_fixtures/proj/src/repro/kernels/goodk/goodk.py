"""Fake Pallas entry module (the `<impl>` slot the launch detector keys
on: 4-part module, name neither ops nor ref)."""


def goodk_padded(xp):
    return xp
