"""overflow-range POSITIVE that the syntactic overflow-guard rule
accepts: a sentinel guard with a raise *exists* (so overflow-guard is
happy), but it bounds ``B * w_pad`` while the second launch operand has
``B * w_pad * w_pad`` elements — unprovable, and genuinely overflowable
for crafted shapes."""
import numpy as np

from .badk import badk_padded

_I32_MAX = int(np.iinfo(np.int32).max)


def launch(x):
    B, W = x.shape
    w_pad = ((W + 127) // 128) * 128
    if B * w_pad >= _I32_MAX:
        raise ValueError("index space exceeds int32")
    xp = np.zeros((B, w_pad), dtype=np.int32)
    yp = np.zeros((B, w_pad, w_pad), dtype=np.int32)
    return badk_padded(xp, yp)
