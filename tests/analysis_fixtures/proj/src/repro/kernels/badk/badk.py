"""Fake Pallas entry module for the positive overflow fixture."""


def badk_padded(xp, yp):
    return xp
