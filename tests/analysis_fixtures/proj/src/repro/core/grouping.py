"""cache-key fixtures for the PR-10 grouping-cache shape: memoizing a
geometric grouping while keying only on the member signature.  The
positive is the exact bug class the pinned-gamma work guards against —
``gamma`` rescales change the bucket boundaries, so a cache keyed on the
jobs alone serves groups computed under a stale gamma."""
from .memo import _LRU

groups_cache = _LRU()


def cached_groups(sig, gamma):
    # cache-key POSITIVE: `gamma` shapes the bucket boundaries (the value)
    # but the key carries only the member signature
    key = ("groups", sig)
    found, val = groups_cache.lookup(key)
    if found:
        return val
    val = [k // gamma for k in range(sig)]
    groups_cache.store(key, val)
    return val


def cached_groups_sound(sig, gamma):
    # cache-key NEGATIVE: gamma is folded into the key alongside the
    # membership signature, so rescales miss instead of serving stale groups
    key = ("groups", sig, gamma)
    found, val = groups_cache.lookup(key)
    if found:
        return val
    val = [k // gamma for k in range(sig)]
    groups_cache.store(key, val)
    return val
