"""Helper called from a jitted stage in staged.py.  On its own this file
is innocent — no jax import, no jit — which is exactly why the syntactic
jit-purity rule never looks at it.  The taint engine follows the traced
value into ``pick`` and flags the branch."""


def pick(y, n):
    if y[0] > 0:  # tracer-taint POSITIVE: Python branch on a traced value
        return y * 2
    total = 0
    for i in range(n):  # negative: n is static at the jit boundary
        total += i
    return y + total
