"""cache-key fixtures: one caching function leaks a parameter into the
value only, one reads an env knob the key omits, one is sound.  There is
no syntactic rule for cache keys at all, so the positives are invisible
to the PR-8 layer by construction."""
import os


class _LRU:
    def __init__(self):
        self._d = {}

    def lookup(self, k):
        return (k in self._d, self._d.get(k))

    def store(self, k, v):
        self._d[k] = v


plan_cache = _LRU()


def cached_plan(n, scale):
    # cache-key POSITIVE: `scale` shapes the value but not the key
    key = ("plan", n)
    found, val = plan_cache.lookup(key)
    if found:
        return val
    val = [i * scale for i in range(n)]
    plan_cache.store(key, val)
    return val


def cached_env(n):
    # cache-key POSITIVE: REPRO_FAKE_MODE changes the value, key omits it
    key = ("env", n)
    found, val = plan_cache.lookup(key)
    if found:
        return val
    val = n * (2 if os.environ.get("REPRO_FAKE_MODE") == "x" else 1)
    plan_cache.store(key, val)
    return val


def cached_sound(n, scale):
    # cache-key NEGATIVE: every value input reaches the key
    key = ("sound", n, scale)
    found, val = plan_cache.lookup(key)
    if found:
        return val
    val = [i * scale for i in range(n)]
    plan_cache.store(key, val)
    return val
