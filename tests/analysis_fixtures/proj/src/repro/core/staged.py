"""Jitted stage whose own body is clean (passes the syntactic jit-purity
rule) but which hands a tracer to :func:`repro.core.helper.pick`, where
a Python branch consumes it — only the interprocedural taint engine
sees that."""
import jax
import jax.numpy as jnp

from .helper import pick


def step(x, n):
    if x.shape[0] > 4:  # tracer-taint NEGATIVE: shapes are static
        y = jnp.cumsum(x)
    else:
        y = jnp.cumsum(x) * 2
    return pick(y, n)


step_jit = jax.jit(step, static_argnames=("n",))
