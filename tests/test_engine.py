"""Engine layer: registry/parity with the legacy call paths, backend
dispatch (pallas vs numpy alphas), and the incremental online path."""
import numpy as np
import pytest

from repro.core import (available_schedulers, backfill, cache_stats,
                        clear_caches, compute_alphas, gdm, make_scheduler,
                        om_alg, paper_workload, plan, plan_online,
                        poisson_releases, simulate_online, theta0,
                        use_alpha_backend)
from repro.core import backend as backend_mod
from repro.core.timeline import EdgeIntervals, _alphas_vectorized


def _rand_edges(seed, m=6, e=40, horizon=60):
    rng = np.random.default_rng(seed)
    t0 = rng.integers(0, horizon, e)
    t1 = t0 + rng.integers(1, 30, e)
    edges = EdgeIntervals(t0.astype(np.int64), t1.astype(np.int64),
                          rng.integers(0, m, e).astype(np.int64),
                          rng.integers(0, m, e).astype(np.int64))
    events = np.unique(np.concatenate([t0, t1]))
    return events, edges


# --- registry + offline parity ---------------------------------------------

def test_registry_covers_all_paper_algorithms():
    names = set(available_schedulers())
    assert {"gdm", "gdm_rt", "om_alg",
            "gdm_bf", "gdm_rt_bf", "om_alg_bf"} <= names


def test_unknown_scheduler_raises():
    with pytest.raises(KeyError):
        make_scheduler("nope")


@pytest.mark.parametrize("seed", [0, 1])
def test_engine_matches_legacy_gdm(seed):
    inst = paper_workload(m=10, mu_bar=3, seed=seed, scale=0.06)
    legacy = gdm(inst, beta=2.0, rng=np.random.default_rng(seed))
    p = plan(inst, "gdm", beta=2.0, seed=seed)
    assert p.twct() == pytest.approx(legacy.twct(), abs=1e-9)
    assert p.job_completions() == legacy.job_completions()
    # backfilled variant == backfill of the legacy schedule
    pb = plan(inst, "gdm_bf", beta=2.0, seed=seed)
    assert pb.twct() == pytest.approx(backfill(legacy).twct(), abs=1e-9)


def test_engine_matches_legacy_gdm_rt_flat():
    inst = paper_workload(m=10, mu_bar=4, seed=2, scale=0.06, rooted=True)
    legacy = gdm(inst, beta=2.0, rng=np.random.default_rng(2), rooted=True,
                 nested=False)
    p = plan(inst, "gdm_rt", beta=2.0, seed=2, nested=False)
    assert p.twct() == pytest.approx(legacy.twct(), abs=1e-9)
    assert p.job_completions() == legacy.job_completions()


def test_engine_matches_legacy_om_alg():
    inst = paper_workload(m=10, mu_bar=3, seed=3, scale=0.06)
    legacy = om_alg(inst)
    p = plan(inst, "om_alg")
    assert p.twct() == pytest.approx(legacy.twct(), abs=1e-9)
    assert p.job_completions() == legacy.job_completions()
    pb = plan(inst, "om_alg_bf")
    assert pb.twct() == pytest.approx(backfill(legacy).twct(), abs=1e-9)


def test_plan_backfilled_shortcut():
    inst = paper_workload(m=8, mu_bar=3, seed=0, scale=0.05)
    p = plan(inst, "gdm", seed=0)
    assert p.backfilled().twct() == pytest.approx(
        plan(inst, "gdm_bf", seed=0).twct(), abs=1e-9)


def test_transcript_roundtrip_completions():
    inst = paper_workload(m=8, mu_bar=3, seed=1, scale=0.05)
    p = plan(inst, "gdm", seed=1)
    tj = p.transcript().job_completions()
    pj = p.job_completions()
    for jid, t in pj.items():
        assert tj[jid] == pytest.approx(t, abs=1e-6)


# --- backend dispatch -------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_pallas_alphas_match_numpy_oracle(seed):
    m = 5 + seed
    events, edges = _rand_edges(seed, m=m, e=30 + 10 * seed)
    a_np = compute_alphas(events, edges, m, force="numpy")
    a_pl = compute_alphas(events, edges, m, force="pallas")
    assert np.array_equal(a_np, a_pl)
    assert np.array_equal(a_np, _alphas_vectorized(events, edges, m))


def test_backend_switch_is_results_identical_end_to_end():
    inst = paper_workload(m=8, mu_bar=3, seed=0, scale=0.05)
    ref = gdm(inst, rng=np.random.default_rng(0))
    with use_alpha_backend("pallas"):
        via_kernel = gdm(inst, rng=np.random.default_rng(0))
    assert via_kernel.twct() == pytest.approx(ref.twct(), abs=1e-9)
    for p_ref, p_k in zip(ref.parts, via_kernel.parts):
        assert np.array_equal(p_ref.alphas, p_k.alphas)


def test_backend_config_rejects_unknown():
    with pytest.raises(ValueError):
        backend_mod.set_alpha_backend("cuda")


# --- caches -----------------------------------------------------------------

def test_bna_cache_bytes_keyed_and_bounded():
    clear_caches()
    d = np.zeros((4, 4), dtype=np.int64)
    d[0, 1] = 3
    p1 = backend_mod.bna_pieces(d)
    p2 = backend_mod.bna_pieces(d.copy())   # fresh object, same bytes
    assert p1 is p2
    st = cache_stats()["bna"]
    assert st["hits"] == 1 and st["misses"] == 1
    # bounded: distinct demands never exceed maxsize
    old = backend_mod.config.bna_cache_size
    try:
        backend_mod.config.bna_cache_size = 4
        clear_caches()
        for v in range(10):
            dv = np.zeros((4, 4), dtype=np.int64)
            dv[1, 2] = v + 1
            backend_mod.bna_pieces(dv)
        assert len(backend_mod.bna_cache) <= 4
    finally:
        backend_mod.config.bna_cache_size = old
        clear_caches()


def test_order_cache_hits_on_replanning_same_state():
    clear_caches()
    inst = paper_workload(m=8, mu_bar=3, seed=4, scale=0.05)
    g = gdm(inst, rng=np.random.default_rng(0))
    o = om_alg(inst)   # same state -> Algorithm 5 order reused
    assert cache_stats()["order"]["hits"] >= 1
    assert g.meta["order"] == o.meta["order"]


# --- incremental online path ------------------------------------------------

def test_online_incremental_matches_full_recompute_and_hits():
    base = paper_workload(m=8, mu_bar=3, seed=1, scale=0.05)
    inst = poisson_releases(base, theta=theta0(base) * 5, seed=1)
    legacy = simulate_online(
        inst, lambda sub: gdm(sub, rng=np.random.default_rng(0)).transcript())
    clear_caches()
    inc = plan_online(inst, "gdm", seed=0)
    cold = plan_online(inst, "gdm", incremental=False, seed=0)
    assert inc.twct() == pytest.approx(legacy.twct(), abs=1e-9)
    assert cold.twct() == pytest.approx(legacy.twct(), abs=1e-9)
    assert inc.job_completions == legacy.job_completions
    # the bytes-keyed cache must hit across reschedules even from cold
    assert inc.stats["bna"]["hits"] > 0
    assert cold.stats["bna"]["hits"] == 0
    assert inc.reschedules == legacy.reschedules


def test_online_accepts_scheduler_names_and_objects():
    base = paper_workload(m=8, mu_bar=3, seed=3, scale=0.04)
    by_name = simulate_online(base, "om_alg")
    by_obj = simulate_online(base, make_scheduler("om_alg"))
    by_closure = simulate_online(base, lambda sub: om_alg(sub).transcript())
    assert by_name.twct() == pytest.approx(by_closure.twct(), abs=1e-9)
    assert by_obj.twct() == pytest.approx(by_closure.twct(), abs=1e-9)


@pytest.mark.slow
def test_online_acceptance_scale_hit_rate_and_wallclock():
    """Acceptance: paper_workload(scale=0.12), Poisson releases — BNA hit
    rate > 0, wall-clock no worse than from-scratch, identical twct."""
    base = paper_workload(m=30, mu_bar=5, seed=0, scale=0.12)
    inst = poisson_releases(base, theta=theta0(base) * 2, seed=0)
    clear_caches()
    inc = plan_online(inst, "gdm", seed=0)
    cold = plan_online(inst, "gdm", incremental=False, seed=0)
    assert inc.twct() == pytest.approx(cold.twct(), abs=1e-9)
    assert inc.stats["bna"]["hit_rate"] > 0
    assert inc.stats["wall_s"] <= cold.stats["wall_s"] * 1.10
