"""Partition rules, the coflow collective planner, and the serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.partition import param_pspecs, zero_pspecs
from repro.dist.planner import (CollectiveOp, coflows_from_step,
                                extract_collectives, plan,
                                bucket_order_from_plan)
from repro.launch.specs import abstract_params


def test_param_pspecs_rules():
    cfg = get_config("qwen3_moe_235b")
    params = abstract_params(cfg)
    specs = param_pspecs(params)
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
            for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
    assert flat["embed"] == P("model", None)
    assert flat["unembed"] == P(None, "model")
    wq = [v for k, v in flat.items() if k.endswith("wq")][0]
    assert wq == P(None, None, "model")          # stacked + TP on flat dim
    moe_gate = [v for k, v in flat.items() if "moe/w_gate" in k][0]
    assert moe_gate == P(None, "model", None, None)  # EP on experts
    norm = [v for k, v in flat.items() if k.endswith("final_norm/scale")][0]
    assert norm in (P(), P(None))  # replicated (both spellings equivalent)


def test_zero_pspecs_divisibility():
    import os
    cfg = get_config("tinyllama-1.1b")
    params = abstract_params(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    zp = zero_pspecs(params, mesh)  # dp size 1: everything stays legal
    for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_leaves(zp, is_leaf=lambda x: isinstance(x, P))):
        for i, ax in enumerate(tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if ax is not None:
                size = np.prod([mesh.shape[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))])
                assert leaf.shape[i] % size == 0


def test_extract_collectives_parses_hlo():
    hlo = """
  %all-reduce.1 = bf16[1024,128]{1,0} all-reduce(bf16[1024,128]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096]{0} all-gather(f32[256]{0} %y), replica_groups=[8,2]<=[16]
  %a2a.2 = bf16[64,32]{1,0} all-to-all(bf16[64,32]{1,0} %z), replica_groups={{0,4,8,12}}
"""
    ops = extract_collectives(hlo)
    assert [o.kind for o in ops] == ["all-reduce", "all-gather", "all-to-all"]
    assert ops[0].bytes == 1024 * 128 * 2
    assert ops[0].axis == "model"     # consecutive ids
    assert ops[2].axis == "data"      # strided ids


def test_plan_and_bucket_translation():
    rng = np.random.default_rng(0)
    ops = [CollectiveOp("all-reduce", float(rng.integers(2**20, 2**24)), i,
                        "model" if i % 2 else "data") for i in range(12)]
    inst = coflows_from_step(ops, rows=4, cols=4, n_buckets=4)
    assert inst.n == 4
    res = plan(inst)
    assert sorted(res.order) == [0, 1, 2, 3]
    buckets = bucket_order_from_plan(res, [f"p{i}" for i in range(8)])
    assert sorted(x for b in buckets for x in b) == [f"p{i}" for i in range(8)]


def test_planner_multi_tenant_makespan_gain():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.planner_ab import multi_tenant_instance
    from repro.core import gdm, om_alg
    inst = multi_tenant_instance(seed=2)
    g = gdm(inst, beta=10.0, rng=np.random.default_rng(1))
    o = om_alg(inst)
    assert g.makespan < o.makespan  # interleaving shortens the phase


def test_serving_engine_fifo_vs_coflow():
    from repro.serve import Request, ServeConfig, ServingEngine
    from repro.train.step import init_params
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def reqs():
        return [Request(rid=i,
                        tokens=rng.integers(1, cfg.vocab, size=6),
                        max_new=4, weight=float(1 + (i % 3)), arrival=0.0)
                for i in range(6)]

    # non-zero arrivals must still get the weighted (Algorithm 5 / session)
    # ordering once they arrive — not the FIFO (arrival, rid) fallback: the
    # light high-priority request admits before the heavy low-priority one
    # that has a smaller rid
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, capacity=32,
                                                 admission="coflow"))
    heavy = Request(rid=1, tokens=rng.integers(1, cfg.vocab, size=18),
                    max_new=12, weight=0.1, arrival=1.0)
    light = Request(rid=2, tokens=rng.integers(1, cfg.vocab, size=3),
                    max_new=2, weight=100.0, arrival=1.0)
    order = eng._admission_order([heavy, light], step=1)
    assert [r.rid for r in order] == [2, 1]
    # duplicate rids in one batch share a session job instead of crashing
    dup = Request(rid=2, tokens=rng.integers(1, cfg.vocab, size=3),
                  max_new=2, weight=100.0, arrival=1.0)
    assert len(eng._admission_order([light, dup], step=2)) == 2

    out = {}
    for mode in ("coflow", "fifo"):
        eng = ServingEngine(cfg, params, ServeConfig(slots=2, capacity=32,
                                                     admission=mode))
        out[mode] = eng.run(reqs())
        assert out[mode]["completed"] == 6
        # engines are reusable: a second batch with restarted rids gets a
        # fresh scheduling session instead of duplicate-jid errors
        assert eng.run(reqs())["completed"] == 6
    # both complete; admission ordering is exercised (values may differ)
    assert out["coflow"]["steps"] > 0


def test_serve_config_ports_validation_and_threading():
    from repro.core import AdmissionPolicy
    from repro.serve import Request, ServeConfig, ServingEngine
    from repro.train.step import init_params

    # option validation at construction, like make_scheduler's registry
    with pytest.raises(ValueError, match="ports"):
        ServeConfig(ports=1)
    with pytest.raises(ValueError, match="ports"):
        ServeConfig(ports="8")
    with pytest.raises(ValueError, match="ports"):
        ServeConfig(ports=True)
    with pytest.raises(ValueError, match="slots"):
        ServeConfig(slots=0)
    with pytest.raises(ValueError, match="admission"):
        ServeConfig(admission="lifo")
    with pytest.raises(TypeError, match="backpressure"):
        ServeConfig(backpressure=0.5)

    # the session's port model follows the configured serving topology
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    policy = AdmissionPolicy(max_pending=8, replan_budget=0.5, window=8)
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, capacity=32,
                                                 ports=5, backpressure=policy))
    assert eng._session.m == 5
    assert eng._session.admission is policy
    job = eng._request_job(Request(rid=11, tokens=np.arange(3), max_new=2))
    assert job.m == 5
    r = Request(rid=0, tokens=np.arange(4), max_new=2, weight=2.0)
    assert [x.rid for x in eng._admission_order([r], step=0)] == [0]
    # run() resets onto the configured topology too
    assert eng._new_session().m == 5
