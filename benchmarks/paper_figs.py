"""Paper-figure benchmarks (§VII): G-DM / G-DM-RT vs O(m)Alg, with and
without backfilling, offline and online.

  Fig 5a / 6a — offline, sweep number of servers m (mu_bar = 5)
  Fig 5b / 6b — offline, sweep mu_bar (m = 150)
  Fig 5c / 6c — online, sweep arrival-rate multiplier a (theta = a*theta0)
  Fig 4       — beta sweep (G-DM-RT, mu_bar = 5)
  §VII-A      — relative standard deviation across 10 randomized runs

Metric: percent improvement of total weighted completion time,
100 * (1 - TWCT_GDM / TWCT_Om). Online measures from arrival.

Default scale trims the trace (fewer coflows, proportionally narrower) so
the full suite runs in CPU-minutes; --full uses the paper's 267-coflow
count (same published statistics) — EXPERIMENTS.md quotes the full run.
"""
from __future__ import annotations

import numpy as np

from repro.core import (clear_caches, make_scheduler, paper_workload,
                        plan_online, poisson_releases, theta0,
                        workload_stats)

from .common import emit, save_json, timed

DEFAULT_SCALE = 0.35
DEFAULT_SEEDS = 3


def _pair_schedulers(rooted: bool, beta: float, seed: int):
    # rooted sweeps use the flat DMA-RT fast path (nested=False): identical
    # delay-and-merge principle, one global fix-up, no per-job packet
    # decomposition — tests check nested/flat agreement on small instances
    g = make_scheduler("gdm_rt" if rooted else "gdm", beta=beta, seed=seed,
                       nested=False)
    o = make_scheduler("om_alg")
    return g, o


def _pair(inst, rooted: bool, beta: float, seed: int, bf: bool):
    g, o = _pair_schedulers(rooted, beta, seed)
    gp, op = g.plan_full(inst), o.plan_full(inst)
    if bf:
        return gp.backfilled().twct(), op.backfilled().twct()
    return gp.twct(), op.twct()


def fig_a(rooted: bool, scale: float = DEFAULT_SCALE, seeds: int = DEFAULT_SEEDS,
          ms=(10, 30, 50, 100, 150), beta: float = 2.0) -> list[dict]:
    name = "fig6a" if rooted else "fig5a"
    rows = []
    for m in ms:
        gains, gains_bf = [], []
        us = 0.0
        for seed in range(seeds):
            # one instance per seed: BNA decompositions (bytes-keyed LRU)
            # and the Algorithm 5 order (state-keyed LRU) are shared by all
            # four algorithm variants
            inst = paper_workload(m=m, mu_bar=5, seed=seed, scale=scale,
                                  rooted=rooted)
            gs, os_ = _pair_schedulers(rooted, beta, seed)
            (pair, dt) = timed(lambda: (gs.plan_full(inst),
                                        os_.plan_full(inst)))
            g, o = pair
            us += dt
            gains.append(1 - g.twct() / o.twct())
            gains_bf.append(1 - g.backfilled().twct() / o.backfilled().twct())
        emit(f"{name}_m{m}", us / seeds,
             f"gain_pct={100 * float(np.mean(gains)):.1f}")
        emit(f"{name}-BF_m{m}", us / seeds,
             f"gain_pct={100 * float(np.mean(gains_bf)):.1f}")
        rows.append({"m": m, "gain": float(np.mean(gains)),
                     "gain_bf": float(np.mean(gains_bf)),
                     "std": float(np.std(gains))})
    save_json(name, rows)
    return rows


def fig_b(rooted: bool, scale: float = DEFAULT_SCALE, seeds: int = DEFAULT_SEEDS,
          mus=(2, 5, 10, 20), m: int = 150, beta: float = 2.0) -> list[dict]:
    name = "fig6b" if rooted else "fig5b"
    rows = []
    for mu in mus:
        gains = []
        us = 0.0
        for seed in range(seeds):
            inst = paper_workload(m=m, mu_bar=mu, seed=seed, scale=scale,
                                  rooted=rooted)
            (gt, ot), dt = timed(_pair, inst, rooted, beta, seed, False)
            gains.append(1 - gt / ot)
            us += dt
        emit(f"{name}_mu{mu}", us / seeds,
             f"gain_pct={100 * float(np.mean(gains)):.1f}")
        rows.append({"mu_bar": mu, "gain": float(np.mean(gains))})
    save_json(name, rows)
    return rows


def fig_c(rooted: bool, scale: float = DEFAULT_SCALE, seeds: int = 2,
          factors=(1, 2, 10, 25, 100), m: int = 150, beta: float = 2.0) -> list[dict]:
    """Online: jobs arrive Poisson(a * theta0); reschedule on each arrival."""
    name = "fig6c" if rooted else "fig5c"
    rows = []
    for a in factors:
        gains = []
        us = 0.0
        hit_rates = []
        for seed in range(seeds):
            base = paper_workload(m=m, mu_bar=5, seed=seed, scale=scale,
                                  rooted=rooted)
            inst = poisson_releases(base, theta=a * theta0(base), seed=seed)
            g_sched, o_sched = _pair_schedulers(rooted, beta, seed)
            # cold start per measurement: the reported hit rate must come
            # from within-run reschedule reuse, not earlier sweep points
            clear_caches()
            (rg, ro), dt = timed(
                lambda: (plan_online(inst, g_sched),
                         plan_online(inst, o_sched)))
            gains.append(1 - rg.twct() / ro.twct())
            hit_rates.append(rg.stats["bna"]["hit_rate"])
            us += dt
        emit(f"{name}_a{a}", us / seeds,
             f"gain_pct={100 * float(np.mean(gains)):.1f};"
             f"bna_hit_pct={100 * float(np.mean(hit_rates)):.1f}")
        rows.append({"a": a, "gain": float(np.mean(gains)),
                     "bna_hit_rate": float(np.mean(hit_rates))})
    save_json(name, rows)
    return rows


def fig4_beta(scale: float = DEFAULT_SCALE, seeds: int = 2,
              betas=(1, 2, 10, 100, 500), ms=(30, 150)) -> list[dict]:
    rows = []
    for m in ms:
        for beta in betas:
            vals = []
            us = 0.0
            for seed in range(seeds):
                inst = paper_workload(m=m, mu_bar=5, seed=seed, scale=scale,
                                      rooted=True)
                sched = make_scheduler("gdm_rt", beta=beta, seed=seed,
                                       nested=False)
                s, dt = timed(sched.plan_full, inst)
                vals.append(s.twct())
                us += dt
            emit(f"fig4_m{m}_beta{beta}", us / seeds,
                 f"twct={float(np.mean(vals)):.0f}")
            rows.append({"m": m, "beta": beta, "twct": float(np.mean(vals))})
    save_json("fig4", rows)
    return rows


def rsd(scale: float = DEFAULT_SCALE, runs: int = 10, m: int = 50) -> dict:
    """§VII-A: relative standard deviation over repeated randomized runs —
    the paper reports < 0.5% (plain) and < 0.9% (backfilled)."""
    out = {}
    for rooted in (False, True):
        inst = paper_workload(m=m, mu_bar=5, seed=0, scale=scale, rooted=rooted)
        name = "gdm_rt" if rooted else "gdm"
        vals = [make_scheduler(name, beta=2.0, seed=1000 + r,
                               nested=False).plan_full(inst).twct()
                for r in range(runs)]
        r = float(np.std(vals) / np.mean(vals))
        key = "G-DM-RT" if rooted else "G-DM"
        out[key] = r
        emit(f"rsd_{key}", 0.0, f"rsd_pct={100 * r:.2f}")
    save_json("rsd", out)
    return out


def workload_calibration(scale: float = 1.0) -> dict:
    """Synthetic-trace statistics next to the paper's published ones."""
    inst = paper_workload(m=150, mu_bar=5, seed=0, scale=scale)
    st = workload_stats(inst)
    emit("workload_delta", 0.0, f"delta={st['delta']}")
    save_json("workload_stats", st)
    return st
