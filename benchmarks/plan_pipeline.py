"""Planning-pipeline A/B: python vs jitted path, cold-start wall per scenario.

For every scenario in the matrix (fixed seeds) this measures the full
``plan`` wall-clock with the G-DM scheduler under both plan backends:

* ``python_us``   — best-of-N cold runs on the classic numpy path (all
  result caches cleared before each run; this is the baseline "cold-start
  planning wall" a fresh process pays per instance).
* ``jit_cold_us`` — one run on the jitted pipeline with the compile cache
  ALSO cleared: trace + XLA compile + execute.  This is the first-instance
  cost of a fresh process without a persisted jax compilation cache.
* ``jit_warm_us`` — best-of-N runs with result caches cleared but compiled
  executables retained (the steady state of a long-lived scheduler process,
  or any process with the jax compilation cache persisted — the CI job
  keeps one).

Plans must be bit-identical across backends (asserted on twct here; the
full transcript-level grid lives in tests/test_pipeline.py).  Results land
in ``benchmarks/results/BENCH_plan.json`` with per-scenario rows, the
geomean warm speedup, and the headline wide_shallow/fb_like rows at
m >= 50.  On a CPU-only container the pipeline runs through XLA's CPU
backend — ``device`` records that; the >=10x cold-start targets are stated
for TPU-attached runs, which is also the only configuration where
``auto`` resolves to jit.
"""
from __future__ import annotations

import numpy as np

from repro import scenarios
from repro.core import clear_caches, plan, use_plan_backend

from . import common

# (scenario, build overrides) — fixed seeds, one headline pair at m >= 50
_FAST_CASES = [
    ("wide_shallow", {"m": 50, "scale": 0.5}),
    ("fb_like", {"m": 50, "scale": 0.1}),
    ("incast", {"m": 16, "scale": 1.0}),
    ("deep_chain", {"m": 12, "scale": 0.3}),
]
_FULL_CASES = _FAST_CASES + [
    ("shuffle_heavy", {"m": 24, "scale": 0.2}),
    ("alibaba_sparse", {"m": 24, "scale": 0.2}),
    ("dist_collectives", {"m": 24, "scale": 0.2}),
]
_SEEDS = (0, 1)


def _bench_case(scen: str, kw: dict, seed: int, reps: int) -> dict:
    import jax

    import repro.core.pipeline as pipeline

    built = scenarios.build(scen, seed=seed, **kw)
    row: dict = {"scenario": scen, "seed": seed, "m": built.instance.m,
                 "jobs": len(built.instance.jobs), **kw}

    with use_plan_backend("python"):
        best = np.inf
        for _ in range(reps):
            clear_caches()
            p, us = common.timed(plan, built.instance, "gdm", seed=seed)
            best = min(best, us)
        row["python_us"] = best
        twct_py = p.twct()

    with use_plan_backend("jit"):
        pipeline.clear_pipeline_caches(compiled=True)
        clear_caches()
        p, us = common.timed(plan, built.instance, "gdm", seed=seed)
        row["jit_cold_us"] = us
        stats = pipeline.pipeline_stats()["compile"]
        row["compile_ms"] = stats["compile_s"] * 1e3
        row["compiles"] = stats["compiles"]
        best = np.inf
        for _ in range(reps):
            clear_caches()  # result caches only; executables retained
            p, us = common.timed(plan, built.instance, "gdm", seed=seed)
            best = min(best, us)
        row["jit_warm_us"] = best
        assert p.twct() == twct_py, \
            f"jit plan diverged on {scen} seed {seed}"

    row["identical"] = True
    row["speedup_cold"] = row["python_us"] / max(row["jit_cold_us"], 1e-9)
    row["speedup_warm"] = row["python_us"] / max(row["jit_warm_us"], 1e-9)
    row["device"] = jax.devices()[0].platform
    return row


def run(fast: bool = True) -> dict:
    cases = _FAST_CASES if fast else _FULL_CASES
    reps = 3 if fast else 2
    rows = [_bench_case(scen, kw, seed, reps)
            for scen, kw in cases for seed in _SEEDS]
    warm = np.array([r["speedup_warm"] for r in rows])
    cold = np.array([r["speedup_cold"] for r in rows])
    headline = {
        f"{r['scenario']}_m{r['m']}_seed{r['seed']}": round(r["speedup_warm"], 3)
        for r in rows
        if r["scenario"] in ("wide_shallow", "fb_like") and r["m"] >= 50
    }
    payload = {
        "scheduler": "gdm",
        "seeds": list(_SEEDS),
        "device": rows[0]["device"],
        "rows": rows,
        "geomean_speedup_warm": float(np.exp(np.log(warm).mean())),
        "geomean_speedup_cold": float(np.exp(np.log(cold).mean())),
        "headline_warm_speedup_m50": headline,
        "note": ("speedups are python_us / jit_*_us; >1 means jit faster. "
                 "Targets (>=10x cold wide_shallow/fb_like at m>=50, >=2x "
                 "geomean) apply to TPU-attached runs where auto resolves "
                 "to jit; CPU rows record the XLA-CPU reality."),
    }
    common.save_json("BENCH_plan", payload)
    for r in rows:
        common.emit(
            f"plan_pipeline_{r['scenario']}_m{r['m']}_s{r['seed']}",
            r["jit_warm_us"],
            f"python_us={r['python_us']:.0f};jit_cold_us={r['jit_cold_us']:.0f};"
            f"speedup_warm={r['speedup_warm']:.2f}x;"
            f"speedup_cold={r['speedup_cold']:.2f}x;"
            f"compiles={r['compiles']};device={r['device']};identical=True",
            compile_ms=r["compile_ms"],
            steady_ms=r["jit_warm_us"] / 1e3,
            backend="plan:python-vs-jit",
        )
    common.emit(
        "plan_pipeline_geomean", 0.0,
        f"warm={payload['geomean_speedup_warm']:.2f}x;"
        f"cold={payload['geomean_speedup_cold']:.2f}x;"
        f"cases={len(rows)};device={payload['device']}",
        backend="plan:python-vs-jit")
    return payload
