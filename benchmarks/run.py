"""Benchmark entry point — one function per paper table/figure plus the
framework benchmarks. Prints
``name,us_per_call,compile_ms,steady_ms,backend,interpret,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run             # CI-sized (~15 min)
  PYTHONPATH=src python -m benchmarks.run --standard  # m up to 150 (~2 h)
  PYTHONPATH=src python -m benchmarks.run --paper     # published scale

The committed `benchmarks/results/*.json` + `bench_standard.log` +
`full_scale.json` hold the --standard and published-scale sweeps quoted in
EXPERIMENTS.md; the default profile re-validates every benchmark at a
CPU-minutes budget.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="(default profile)")
    ap.add_argument("--standard", action="store_true")
    ap.add_argument("--paper", action="store_true",
                    help="published workload scale (longest)")
    ap.add_argument("--only", default=None,
                    help="comma list: figs,online,beta,rsd,planner,kernels,"
                         "bna_batch,roofline,scenarios,plan_pipeline,serve,"
                         "analysis")
    ap.add_argument("--scenario", default=None,
                    help="comma list of scenario-registry keys for the "
                         "scenario x scheduler matrix (default: all "
                         "registered; implies the 'scenarios' section)")
    ap.add_argument("--alpha-backend", default=None,
                    choices=("auto", "numpy", "pallas"),
                    help="route merge_and_fix alphas through this backend "
                         "(default: REPRO_ALPHA_BACKEND or auto)")
    ap.add_argument("--bna-backend", default=None,
                    choices=("auto", "numpy", "pallas"),
                    help="route the batched BNA step through this backend "
                         "(default: REPRO_BNA_BACKEND or auto)")
    ap.add_argument("--plan-backend", default=None,
                    choices=("auto", "python", "jit"),
                    help="route the planning pipeline (order/decompose/"
                         "merge_and_fix) through this backend "
                         "(default: REPRO_PLAN_BACKEND or auto)")
    ap.add_argument("--matrix-seeds", type=int, default=1,
                    help="seeds per scenario in the scenario matrix; > 1 "
                         "batches the decomposition prefetch across the "
                         "whole seed set (one jit trace amortized)")
    ap.add_argument("--backfill-exec", default="packet",
                    choices=("packet", "ledger"),
                    help="backfill executor for the *_bf schedulers in the "
                         "scenario matrix (packet: timed-matching re-"
                         "execution, never worse than the plan; ledger: "
                         "historical uniform-rate sweep)")
    ap.add_argument("--driver", default="session",
                    choices=("session", "batch"),
                    help="online-protocol driver for the scenario matrix's "
                         "online rows (session: event-driven "
                         "SchedulerSession with frontier-append repair; "
                         "batch: historical closed loop — results-identical)")
    args = ap.parse_args()
    args.fast = not (args.standard or args.paper)

    if args.alpha_backend:
        from repro.core import set_alpha_backend
        set_alpha_backend(args.alpha_backend)
    if args.bna_backend:
        from repro.core import set_bna_backend
        set_bna_backend(args.bna_backend)
    if args.plan_backend:
        from repro.core import set_plan_backend
        set_plan_backend(args.plan_backend)

    if args.fast:
        scale, seeds, ms, mus, factors = 0.12, 2, (10, 30, 50), (2, 5, 10), (2, 25)
    elif args.paper:
        scale, seeds, ms, mus, factors = 1.0, 3, (10, 30, 50, 100, 150), \
            (2, 5, 10, 20), (1, 2, 10, 25, 100)
    else:
        scale, seeds, ms, mus, factors = 0.35, 2, (10, 30, 50, 100, 150), \
            (2, 5, 10), (2, 10, 100)

    want = set((args.only or
                "figs,online,beta,rsd,planner,kernels,roofline,scenarios,"
                "plan_pipeline,serve,analysis").split(","))
    if args.scenario:
        want.add("scenarios")
    from . import (analysis_bench, common, kernels_bench, paper_figs,
                   plan_pipeline, planner_ab, roofline_report,
                   scenario_matrix, serve_stream)

    if "figs" in want:
        paper_figs.workload_calibration(scale)
        paper_figs.fig_a(rooted=False, scale=scale, seeds=seeds, ms=ms)
        paper_figs.fig_a(rooted=True, scale=scale, seeds=seeds, ms=ms)
        paper_figs.fig_b(rooted=False, scale=scale, seeds=seeds, mus=mus)
        paper_figs.fig_b(rooted=True, scale=scale, seeds=seeds, mus=mus)
    online_m = 150 if args.paper else 50
    if "online" in want:
        paper_figs.fig_c(rooted=False, scale=min(scale, 0.2), factors=factors,
                         m=online_m)
        paper_figs.fig_c(rooted=True, scale=min(scale, 0.2), factors=factors,
                         m=online_m)
    if "beta" in want:
        paper_figs.fig4_beta(scale=min(scale, 0.25),
                             ms=(30, 150) if not args.fast else (30,))
    if "rsd" in want:
        paper_figs.rsd(scale=min(scale, 0.15), m=50)
    if "scenarios" in want:
        profile = "paper" if args.paper else ("standard" if args.standard
                                              else "fast")
        scenario_matrix.run(
            args.scenario.split(",") if args.scenario else None,
            profile=profile, backfill_exec=args.backfill_exec,
            driver=args.driver, seeds=args.matrix_seeds)
    if "plan_pipeline" in want:
        plan_pipeline.run(fast=args.fast)
    if "serve" in want:
        serve_stream.run(fast=args.fast)
    if "planner" in want:
        planner_ab.run()
    if "kernels" in want:
        kernels_bench.run(fast=args.fast)
    elif "bna_batch" in want:
        kernels_bench.run_bna_batch(fast=args.fast)
    if "analysis" in want:
        analysis_bench.run(fast=args.fast)
    if "roofline" in want:
        roofline_report.bna_batch_roofline()
        try:
            roofline_report.render()
        except FileNotFoundError:
            print("roofline: dryrun.json missing (run repro.launch.dryrun --all)")
    common.flush_csv()


if __name__ == "__main__":
    main()
