"""Per-kernel microbenchmarks: wall time per call (interpret mode on CPU —
functional timing, NOT TPU perf; the TPU roofline terms are derived
analytically from the tile shapes and reported as `derived`)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import emit, timed

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def flash_attention_bench():
    from repro.kernels.flash_attention import flash_attention

    B, Hq, Hkv, S, d = 1, 4, 2, 256, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Hq, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    out, us = timed(lambda: flash_attention(q, k, v, block_q=64, block_k=64)
                    .block_until_ready())
    flops = 4 * B * Hq * S * S * d          # 2 matmuls, fwd
    bytes_ = (q.size + k.size + v.size + out.size) * 4
    t_c, t_m = flops / PEAK_FLOPS, bytes_ / HBM_BW
    emit("kernel_flash_attention", us,
         f"tpu_compute_s={t_c:.2e};tpu_memory_s={t_m:.2e};"
         f"bound={'compute' if t_c > t_m else 'memory'}")


def ssd_scan_bench():
    from repro.kernels.ssd_scan import ssd_scan

    B, S, H, G, N, P = 1, 256, 4, 1, 64, 64
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.8, 1.0, size=(B, S, H)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    out, us = timed(lambda: ssd_scan(x, a, b, c, chunk=64).block_until_ready())
    L = 64
    nC = S // L
    flops = B * H * nC * (2 * L * L * N + 2 * L * L * P + 2 * L * N * P * 2)
    bytes_ = (x.size + a.size + b.size + c.size + out.size) * 4
    t_c, t_m = flops / PEAK_FLOPS, bytes_ / HBM_BW
    emit("kernel_ssd_scan", us,
         f"tpu_compute_s={t_c:.2e};tpu_memory_s={t_m:.2e};"
         f"bound={'compute' if t_c > t_m else 'memory'}")


def coflow_merge_bench():
    from repro.kernels.coflow_merge import interval_alphas

    rng = np.random.default_rng(0)
    E, K, m = 4000, 8192, 150
    t0 = rng.integers(0, K - 2, E)
    t1 = t0 + rng.integers(1, 64, E)
    si = np.minimum(t0, K - 1)
    ei = np.minimum(t1, K)
    s = rng.integers(0, m, E)
    r = rng.integers(0, m, E)
    out, us = timed(interval_alphas, si, ei, s, r, K, m)
    ports_pad = ((2 * m + 127) // 128) * 128
    bytes_ = K * ports_pad * 4 * 2          # read deltas + running counts
    t_m = bytes_ / HBM_BW
    emit("kernel_coflow_merge", us,
         f"tpu_memory_s={t_m:.2e};bound=memory (one pass, ~2 ops/byte)")


def run():
    flash_attention_bench()
    ssd_scan_bench()
    coflow_merge_bench()
