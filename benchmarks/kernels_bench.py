"""Per-kernel microbenchmarks: wall time per call (interpret mode on CPU —
functional timing, NOT TPU perf; the TPU roofline terms are derived
analytically from the tile shapes and reported as `derived`)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import emit, timed, timed2

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def flash_attention_bench():
    from repro.kernels.flash_attention import flash_attention

    B, Hq, Hkv, S, d = 1, 4, 2, 256, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Hq, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    out, us, c_ms, s_ms = timed2(
        lambda: flash_attention(q, k, v, block_q=64, block_k=64)
        .block_until_ready())
    flops = 4 * B * Hq * S * S * d          # 2 matmuls, fwd
    bytes_ = (q.size + k.size + v.size + out.size) * 4
    t_c, t_m = flops / PEAK_FLOPS, bytes_ / HBM_BW
    emit("kernel_flash_attention", us,
         f"tpu_compute_s={t_c:.2e};tpu_memory_s={t_m:.2e};"
         f"bound={'compute' if t_c > t_m else 'memory'}",
         compile_ms=c_ms, steady_ms=s_ms)


def ssd_scan_bench():
    from repro.kernels.ssd_scan import ssd_scan

    B, S, H, G, N, P = 1, 256, 4, 1, 64, 64
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.8, 1.0, size=(B, S, H)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    out, us, c_ms, s_ms = timed2(
        lambda: ssd_scan(x, a, b, c, chunk=64).block_until_ready())
    L = 64
    nC = S // L
    flops = B * H * nC * (2 * L * L * N + 2 * L * L * P + 2 * L * N * P * 2)
    bytes_ = (x.size + a.size + b.size + c.size + out.size) * 4
    t_c, t_m = flops / PEAK_FLOPS, bytes_ / HBM_BW
    emit("kernel_ssd_scan", us,
         f"tpu_compute_s={t_c:.2e};tpu_memory_s={t_m:.2e};"
         f"bound={'compute' if t_c > t_m else 'memory'}",
         compile_ms=c_ms, steady_ms=s_ms)


def coflow_merge_bench():
    from repro.kernels.coflow_merge import interval_alphas

    rng = np.random.default_rng(0)
    E, K, m = 4000, 8192, 150
    t0 = rng.integers(0, K - 2, E)
    t1 = t0 + rng.integers(1, 64, E)
    si = np.minimum(t0, K - 1)
    ei = np.minimum(t1, K)
    s = rng.integers(0, m, E)
    r = rng.integers(0, m, E)
    out, us, c_ms, s_ms = timed2(interval_alphas, si, ei, s, r, K, m)
    ports_pad = ((2 * m + 127) // 128) * 128
    bytes_ = K * ports_pad * 4 * 2          # read deltas + running counts
    t_m = bytes_ / HBM_BW
    emit("kernel_coflow_merge", us,
         f"tpu_memory_s={t_m:.2e};bound=memory (one pass, ~2 ops/byte)",
         compile_ms=c_ms, steady_ms=s_ms)


def backend_dispatch_bench():
    """merge_and_fix alpha computation through the engine's backend switch:
    numpy oracle vs the pallas kernel path, same EdgeIntervals input (the
    two must agree exactly; timings are CPU/interpret — functional only)."""
    from repro.core.backend import compute_alphas
    from repro.core.timeline import EdgeIntervals

    rng = np.random.default_rng(0)
    e, m = 3000, 64
    t0 = rng.integers(0, 4000, e)
    t1 = t0 + rng.integers(1, 128, e)
    edges = EdgeIntervals(t0.astype(np.int64), t1.astype(np.int64),
                          rng.integers(0, m, e).astype(np.int64),
                          rng.integers(0, m, e).astype(np.int64))
    events = np.unique(np.concatenate([t0, t1]))
    a_np, us_np = timed(compute_alphas, events, edges, m, "numpy")
    a_pl, us_pl, c_ms, s_ms = timed2(compute_alphas, events, edges, m,
                                     "pallas")
    assert np.array_equal(a_np, a_pl), "backend mismatch"
    emit("backend_alphas_numpy", us_np, f"K={events.size - 1}",
         backend="alpha:numpy", interpret=False)
    emit("backend_alphas_pallas", us_pl,
         "identical=True;note=interpret-mode timing, not TPU perf",
         compile_ms=c_ms, steady_ms=s_ms, backend="alpha:pallas")


def merge_fix_bench():
    """Fused merge_and_fix tail (kernels/merge_fix): alphas + expanded
    interval durations in one device round-trip, against the numpy oracle
    (bit-identical by construction)."""
    from repro.kernels.merge_fix import merge_fix_step
    from repro.kernels.merge_fix.ref import merge_fix_ref

    rng = np.random.default_rng(0)
    e, m = 3000, 64
    t0 = rng.integers(0, 4000, e)
    t1 = t0 + rng.integers(1, 128, e)
    s = rng.integers(0, m, e)
    r = rng.integers(0, m, e)
    events = np.unique(np.concatenate([t0, t1]))
    ref = merge_fix_ref(events, t0, t1, s, r, m)
    (al, de), us, c_ms, s_ms = timed2(merge_fix_step, events, t0, t1, s, r, m)
    assert np.array_equal(al, ref[0]) and np.array_equal(de, ref[1]), \
        "merge_fix fused step diverged from oracle"
    emit("kernel_merge_fix", us,
         f"K={events.size - 1};identical=True;"
         "note=interpret-mode timing, not TPU perf",
         compile_ms=c_ms, steady_ms=s_ms)


def cap_to_slack_bench():
    """Backfill inner loop: vectorized _cap_to_slack vs the scalar greedy
    reference on a shuffle_heavy/incast-shaped call (many edges, plentiful
    slack — the fast path that dominates every sweep interval), plus a
    conflict-heavy call that exercises the scalar fallback."""
    from repro.core.backfill import _cap_to_slack, _cap_to_slack_scalar

    rng = np.random.default_rng(0)
    m, e = 150, 2000
    srcs = rng.integers(0, m, e)
    dsts = rng.integers(0, m, e)
    want = rng.random(e) * 3
    wide_s = np.full(m, 100.0)
    wide_r = np.full(m, 100.0)
    tight_s = rng.random(m) * 2
    tight_r = rng.random(m) * 2
    for name, s0, r0 in (("wide", wide_s, wide_r), ("tight", tight_s, tight_r)):
        got_v, us_v = timed(lambda: _cap_to_slack(
            want, srcs, dsts, s0.copy(), r0.copy()))
        got_s, us_s = timed(lambda: _cap_to_slack_scalar(
            want, srcs, dsts, s0.copy(), r0.copy()))
        assert np.array_equal(got_v, got_s), "cap_to_slack fast path diverged"
        emit(f"backfill_cap_to_slack_{name}", us_v,
             f"scalar_us={us_s:.1f};speedup={us_s / max(us_v, 1e-9):.1f}x;"
             f"edges={e};m={m}")


def backfill_executor_bench():
    """Packet vs ledger backfill executors on a dense shuffle_heavy plan:
    wall time per re-execution plus the twct each executor reports (packet
    is pointwise <= the plan by construction)."""
    from repro import scenarios
    from repro.core import backfill, plan

    built = scenarios.build("shuffle_heavy", m=10, seed=0, scale=0.25)
    p = plan(built.instance, "gdm", seed=0)
    planned = p.twct()
    for q in p.schedule.parts:  # pre-build the lazy decomposition so both
        q.coflow_intervals()    # executors are timed per re-execution
    for exec_ in ("packet", "ledger"):
        bf, us = timed(backfill, p.schedule, True, exec_)
        emit(f"backfill_exec_{exec_}", us,
             f"twct={bf.twct():.0f};plan_twct={planned:.0f};"
             f"never_worse={bf.twct() <= planned + 1e-9}")


def engine_cache_bench():
    """Incremental online path vs from-scratch: same seeded workload, same
    twct by construction; derived reports the BNA-cache hit rate and the
    warm/cold wall-clock ratio (the ISSUE acceptance numbers)."""
    from repro.core import (clear_caches, paper_workload, plan_online,
                            poisson_releases, theta0)

    base = paper_workload(m=30, mu_bar=5, seed=0, scale=0.12)
    inst = poisson_releases(base, theta=2 * theta0(base), seed=0)
    clear_caches()
    inc = plan_online(inst, "gdm", seed=0)
    cold = plan_online(inst, "gdm", incremental=False, seed=0)
    assert abs(inc.twct() - cold.twct()) < 1e-9, "incremental path diverged"
    speedup = cold.stats["wall_s"] / max(inc.stats["wall_s"], 1e-12)
    emit("engine_online_incremental", inc.stats["wall_s"] * 1e6,
         f"bna_hit_pct={100 * inc.stats['bna']['hit_rate']:.1f};"
         f"order_hit_pct={100 * inc.stats['order']['hit_rate']:.1f};"
         f"speedup_vs_cold={speedup:.2f};reschedules={inc.reschedules}")


def session_repair_bench():
    """Frontier-append plan repair (core/session.py): a stream of arrivals
    landing on the clean cuts of the O(m)Alg sequential schedule, so every
    replan after the first takes the splice fast path.  Reports the repair
    hit rate and warm-replan wall-clock from SessionStats (the PR 1
    cache-stats precedent extended), against the repair-disabled session —
    results are identical by construction; only planning time differs.
    Coflows are wide (dense permutation mixes), the shape where the splice
    pays: a full replan rebuilds every retained coflow's BNA edge intervals,
    the repair only slices the retained expansion."""
    from repro.core import (Coflow, Instance, Job, clear_caches,
                            simulate_online)
    from repro.core.session import SchedulerSession

    rng = np.random.default_rng(0)
    m, base, appends = 24, 16, 12
    jobs = [Job(k, [Coflow(k, 0, _wide_demand(rng, m, 8 + 2 * k))], [],
                weight=1.0, release=0) for k in range(base)]
    # each append lands exactly on the next clean cut — the earliest planned
    # completion on the probe session's live frontier (the event API driving
    # its own workload generation)
    probe = SchedulerSession(m, "om_alg")
    for j in jobs:
        probe.submit(j)
    size, w = 60, 0.05
    for a in range(appends):
        f = probe.frontier()
        t = min(v for v in f.completions.values())
        jid = base + a
        job = Job(jid, [Coflow(jid, 0, _wide_demand(rng, m, size))], [],
                  weight=w, release=int(t))
        jobs.append(job)
        probe.advance(until=t)
        probe.submit(job)
        size, w = size + 2, w / 2
    inst = Instance(m, jobs)
    clear_caches()
    on, us_on = timed(lambda: simulate_online(inst, "om_alg",
                                              driver="session"))
    clear_caches()
    off, us_off = timed(lambda: simulate_online(inst, "om_alg",
                                                driver="session",
                                                repair=False))
    assert on.job_completions == off.job_completions, "repair diverged"
    s_on, s_off = on.stats["session"], off.stats["session"]
    emit("session_repair", us_on,
         f"repairs={s_on['repairs']};"
         f"repair_hit_pct={100 * s_on['repair_hit_rate']:.0f};"
         f"warm_replan_ms={1e3 * s_on['warm_replan_wall_s']:.2f};"
         f"full_replan_warm_ms={1e3 * s_off['warm_replan_wall_s']:.2f};"
         f"warm_speedup={s_off['warm_replan_wall_s'] / max(s_on['warm_replan_wall_s'], 1e-12):.2f}x;"
         f"identical=True")


def group_cache_bench():
    """Group-block cache (PR 10): under a session-pinned gamma, streaming
    full replans reassemble untouched geometric groups from cached
    origin-0 DMA blocks (backend.group_block) instead of rebuilding them.
    Reports the group-cache hit/miss traffic, the grouping-prefix cumsum
    counters (exact / extended / cold), and the cached vs cache-bypassed
    online wall clock on the same trace — completions are identical by
    construction (translation invariance)."""
    from repro.core import (Instance, backend, clear_caches, simulate_online,
                            stream_jobs)

    jobs = stream_jobs(8, 120, 7, process="poisson", load=1.0, mu=2)
    inst = Instance(8, list(jobs))
    clear_caches()
    on, us_on = timed(lambda: simulate_online(inst, "gdm", delays="spread",
                                              seed=0, gamma="pinned"))
    g = on.stats["group"]
    pref = backend.cache_stats()["gkey"]["prefix"]
    with backend.no_caches():
        off, us_off = timed(lambda: simulate_online(inst, "gdm",
                                                    delays="spread", seed=0,
                                                    gamma="pinned"))
    assert on.job_completions == off.job_completions, "group cache diverged"
    emit("group_block_cache", us_on,
         f"group_hits={g['hits']};group_misses={g['misses']};"
         f"group_hit_pct={100 * g['hit_rate']:.1f};"
         f"gkey_exact={pref['exact']};gkey_extended={pref['extended']};"
         f"gkey_cold={pref['cold']};"
         f"repair_hit_pct={100 * on.stats['session']['repair_hit_rate']:.0f};"
         f"nocache_us={us_off:.0f};"
         f"speedup={us_off / max(us_on, 1e-9):.2f}x;identical=True")


def _wide_demand(rng, m, units):
    """units per edge over several random permutations: effective size ==
    units * n_perms, every port busy (the dense shape BNA pieces blow up on)."""
    d = np.zeros((m, m), np.int64)
    for _ in range(4):
        d[np.arange(m), rng.permutation(m)] += units
    np.fill_diagonal(d, 0)
    return d


def bna_batch_bench(fast: bool = True):
    """Batched multi-coflow BNA (core/matching.py) vs the scalar per-coflow
    loop, scaling the batch K toward 1e5 (the full-trace coflow count).
    Scalar wall-clock is measured on a sample and extrapolated past
    SCALAR_CAP so the sweep stays CI-cheap; piece-level bit-identity is
    asserted on the sampled prefix.  A pallas-backend parity point runs the
    same batch through the bna_step kernel (interpret-mode timing on CPU —
    functional only, the TPU term is in the roofline report)."""
    from repro.core import backend
    from repro.core.bna import bna
    from repro.core.matching import bna_many

    rng = np.random.default_rng(0)
    w, density, scalar_cap = 8, 0.6, 512
    Ks = (256, 2048, 16384) if fast else (1024, 8192, 65536, 100_000)

    def make(K):
        out = []
        for _ in range(K):
            d = rng.integers(1, 60, size=(w, w))
            d[rng.random((w, w)) > density] = 0
            out.append(d)
        return out

    for K in Ks:
        demands = make(K)
        with backend.use_bna_backend("numpy"):
            many, us_b = timed(bna_many, demands)
        n_s = min(K, scalar_cap)
        ref, us_s = timed(lambda: [bna(d) for d in demands[:n_s]])
        for a, b in zip(many, ref):
            assert len(a) == len(b) and all(
                x == y and np.array_equal(p, q)
                for (x, p), (y, q) in zip(a, b)), "bna_many diverged"
        us_scalar_est = us_s * (K / n_s)
        emit(f"bna_batch_K{K}", us_b,
             f"scalar_est_us={us_scalar_est:.0f};"
             f"speedup={us_scalar_est / max(us_b, 1e-9):.1f}x;"
             f"w={w};identical=True"
             + ("" if K == n_s else f";scalar_sampled_n={n_s}"),
             backend="bna:numpy", interpret=False)

    demands = make(96)
    with backend.use_bna_backend("numpy"):
        ref = bna_many(demands)
    with backend.use_bna_backend("pallas"):
        got, us_pl, c_ms, s_ms = timed2(
            lambda: (backend.clear_caches() or bna_many(demands)))
    for a, b in zip(got, ref):
        assert len(a) == len(b) and all(
            x == y and np.array_equal(p, q)
            for (x, p), (y, q) in zip(a, b)), "pallas bna_step diverged"
    emit("bna_batch_pallas", us_pl,
         "identical=True;note=interpret-mode timing, not TPU perf",
         compile_ms=c_ms, steady_ms=s_ms, backend="bna:pallas")


def bna_batch_planning_bench(fast: bool = True):
    """The ISSUE acceptance number: cold-start planning wall-clock on a
    BNA-bound scenario with the instance-level batch prefetch on vs off
    (REPRO_BNA_BATCH).  Plans are results-identical by construction; the
    target is >= 2x, reported explicitly as ``meets_2x_target`` (best of 3
    cold runs per side in fast mode, best of 2 at --standard/--paper; not
    asserted — a loaded CI runner can depress the ratio, but a regression
    is visible in the committed CSV).  Fast mode
    uses incast — the most robustly BNA-bound CI-cheap shape (all senders
    hammer few receivers, so matching dominates and the merge/ordering
    overhead that dilutes the ratio is minimal); --standard/--paper use
    fb_like at larger m, the ISSUE's headline shape."""
    from repro import scenarios
    from repro.core import clear_caches, plan
    from repro.core.backend import config

    scen, kw = ("incast", dict(m=16, scale=1.5)) if fast \
        else ("fb_like", dict(m=30, scale=0.5))
    built = scenarios.build(scen, seed=0, **kw)
    prev = config.bna_batch
    try:
        # warm numpy/jit import costs out of the comparison
        config.bna_batch = True
        clear_caches()
        plan(built.instance, "gdm", seed=0)
        best = {}
        twct = {}
        for batch in (False, True):
            config.bna_batch = batch
            best[batch] = np.inf
            for _ in range(3 if fast else 2):
                clear_caches()
                p, us = timed(plan, built.instance, "gdm", seed=0)
                best[batch] = min(best[batch], us)
            twct[batch] = p.twct()
    finally:
        config.bna_batch = prev
    assert twct[False] == twct[True], "batch prefetch changed the plan"
    n_cof = sum(j.mu for j in built.instance.jobs)
    speedup = best[False] / max(best[True], 1e-9)
    emit("bna_batch_planning", best[True],
         f"off_us={best[False]:.0f};speedup={speedup:.2f}x;"
         f"meets_2x_target={speedup >= 2.0};"
         f"scenario={scen};m={built.instance.m};coflows={n_cof};"
         f"identical=True", interpret=False)


def run_bna_batch(fast: bool = True):
    bna_batch_bench(fast)
    bna_batch_planning_bench(fast)


def run(fast: bool = True):
    flash_attention_bench()
    ssd_scan_bench()
    coflow_merge_bench()
    backend_dispatch_bench()
    merge_fix_bench()
    cap_to_slack_bench()
    backfill_executor_bench()
    engine_cache_bench()
    session_repair_bench()
    group_cache_bench()
    run_bna_batch(fast)
