"""Static-analysis smoke: time the full-repo contract scan so the pass's
own cost is tracked in benchmarks.csv alongside the things it guards.

Three rows: the file-scope AST rules alone (pure parsing + visitors),
the program-scope dataflow rules alone (interval engine + taint + call
graph — the PR-9 layer), and the full scan including the inspect-based
registry-consistency rule (which imports the live registries and builds
every scenario at small scale).  The full-scan wall time is written to
``benchmarks/results/BENCH_analysis.json`` and asserted under the CI
budget — the analyzer guards every PR, so its cost is itself a
regression surface.
"""
from __future__ import annotations

import sys
from pathlib import Path

from repro.analysis import names, scan_paths

from .common import emit, save_json, timed2

ROOT = Path(__file__).resolve().parents[1]

# hard CI budget for one full --strict scan (seconds); the gate runs on
# every PR, so analyzer slowdowns past this fail the analysis bench job
BUDGET_S = 30.0

_DATAFLOW = ["overflow-range", "tracer-taint", "cache-key"]


def run(fast: bool = True) -> None:
    paths = [ROOT / "src", ROOT / "benchmarks"]
    file_rules = [n for n in names()
                  if n != "registry-consistency" and n not in _DATAFLOW]

    rep, us, comp, steady = timed2(
        scan_paths, paths, root=ROOT, rules=file_rules, reps=2 if fast else 3)
    emit("analysis_file_rules", us,
         f"files={rep.n_files};rules={len(file_rules)};"
         f"findings={len(rep.unsuppressed)};suppressed={len(rep.suppressed)}",
         compile_ms=comp, steady_ms=steady, backend="python",
         interpret=False)

    rep, us, comp, steady = timed2(
        scan_paths, paths, root=ROOT, rules=_DATAFLOW, reps=2 if fast else 3)
    emit("analysis_dataflow_rules", us,
         f"files={rep.n_files};rules={len(_DATAFLOW)};"
         f"findings={len(rep.unsuppressed)};suppressed={len(rep.suppressed)}",
         compile_ms=comp, steady_ms=steady, backend="python",
         interpret=False)
    dataflow_ms = steady

    rep, us, comp, steady = timed2(
        scan_paths, paths, root=ROOT, project=True, reps=2 if fast else 3)
    emit("analysis_full_repo_scan", us,
         f"files={rep.n_files};rules={len(names())};"
         f"findings={len(rep.unsuppressed)};suppressed={len(rep.suppressed)}",
         compile_ms=comp, steady_ms=steady, backend="python",
         interpret=False)

    wall_s = steady / 1e3
    payload = {
        "files": rep.n_files,
        "rules": len(names()),
        "findings": len(rep.unsuppressed),
        "suppressed": len(rep.suppressed),
        "dataflow_rules_ms": round(dataflow_ms, 2),
        "full_scan_ms": round(steady, 2),
        "budget_s": BUDGET_S,
        "within_budget": wall_s < BUDGET_S,
    }
    save_json("BENCH_analysis", payload)
    if rep.unsuppressed:
        print(f"analysis: WARNING {len(rep.unsuppressed)} unsuppressed "
              "finding(s) — the static-analysis CI gate will fail")
    if wall_s >= BUDGET_S:
        print(f"analysis: FAIL full scan took {wall_s:.1f}s "
              f">= {BUDGET_S:.0f}s budget", file=sys.stderr)
        sys.exit(1)
    print(f"analysis: full scan {wall_s:.2f}s "
          f"(dataflow {dataflow_ms / 1e3:.2f}s) within {BUDGET_S:.0f}s budget")


if __name__ == "__main__":
    run()
