"""Static-analysis smoke: time the full-repo contract scan so the pass's
own cost is tracked in benchmarks.csv alongside the things it guards.

Two rows: the file-scope AST rules alone (pure parsing + visitors), and
the full scan including the inspect-based registry-consistency rule
(which imports the live registries and builds every scenario at small
scale — the dominant cost)."""
from __future__ import annotations

from pathlib import Path

from repro.analysis import names, scan_paths

from .common import emit, timed2

ROOT = Path(__file__).resolve().parents[1]


def run(fast: bool = True) -> None:
    paths = [ROOT / "src", ROOT / "benchmarks"]
    file_rules = [n for n in names() if n != "registry-consistency"]

    rep, us, comp, steady = timed2(
        scan_paths, paths, root=ROOT, rules=file_rules, reps=2 if fast else 3)
    emit("analysis_file_rules", us,
         f"files={rep.n_files};rules={len(file_rules)};"
         f"findings={len(rep.unsuppressed)};suppressed={len(rep.suppressed)}",
         compile_ms=comp, steady_ms=steady, backend="python",
         interpret=False)

    rep, us, comp, steady = timed2(
        scan_paths, paths, root=ROOT, project=True, reps=2 if fast else 3)
    emit("analysis_full_repo_scan", us,
         f"files={rep.n_files};rules={len(names())};"
         f"findings={len(rep.unsuppressed)};suppressed={len(rep.suppressed)}",
         compile_ms=comp, steady_ms=steady, backend="python",
         interpret=False)
    if rep.unsuppressed:
        print(f"analysis: WARNING {len(rep.unsuppressed)} unsuppressed "
              "finding(s) — the static-analysis CI gate will fail")


if __name__ == "__main__":
    run()
