"""Sustained-arrivals serve bench: scheduling latency + sustainable rate.

Drives the :class:`repro.core.stream.StreamDriver` harness over fixed-seed
heavy-tail traces (Pareto coflow sizes, `stream_jobs`) under Poisson and
bursty MMPP arrival processes, for the three session-native schedulers
(om_alg, G-DM spread, G-DM-RT spread).  Each pure cell is cross-checked
bit-identical against ``simulate_online(driver="batch")`` on the same
trace, and reports:

* p50/p95/p99 per-arrival scheduling latency (submit + replan wall),
* sustained jobs/sec of the whole feed+drain loop,
* repair / full-replan / deferral / reject counts from ``SessionStats``.

Three extra cell groups quantify the repair-certification fixes, the
PR-10 pinned-gamma epochs, and the backpressure policy:

* ``gamma="pinned"`` cells re-run every G-DM/G-DM-RT spread trace with
  the session-stable grouping scale (core/gdm.py GammaEpoch) — the
  pure-mode repair-hit-rate lift and the p95 latency delta vs the
  residual-gamma cells are the PR-10 headline, and each pinned cell is
  still asserted bit-identical to its own pinned batch comparator.  The
  pinned pure cells must clear ``_PINNED_HIT_FLOOR`` (the CI gate).
* ``repair="legacy"`` cells re-run the G-DM/G-DM-RT spread traces under
  the pre-generalization certification gate (singleton groups, gdm only)
  — the before/after repair-hit-rate delta was PR 7's headline.
* an overload cell (load > 1, MMPP) attaches an
  :class:`~repro.core.session.AdmissionPolicy` and records deferrals,
  rejects, and the windowed replan debt the policy budgets on.

Fast mode (the ``serve-stream`` CI job) pumps ~1e4 jobs total through
live sessions across the cells — om_alg carries the arrival volume, the
G-DM cells run shorter prefixes at the same load, and every pure cell's
batch comparator re-drives the same trace; ``--standard``/``--paper``
scale cells 10x.  The harness is O(n) in arrivals with a backlog-bounded
active set at load < 1, so 1e5-1e6-job soaks are a sizing knob
(``run(n_jobs=...)``), not a code path.  Results land in
``benchmarks/results/BENCH_serve.json``.
"""
from __future__ import annotations

import numpy as np

from repro.core import AdmissionPolicy, Instance, simulate_online, stream_jobs
from repro.core.stream import StreamDriver

from . import common

_M = 8
_MU = 2
# (label, registry name, opts, fast-mode jobs): near-critical load makes the
# per-replan cost track the backlog excursion, so the cheap job-sequential
# om_alg carries the arrival volume while the G-DM cells run a shorter
# prefix of the same generator family at the same load
_SCHEDULERS = [
    ("om_alg", "om_alg", {}, 1_000),
    ("gdm_spread", "gdm", {"delays": "spread", "seed": 0}, 250),
    ("gdm_rt_spread", "gdm_rt", {"delays": "spread", "seed": 0}, 250),
]
_TRACE_SEED = 7
_LOAD = 0.9
_OVERLOAD = 2.0
# CI floor for the pinned-gamma pure cells' repair hit rate: the tentpole
# target (residual-gamma cells sat at ~6-8% before pinning)
_PINNED_HIT_FLOOR = 0.4


def _trace(n_jobs: int, process: str, load: float = _LOAD):
    return stream_jobs(_M, n_jobs, _TRACE_SEED, process=process, load=load,
                       mu=_MU)


def _cell(name: str, jobs, sched: str, opts: dict, *,
          repair: "bool | str" = True,
          admission: AdmissionPolicy | None = None,
          gamma: "str | int" = "residual",
          check_batch: bool = True) -> dict:
    drv = StreamDriver(_M, sched, repair=repair, admission=admission,
                       gamma=gamma, **opts)
    for j in jobs:
        drv.feed(j)
    res = drv.result()
    row = {"cell": name, "scheduler": sched, "n_jobs": len(jobs),
           "gamma": gamma, **res.as_dict()}
    if "group" in res.online.stats:   # group-block cache traffic this cell
        row["group_cache"] = res.online.stats["group"]
    if check_batch:
        batch = simulate_online(Instance(_M, list(jobs)), sched,
                                driver="batch", gamma=gamma, **opts)
        row["identical_to_batch"] = (
            res.online.job_completions == batch.job_completions
            and res.online.twct() == batch.twct())
        assert row["identical_to_batch"], f"stream/batch divergence in {name}"
    return row


def run(fast: bool = True, n_jobs: int | None = None) -> dict:
    scale = 1 if fast else 10
    rows: list[dict] = []

    for process in ("poisson", "mmpp"):
        for label, sched, opts, n_fast in _SCHEDULERS:
            n = n_jobs if n_jobs is not None else n_fast * scale
            jobs = _trace(n, process)
            rows.append(_cell(f"{process}_{label}", jobs, sched, opts))
            if sched != "om_alg":
                # PR-10 A/B: same trace under the session-pinned gamma
                rows.append(_cell(f"{process}_{label}_pinned", jobs, sched,
                                  opts, gamma="pinned"))

    # before/after for the two certification fixes: same poisson trace,
    # pre-generalization gate (legacy) vs the grouped certification
    for label, sched, opts, n_fast in _SCHEDULERS[1:]:
        n = n_jobs if n_jobs is not None else n_fast * scale
        rows.append(_cell(f"legacy_{label}", _trace(n, "poisson"), sched,
                          opts, repair="legacy", check_batch=False))

    # overload: load > 1 bursty arrivals with admission control
    policy = AdmissionPolicy(max_pending=16, replan_budget=0.4, window=16)
    jobs_o = _trace(60 * scale, "mmpp", load=_OVERLOAD)
    rows.append(_cell("overload_mmpp_gdm_spread", jobs_o, "gdm",
                      {"delays": "spread", "seed": 0}, admission=policy,
                      check_batch=False))

    by_cell = {r["cell"]: r for r in rows}
    hit = lambda c: by_cell[c]["session_repair_hit_rate"]
    deltas = {
        f"{label}_hit_rate_fixed_vs_legacy":
            [round(hit(f"poisson_{label}"), 4), round(hit(f"legacy_{label}"), 4)]
        for label, _, _, _ in _SCHEDULERS[1:]
    }
    # PR-10 A/B: pinned vs residual gamma, per process x scheduler — the
    # pure-mode hit-rate lift (CI-floored) and the p95 latency delta
    pinned_ab = {}
    for process in ("poisson", "mmpp"):
        for label, _, _, _ in _SCHEDULERS[1:]:
            res_c, pin_c = f"{process}_{label}", f"{process}_{label}_pinned"
            pinned_ab[pin_c] = {
                "hit_rate_pinned_vs_residual":
                    [round(hit(pin_c), 4), round(hit(res_c), 4)],
                "p95_ms_pinned_vs_residual":
                    [round(by_cell[pin_c]["p95_ms"], 3),
                     round(by_cell[res_c]["p95_ms"], 3)],
                "gamma_rescales": by_cell[pin_c]["session_gamma_rescales"],
            }
            assert hit(pin_c) >= _PINNED_HIT_FLOOR, (
                f"{pin_c}: pinned-gamma pure-mode repair hit rate "
                f"{hit(pin_c):.3f} fell below the {_PINNED_HIT_FLOOR} floor")
            assert hit(pin_c) > hit(res_c), (
                f"{pin_c}: pinning must lift the hit rate over the "
                f"residual-gamma cell ({hit(pin_c):.3f} <= {hit(res_c):.3f})")
    backend, interpret = common.provenance()
    payload = {
        "m": _M, "mu": _MU, "trace_seed": _TRACE_SEED,
        "load": _LOAD, "overload": _OVERLOAD,
        "backend": backend, "interpret": interpret,
        "jobs_pumped": int(sum(r["offered"] for r in rows)),
        "admission_policy": {"max_pending": policy.max_pending,
                             "replan_budget": policy.replan_budget,
                             "window": policy.window},
        "rows": rows,
        "hit_rate_deltas": deltas,
        "pinned_vs_residual": pinned_ab,
        "pinned_hit_floor": _PINNED_HIT_FLOOR,
        "note": ("pure cells (no admission) are asserted bit-identical to "
                 "simulate_online(driver='batch') on the same trace — "
                 "including the gamma='pinned' cells, whose batch "
                 "comparator pins identically; pinned cells must clear the "
                 "pinned_hit_floor pure-mode repair hit rate (the PR-10 "
                 "gamma-stability payoff, CI-gated); legacy cells re-run "
                 "the pre-generalization repair gate — the hit-rate delta "
                 "is the certification-bugfix payoff; the overload cell "
                 "exercises deferral/reject backpressure, which trades "
                 "schedule optimality for replan-rate stability and is not "
                 "batch-identical by design."),
    }
    common.save_json("BENCH_serve", payload)
    for r in rows:
        common.emit(
            f"serve_{r['cell']}",
            r["p50_ms"] * 1e3,
            f"p95_ms={r['p95_ms']:.2f};p99_ms={r['p99_ms']:.2f};"
            f"jobs_per_sec={r['jobs_per_sec']:.1f};"
            f"hit_rate={r['session_repair_hit_rate']:.3f};"
            f"repairs={r['session_repairs']};"
            f"full_replans={r['session_full_replans']};"
            f"deferred={r['deferred']};rejected={r['rejected']};"
            f"identical={r.get('identical_to_batch', 'n/a')}",
            steady_ms=r["p50_ms"],
        )
    return payload


if __name__ == "__main__":
    run()
    common.flush_csv("serve_stream")
