"""Scenario x scheduler matrix over the workload zoo (repro.scenarios).

For every selected scenario, plans the instance with every registered
scheduler and emits one CSV row per (scenario, scheduler) pair plus a
per-scenario summary row carrying the paper's headline metric (percent TWCT
improvement of G-DM+backfill over O(m)Alg+backfill) — showing how relative
algorithm performance shifts across trace shapes, which a single
FB-calibrated trace cannot.

Scenarios with an online arrival model additionally run the §VII-C.2
rescheduling protocol through the selected ``driver`` (``session``: the
event-driven SchedulerSession, frontier-append repair enabled; ``batch``:
the historical closed loop — the two are results-identical, the
`session-equivalence` CI job pins it).
"""
from __future__ import annotations

from repro import scenarios
from repro.core import available_schedulers, plan, simulate_online

from . import common

_ONLINE_SCHEDULERS = ("gdm", "om_alg")


def run(scenario_names: list[str] | None = None, profile: str = "fast",
        seed: int = 0, backfill_exec: str = "packet",
        driver: str = "session") -> None:
    names = scenario_names or scenarios.names()
    for scen in names:
        built = common.build_scenario(scen, profile=profile, seed=seed)
        twcts: dict[str, float] = {}
        for sched in sorted(available_schedulers()):
            opts = scenarios.scheduler_opts(sched, built.meta)
            if sched.endswith("_bf"):
                opts["exec"] = backfill_exec
            p, us = common.timed(plan, built.instance, sched, seed=seed, **opts)
            twcts[sched] = p.twct()
            common.emit(f"scenario_{scen}_{sched}", us,
                        f"twct={p.twct():.0f} makespan={p.makespan:.0f}")
        if twcts.get("om_alg_bf"):
            gain = 100 * (1 - twcts["gdm_bf"] / twcts["om_alg_bf"])
            common.emit(f"scenario_{scen}_summary", 0.0,
                        f"gdm_bf_vs_om_alg_bf_pct={gain:.1f}")
        if built.meta.arrival != "offline":
            for sched in _ONLINE_SCHEDULERS:
                opts = scenarios.scheduler_opts(sched, built.meta)
                r, us = common.timed(simulate_online, built.instance, sched,
                                     driver=driver, seed=seed, **opts)
                extra = ""
                if "session" in r.stats:
                    s = r.stats["session"]
                    extra = (f";repairs={s['repairs']}"
                             f";repair_hit_pct={100 * s['repair_hit_rate']:.0f}")
                common.emit(f"online_{scen}_{sched}_{driver}", us,
                            f"twct={r.twct():.0f}"
                            f";reschedules={r.reschedules}{extra}")
