"""Scenario x scheduler matrix over the workload zoo (repro.scenarios).

For every selected scenario, plans the instance with every registered
scheduler and emits one CSV row per (scenario, scheduler) pair plus a
per-scenario summary row carrying the paper's headline metric (percent TWCT
improvement of G-DM+backfill over O(m)Alg+backfill) — showing how relative
algorithm performance shifts across trace shapes, which a single
FB-calibrated trace cannot.

Scenarios with an online arrival model additionally run the §VII-C.2
rescheduling protocol through the selected ``driver`` (``session``: the
event-driven SchedulerSession, frontier-append repair enabled; ``batch``:
the historical closed loop — the two are results-identical, the
`session-equivalence` CI job pins it).

With ``seeds > 1`` the matrix runs every (scenario, scheduler) cell at
``seed .. seed + seeds - 1`` and emits the per-cell mean; before planning,
the decomposition prefetch is issued ONCE over the union of all seeds'
coflow demands, so the jitted pipeline amortizes a single trace/compile
(and the numpy path one batched BNA pass) across the whole seed batch —
the vmapped bucket decomposition sees one big (B, w, w) stack instead of
``seeds`` small ones.
"""
from __future__ import annotations

import numpy as np

from repro import scenarios
from repro.core import available_schedulers, plan, prefetch_plan, simulate_online

from . import common

_ONLINE_SCHEDULERS = ("gdm", "om_alg")


def run(scenario_names: list[str] | None = None, profile: str = "fast",
        seed: int = 0, backfill_exec: str = "packet",
        driver: str = "session", seeds: int = 1) -> None:
    names = scenario_names or scenarios.names()
    seed_list = list(range(seed, seed + max(1, seeds)))
    for scen in names:
        builts = [common.build_scenario(scen, profile=profile, seed=s)
                  for s in seed_list]
        if len(builts) > 1:
            # one batched prefetch across the whole seed set: every later
            # per-seed plan call hits the decomposition caches
            demands = [c.demand for b in builts for j in b.instance.jobs
                       for c in j.coflows]
            prefetch_plan(demands)
        built = builts[0]
        twcts: dict[str, float] = {}
        for sched in sorted(available_schedulers()):
            opts = scenarios.scheduler_opts(sched, built.meta)
            if sched.endswith("_bf"):
                opts["exec"] = backfill_exec
            us_all, tw_all, mk_all = [], [], []
            for s, b in zip(seed_list, builts):
                p, us = common.timed(plan, b.instance, sched, seed=s, **opts)
                us_all.append(us)
                tw_all.append(p.twct())
                mk_all.append(p.makespan)
            twcts[sched] = float(np.mean(tw_all))
            tag = "" if len(builts) == 1 else f" seeds={len(builts)}"
            common.emit(f"scenario_{scen}_{sched}", float(np.mean(us_all)),
                        f"twct={np.mean(tw_all):.0f} "
                        f"makespan={np.mean(mk_all):.0f}{tag}")
        if twcts.get("om_alg_bf"):
            gain = 100 * (1 - twcts["gdm_bf"] / twcts["om_alg_bf"])
            common.emit(f"scenario_{scen}_summary", 0.0,
                        f"gdm_bf_vs_om_alg_bf_pct={gain:.1f}")
        if built.meta.arrival != "offline":
            for sched in _ONLINE_SCHEDULERS:
                opts = scenarios.scheduler_opts(sched, built.meta)
                r, us = common.timed(simulate_online, built.instance, sched,
                                     driver=driver, seed=seed, **opts)
                extra = ""
                if "session" in r.stats:
                    s = r.stats["session"]
                    extra = (f";repairs={s['repairs']}"
                             f";repair_hit_pct={100 * s['repair_hit_rate']:.0f}")
                common.emit(f"online_{scen}_{sched}_{driver}", us,
                            f"twct={r.twct():.0f}"
                            f";reschedules={r.reschedules}{extra}")
