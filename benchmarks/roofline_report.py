"""§Roofline report: renders benchmarks/results/dryrun.json into the
per-(arch x shape x mesh) three-term table, computes MODEL_FLOPS (analytic
6*N*D / 2*N_active*D + attention terms) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, and names the dominant bottleneck.

Also carries the analytic TPU roofline for the ``bna_step`` matching kernel
(`bna_batch_roofline`): per-step bytes/flops at batch sizes K -> 1e5,
independent of dryrun.json.

Interpret-mode rows: when the kernels run under the Pallas interpreter
(CPU emulation, no TPU attached) the measured wall times in
``benchmarks.csv`` say nothing about hardware.  `flag_interpret_rows`
scans the recorded rows and marks every measured kernel row whose
``interpret`` column is true — those rows keep their analytic TPU terms in
`derived` but are explicitly excluded from any measured-vs-roofline
comparison."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.models.common import ArchConfig

from .common import RESULTS, emit, save_json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analytic_params(cfg: ArchConfig) -> tuple[float, float]:
    """(total, active) parameter counts from the config (transparent math,
    no tracing)."""
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = cfg.n_heads, cfg.n_kv_heads

    def attn():
        p = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        if cfg.qkv_bias:
            p += hq * dh + 2 * hkv * dh
        return p + d  # norm

    def mlp_dense():
        return 3 * d * cfg.d_ff + d

    def moe():
        s = cfg.moe
        total = d * s.n_experts + s.n_experts * 3 * d * s.d_ff_expert + d
        active = d * s.n_experts + s.top_k * 3 * d * s.d_ff_expert + d
        return total, active

    def mamba():
        s = cfg.ssm
        d_in = s.expand * d
        H = d_in // s.d_head
        gn = s.n_groups * s.d_state
        conv_ch = d_in + 2 * gn
        p = d * (2 * d_in + 2 * gn + H) + s.d_conv * conv_ch + conv_ch \
            + 3 * H + d_in + d_in * d + d
        return p

    total = active = 0.0
    for spec in cfg.period:
        if spec.kind == "attn":
            total += attn()
            active += attn()
        else:
            total += mamba()
            active += mamba()
        if spec.mlp == "dense":
            total += mlp_dense()
            active += mlp_dense()
        elif spec.mlp == "moe":
            t, a = moe()
            total += t
            active += a
    total *= cfg.n_periods
    active *= cfg.n_periods
    if cfg.family == "encdec":
        enc = (attn() + mlp_dense()) * cfg.n_encoder_layers
        dec_cross = (d * hq * dh + 2 * d * hkv * dh + hq * dh * d + d) * cfg.n_periods
        total += enc + dec_cross
        active += enc + dec_cross
    emb = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    total += emb
    active += emb
    return total, active


def model_flops_per_chip(cfg: ArchConfig, shape_name: str, chips: int) -> float:
    """Useful FLOPs per chip per step: 6*N_active*D for training (fwd 2 +
    bwd 4), 2*N_active*D forward-only for prefill, 2*N_active per token for
    decode — plus the causal-attention term where attention exists."""
    sh = SHAPES[shape_name]
    S, B = sh.seq_len, sh.global_batch
    total, active = analytic_params(cfg)
    n_attn = sum(1 for s in cfg.period if s.kind == "attn") * cfg.n_periods
    hq, dh = cfg.n_heads, cfg.d_head

    if sh.kind == "train":
        tokens = S * B
        base = 6 * active * tokens
        attn = 3 * n_attn * 4 * B * (S * S / 2) * hq * dh  # fwd+bwd(2x)
    elif sh.kind == "prefill":
        tokens = S * B
        base = 2 * active * tokens
        attn = n_attn * 4 * B * (S * S / 2) * hq * dh
    else:  # decode: one token against an S-length cache
        tokens = B
        base = 2 * active * tokens
        attn = n_attn * 4 * B * S * hq * dh
    return (base + attn) / chips


def bna_batch_roofline(Ks=(1_000, 10_000, 100_000), w: int = 16) -> None:
    """Analytic TPU three-term roofline for one `bna_step` kernel call at
    batch size K over width-w matrices (int32 tiles, lanes padded to 128).

    Per matrix and step the kernel streams the (w, w) demand tile in and
    out, plus the (w,)-state rows (row/col/match in, row/col/piece/invalid
    out) and the D/t scalars; the arithmetic is ~6 VPU ops per demand
    element (one-hot compare, masked sum, subtract, three masked mins
    amortized).  Intensity ~3 ops/byte: memory-bound like coflow_merge —
    which is the design point, the kernel exists so the step's HBM pass is
    amortized across the whole batch instead of K separate scalar walks."""
    w_pad = ((w + 127) // 128) * 128
    for K in Ks:
        bytes_ = K * (2 * w * w_pad + 7 * w_pad + 4) * 4
        flops = K * (6 * w * w_pad + 10 * w_pad)
        t_c, t_m = flops / PEAK_FLOPS, bytes_ / HBM_BW
        emit(f"roofline_bna_step_K{K}", 0.0,
             f"tpu_compute_s={t_c:.2e};tpu_memory_s={t_m:.2e};"
             f"bound={'compute' if t_c > t_m else 'memory'};w={w};"
             "analytic=True")
    flag_interpret_rows()


def flag_interpret_rows() -> list[str]:
    """Mark measured kernel rows recorded under the Pallas interpreter.

    Scans the rows emitted so far this run; every measured (non-analytic)
    kernel row whose ``interpret`` provenance column is true gets
    ``;interpret_only=True`` appended to its `derived` field, and one
    summary row lists them.  Interpret wall times exercise semantics on
    CPU — comparing them against the analytic TPU rooflines as if they
    were hardware would be meaningless, so the report names them instead."""
    from . import common

    flagged = []
    for i, r in enumerate(common._rows):
        name, us, c_ms, s_ms, backend, interp, derived = r
        if not interp or name.startswith("roofline_") or us == 0.0:
            continue
        if not (name.startswith("kernel_") or name.startswith("backend_")
                or name.startswith("bna_batch")):
            continue
        if "interpret_only=True" not in derived:
            common._rows[i] = (name, us, c_ms, s_ms, backend, interp,
                               derived + ";interpret_only=True")
        flagged.append(name)
    emit("roofline_interpret_rows", 0.0,
         ("none" if not flagged else ";".join(flagged))
         + ";note=interpret timings excluded from roofline comparison")
    return flagged


def render(dryrun_path: Path | None = None) -> list[dict]:
    path = dryrun_path or (RESULTS / "dryrun.json")
    cells = json.loads(path.read_text())
    table = []
    for r in cells:
        if r.get("variant"):
            continue
        row = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
               "status": r["status"]}
        if r["status"] == "ok":
            chips = 512 if r["mesh"] == "2x16x16" else 256
            cfg = get_config(r["arch"])
            mf = model_flops_per_chip(cfg, r["shape"], chips)
            hlo = r["cost"].get("flops", 0.0)
            rf = r["roofline"]
            row.update({
                "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
                "collective_s": rf["collective_s"],
                "bottleneck": rf["bottleneck"],
                "model_flops_per_chip": mf,
                "useful_ratio": mf / hlo if hlo else None,
                "mem_gib": r["memory"].get("per_device_total_gib"),
            })
        elif r["status"] == "skipped":
            row["reason"] = r.get("reason", "")
        table.append(row)
    save_json("roofline_table", table)
    ok = [t for t in table if t["status"] == "ok"]
    for t in sorted(ok, key=lambda t: (t["arch"], t["shape"], t["mesh"])):
        if t["mesh"] == "16x16":
            emit(f"roofline_{t['arch']}_{t['shape']}", 0.0,
                 f"bottleneck={t['bottleneck'].replace('_s','')};"
                 f"dom_s={max(t['compute_s'], t['memory_s'], t['collective_s']):.3f};"
                 f"useful_ratio={t['useful_ratio'] and round(t['useful_ratio'], 3)}")
    return table
