"""§Perf hillclimb driver — reproduces the EXPERIMENTS.md §6 variant
measurements as commands instead of narrative:

  PYTHONPATH=src python -m benchmarks.hillclimb --cell decode   # §6.2
  PYTHONPATH=src python -m benchmarks.hillclimb --cell train    # §6.1
  PYTHONPATH=src python -m benchmarks.hillclimb --cell moe      # §6.3

Each prints the baseline and every iteration's roofline terms/memory as
JSON lines (and appends to benchmarks/results/hillclimb_<cell>.json).
Heavy: each variant is a fresh 256-device compile (minutes per line).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from .common import RESULTS


def _emit(rows, name):
    (RESULTS / f"hillclimb_{name}.json").write_text(
        json.dumps(rows, indent=1, default=str))


def _row(tag, res):
    out = {"variant": tag,
           "roofline": res["roofline"],
           "mem_gib": res["memory"].get("per_device_total_gib"),
           "coll_total_gb": round(res["collectives"]["total"] / 1e9, 3)}
    print(json.dumps(out))
    return out


def decode_cell():
    from repro.launch.dryrun import run_cell
    rows = []
    rows.append(_row("baseline(heads)", run_cell(
        "tinyllama-1.1b", "decode_32k", verbose=False)))
    rows.append(_row("dh", run_cell(
        "tinyllama-1.1b", "decode_32k",
        variant={"cache_layout": "dh",
                 "config": {"decode_cache_layout": "dh"}}, verbose=False)))
    rows.append(_row("seq(flash-decode)", run_cell(
        "tinyllama-1.1b", "decode_32k",
        variant={"cache_layout": "seq",
                 "config": {"decode_cache_layout": "seq"}}, verbose=False)))
    rows.append(_row("qwen3-4b seq (generalization)", run_cell(
        "qwen3-4b", "decode_32k",
        variant={"cache_layout": "seq",
                 "config": {"decode_cache_layout": "seq"}}, verbose=False)))
    _emit(rows, "decode")


def train_cell():
    from repro.launch.dryrun import run_cell
    rows = []
    rows.append(_row("baseline", run_cell(
        "qwen2.5-32b", "train_4k", verbose=False, probe_cost=False)))
    rows.append(_row("zero", run_cell(
        "qwen2.5-32b", "train_4k", variant={"zero": True},
        verbose=False, probe_cost=False)))
    rows.append(_row("zero+micro16", run_cell(
        "qwen2.5-32b", "train_4k",
        variant={"zero": True, "micro_steps": 16},
        verbose=False, probe_cost=False)))
    rows.append(_row("zero+micro16+dots", run_cell(
        "qwen2.5-32b", "train_4k",
        variant={"zero": True, "micro_steps": 16,
                 "config": {"remat": "dots"}},
        verbose=False, probe_cost=False)))
    _emit(rows, "train")


def moe_cell():
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell
    base = get_config("qwen3-moe-235b-a22b")
    rows = []
    rows.append(_row("ffn-TP (pre-fix baseline)", run_cell(
        "qwen3-moe-235b-a22b", "train_4k",
        variant={"moe_ffn_tp": True}, verbose=False, probe_cost=False)))
    rows.append(_row("EP (default)", run_cell(
        "qwen3-moe-235b-a22b", "train_4k", verbose=False, probe_cost=False)))
    # shard_map dispatch: compiles+verifies at <=8 devices; XLA:CPU aborts
    # at >=64 partitions (EXPERIMENTS.md §6.3 it.2) — not invoked here.
    _emit(rows, "moe")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=("decode", "train", "moe"),
                    required=True)
    args = ap.parse_args()
    {"decode": decode_cell, "train": train_cell, "moe": moe_cell}[args.cell]()


if __name__ == "__main__":
    main()
