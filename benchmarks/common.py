"""Shared benchmark plumbing: CSV emission, timing, workload scales.

Every paper-figure benchmark emits rows
    name,us_per_call,derived
where `derived` carries the figure's metric (e.g. percent improvement of
G-DM over O(m)Alg) so EXPERIMENTS.md can quote the CSV directly.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
RESULTS.mkdir(exist_ok=True)

# Scenario-matrix size profiles: profile -> (m override or None for the
# scenario's default port count, scale).  Used by scenario_matrix.py and the
# --scenario flag on benchmarks.run.
SCENARIO_PROFILES = {
    "fast": (12, 0.08),
    "standard": (24, 0.2),
    "paper": (None, 1.0),
}


def build_scenario(name: str, profile: str = "fast", seed: int = 0):
    """Build a registered scenario at a benchmark size profile."""
    from repro import scenarios

    m, scale = SCENARIO_PROFILES[profile]
    return scenarios.build(name, m=m, scale=scale, seed=seed)

_rows: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    _rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def save_json(name: str, payload) -> Path:
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=str))
    return p


def flush_csv(name: str = "benchmarks") -> None:
    p = RESULTS / f"{name}.csv"
    with open(p, "w") as f:
        f.write("name,us_per_call,derived\n")
        for r in _rows:
            f.write(f"{r[0]},{r[1]:.1f},{r[2]}\n")
