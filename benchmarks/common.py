"""Shared benchmark plumbing: CSV emission, timing, workload scales.

Every paper-figure benchmark emits rows
    name,us_per_call,compile_ms,steady_ms,backend,interpret,derived
where `derived` carries the figure's metric (e.g. percent improvement of
G-DM over O(m)Alg) so EXPERIMENTS.md can quote the CSV directly.

Provenance columns
------------------
``backend`` records the resolved accelerator backends at emission time as
``alpha:<x>|bna:<y>|plan:<z>`` and ``interpret`` whether Pallas kernels run
under the interpreter (CPU emulation) — interpret rows measure semantics,
not hardware, and downstream reports (roofline_report) must flag them
instead of comparing them against analytic rooflines.  ``compile_ms`` /
``steady_ms`` split one-time trace+compile cost from steady-state reuse for
jitted paths (empty for pure-python rows).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
RESULTS.mkdir(exist_ok=True)

CSV_HEADER = "name,us_per_call,compile_ms,steady_ms,backend,interpret,derived"

# Scenario-matrix size profiles: profile -> (m override or None for the
# scenario's default port count, scale).  Used by scenario_matrix.py and the
# --scenario flag on benchmarks.run.
SCENARIO_PROFILES = {
    "fast": (12, 0.08),
    "standard": (24, 0.2),
    "paper": (None, 1.0),
}


def build_scenario(name: str, profile: str = "fast", seed: int = 0):
    """Build a registered scenario at a benchmark size profile."""
    from repro import scenarios

    m, scale = SCENARIO_PROFILES[profile]
    return scenarios.build(name, m=m, scale=scale, seed=seed)


def provenance() -> tuple[str, bool]:
    """Resolved backend triple + interpret mode for provenance columns."""
    from repro.core.backend import (
        resolve_alpha_backend,
        resolve_bna_backend,
        resolve_plan_backend,
    )
    from repro.kernels import default_interpret

    backend = (
        f"alpha:{resolve_alpha_backend()}"
        f"|bna:{resolve_bna_backend()}"
        f"|plan:{resolve_plan_backend()}"
    )
    return backend, default_interpret()


_rows: list[tuple[str, float, float | None, float | None, str, bool, str]] = []


def _fmt_ms(v: float | None) -> str:
    return "" if v is None else f"{v:.3f}"


def emit(
    name: str,
    us_per_call: float,
    derived: str,
    *,
    compile_ms: float | None = None,
    steady_ms: float | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
) -> None:
    if backend is None or interpret is None:
        b, i = provenance()
        backend = b if backend is None else backend
        interpret = i if interpret is None else interpret
    _rows.append((name, us_per_call, compile_ms, steady_ms, backend,
                  bool(interpret), derived))
    print(
        f"{name},{us_per_call:.1f},{_fmt_ms(compile_ms)},{_fmt_ms(steady_ms)},"
        f"{backend},{interpret},{derived}",
        flush=True,
    )


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def timed2(fn, *args, reps: int = 3, **kw):
    """Time `fn` separating first-call (trace+compile) from steady state.

    Returns ``(out, us_per_call, compile_ms, steady_ms)`` where
    ``steady_ms`` is the best of `reps` warm calls, ``compile_ms`` is the
    first-call excess over steady (clamped at 0 — pure-python callees pay
    no compile), and ``us_per_call`` is the steady per-call time in us so
    existing consumers of the second column keep their meaning.
    """
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    first_ms = (time.perf_counter() - t0) * 1e3
    steady_ms = first_ms
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        steady_ms = min(steady_ms, (time.perf_counter() - t0) * 1e3)
    compile_ms = max(0.0, first_ms - steady_ms)
    return out, steady_ms * 1e3, compile_ms, steady_ms


def save_json(name: str, payload) -> Path:
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=str))
    return p


def flush_csv(name: str = "benchmarks") -> None:
    p = RESULTS / f"{name}.csv"
    with open(p, "w") as f:
        f.write(CSV_HEADER + "\n")
        for r in _rows:
            f.write(
                f"{r[0]},{r[1]:.1f},{_fmt_ms(r[2])},{_fmt_ms(r[3])},"
                f"{r[4]},{r[5]},{r[6]}\n"
            )
