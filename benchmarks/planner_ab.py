"""Planner A/B (framework integration benchmark): the paper's scheduler
applied to (a) collectives extracted from a real compiled train step and
(b) a multi-tenant pod fabric, versus naive program-order one-at-a-time.

The single-SPMD-step regime is reported even though delay-and-merge does
NOT win TWCT there (homogeneous ring coflows — the paper's own small-m
regime); the makespan of the collective phase is the planner objective and
the multi-tenant regime is where both metrics win. See EXPERIMENTS.md
§Planner for the regime analysis.
"""
from __future__ import annotations

import numpy as np

from repro.core import Coflow, Instance, Job, make_scheduler

from .common import emit, save_json, timed


def single_step_instance(seed: int = 0):
    from repro.dist.planner import CollectiveOp, coflows_from_step

    rng = np.random.default_rng(seed)
    ops = []
    for i in range(18):
        ops.append(CollectiveOp("all-gather" if i % 3 else "all-reduce",
                                float(rng.integers(2 ** 22, 2 ** 26)), i, "model"))
    for i in range(6):
        ops.append(CollectiveOp("all-reduce",
                                float(rng.integers(2 ** 24, 2 ** 27)),
                                18 + i, "data"))
    return coflows_from_step(ops, rows=8, cols=8, n_buckets=8)


def single_step_from_hlo(hlo_text: str):
    from repro.dist.planner import coflows_from_step, extract_collectives

    ops = extract_collectives(hlo_text)
    return coflows_from_step(ops, rows=8, cols=8, n_buckets=8)


def multi_tenant_instance(seed: int = 2, rows: int = 8, cols: int = 8,
                          tenants: int = 8):
    rng = np.random.default_rng(seed)
    m = rows * cols
    jobs = []
    for t in range(tenants):
        rset = rng.choice(rows, size=rng.integers(2, 5), replace=False)
        cset = rng.choice(cols, size=rng.integers(2, 5), replace=False)
        n_cf = int(rng.integers(2, 6))
        coflows = []
        for k in range(n_cf):
            d = np.zeros((m, m), np.int64)
            x = int(rng.integers(20, 400))
            if rng.random() < 0.5:
                for r in rset:
                    g = np.arange(r * cols, (r + 1) * cols)
                    for i in range(cols):
                        d[g[i], g[(i + 1) % cols]] = x
            else:
                for c in cset:
                    g = np.arange(c, m, cols)
                    for i in range(rows):
                        d[g[i], g[(i + 1) % rows]] = x
            coflows.append(Coflow(t, k, d))
        edges = [(k, k + 1) for k in range(n_cf - 1)]
        jobs.append(Job(t, coflows, edges,
                        weight=float(rng.uniform(0.5, 2.0)), release=0))
    return Instance(m, jobs)


def run(seeds: int = 3) -> list[dict]:
    rows = []
    for regime, make in (("single_step", single_step_instance),
                         ("multi_tenant", multi_tenant_instance)):
        mk_gain, tw_gain, us = [], [], 0.0
        for seed in range(seeds):
            inst = make(seed)
            g_sched = make_scheduler("gdm", beta=10.0, seed=seed)
            o_sched = make_scheduler("om_alg")
            (g, o), dt = timed(lambda: (g_sched.plan_full(inst),
                                        o_sched.plan_full(inst)))
            us += dt
            mk_gain.append(1 - g.makespan / o.makespan)
            tw_gain.append(1 - g.twct() / o.twct())
        emit(f"planner_{regime}", us / seeds,
             f"makespan_gain_pct={100 * float(np.mean(mk_gain)):.1f};"
             f"twct_gain_pct={100 * float(np.mean(tw_gain)):.1f}")
        rows.append({"regime": regime,
                     "makespan_gain": float(np.mean(mk_gain)),
                     "twct_gain": float(np.mean(tw_gain))})
    save_json("planner_ab", rows)
    return rows
