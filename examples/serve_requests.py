"""Serving example: batched requests through the continuous-batching engine
with the paper's coflow-ordered admission vs FIFO.

  PYTHONPATH=src python examples/serve_requests.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.serve import Request, ServeConfig, ServingEngine
from repro.train.step import init_params


def main() -> None:
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def make_requests():
        return [Request(rid=i,
                        tokens=rng.integers(1, cfg.vocab,
                                            size=int(rng.integers(4, 20))),
                        max_new=8,
                        weight=float(rng.uniform(0.5, 3.0)),
                        arrival=float(i // 3))
                for i in range(9)]

    for admission in ("coflow", "fifo"):
        eng = ServingEngine(cfg, params,
                            ServeConfig(slots=3, capacity=64,
                                        admission=admission))
        stats = eng.run(make_requests())
        print(f"{admission:6s}: completed={stats['completed']} "
              f"decode_steps={stats['steps']} "
              f"weighted_finish={stats['weighted_finish']:.1f}")


if __name__ == "__main__":
    main()
