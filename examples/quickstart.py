"""Quickstart: schedule a multi-stage coflow workload with the paper's
G-DM algorithm and compare against the prior-art O(m)Alg baseline, all
through the unified scheduler engine (repro.core.engine).

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (available_schedulers, paper_workload, plan,
                        verify_schedule, workload_stats)


def main() -> None:
    # a Facebook-trace-calibrated workload: ~5 coflows per job, rooted-tree
    # dependencies (Hive/MapReduce-style stages). Gains grow with port count
    # and job count (paper Fig 6a) — benchmarks/run.py sweeps the full range.
    inst = paper_workload(m=24, mu_bar=5, seed=3, scale=0.08, rooted=True)
    print("workload:", workload_stats(inst))
    print("registered schedulers:", ", ".join(available_schedulers()))

    sched = plan(inst, "gdm_rt", beta=2.0, seed=0, decompose=True)
    verify_schedule(inst, sched.schedule)  # capacity + precedence + conservation
    base = plan(inst, "om_alg")

    print(f"G-DM-RT   TWCT = {sched.twct():12.0f}   makespan = {sched.makespan:10.0f}")
    print(f"O(m)Alg   TWCT = {base.twct():12.0f}   makespan = {base.makespan:10.0f}")
    print(f"improvement: {100 * (1 - sched.twct() / base.twct()):.1f}%  "
          "(tiny demo instance — gains grow with m and job count; "
          "benchmarks/run.py reproduces the paper's Fig 5/6 sweeps)")

    bf_g, bf_o = sched.backfilled(), base.backfilled()
    print(f"with backfilling: G-DM-RT-BF {bf_g.twct():.0f} "
          f"vs O(m)Alg-BF {bf_o.twct():.0f}")


if __name__ == "__main__":
    main()
