"""Quickstart: build a workload from the scenario registry, schedule it
with the paper's G-DM algorithm, and compare against the prior-art O(m)Alg
baseline — all through the unified scheduler + scenario registries.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import scenarios
from repro.core import (available_schedulers, plan, verify_schedule,
                        workload_stats)


def main() -> None:
    # a Facebook-trace-calibrated workload: ~5 coflows per job, rooted-tree
    # dependencies (Hive/MapReduce-style stages). Gains grow with port count
    # and job count (paper Fig 6a) — benchmarks/run.py sweeps the full range,
    # and `--scenario` runs the whole zoo (incast, shuffle-heavy, ...).
    built = scenarios.build("fb_like_rt", m=24, seed=3, scale=0.08)
    inst = built.instance
    print("registered scenarios:", ", ".join(scenarios.names()))
    print("scenario:", built.meta.name, "| DAG family:", built.meta.dag_family,
          "| arrivals:", built.meta.arrival)
    print("workload:", workload_stats(inst))
    print("registered schedulers:", ", ".join(available_schedulers()))

    sched = plan(inst, "gdm_rt", beta=2.0, seed=0, decompose=True)
    verify_schedule(inst, sched.schedule)  # capacity + precedence + conservation
    base = plan(inst, "om_alg")

    print(f"G-DM-RT   TWCT = {sched.twct():12.0f}   makespan = {sched.makespan:10.0f}")
    print(f"O(m)Alg   TWCT = {base.twct():12.0f}   makespan = {base.makespan:10.0f}")
    print(f"improvement: {100 * (1 - sched.twct() / base.twct()):.1f}%  "
          "(tiny demo instance — gains grow with m and job count; "
          "benchmarks/run.py reproduces the paper's Fig 5/6 sweeps)")

    bf_g, bf_o = sched.backfilled(), base.backfilled()
    print(f"with backfilling: G-DM-RT-BF {bf_g.twct():.0f} "
          f"vs O(m)Alg-BF {bf_o.twct():.0f}")


if __name__ == "__main__":
    main()
