"""Quickstart: build a workload from the scenario registry, schedule it
with the paper's G-DM algorithm, compare against the prior-art O(m)Alg
baseline, then drive the same engine event-by-event through the stateful
SchedulerSession (the §VII-C.2 online protocol as an API).

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import scenarios
from repro.core import (SchedulerSession, available_schedulers, plan,
                        verify_schedule, workload_stats)


def main() -> None:
    # a Facebook-trace-calibrated workload: ~5 coflows per job, rooted-tree
    # dependencies (Hive/MapReduce-style stages). Gains grow with port count
    # and job count (paper Fig 6a) — benchmarks/run.py sweeps the full range,
    # and `--scenario` runs the whole zoo (incast, shuffle-heavy, ...).
    built = scenarios.build("fb_like_rt", m=24, seed=3, scale=0.08)
    inst = built.instance
    print("registered scenarios:", ", ".join(scenarios.names()))
    print("scenario:", built.meta.name, "| DAG family:", built.meta.dag_family,
          "| arrivals:", built.meta.arrival)
    print("workload:", workload_stats(inst))
    print("registered schedulers:", ", ".join(available_schedulers()))

    sched = plan(inst, "gdm_rt", beta=2.0, seed=0, decompose=True)
    verify_schedule(inst, sched.schedule)  # capacity + precedence + conservation
    base = plan(inst, "om_alg")

    print(f"G-DM-RT   TWCT = {sched.twct():12.0f}   makespan = {sched.makespan:10.0f}")
    print(f"O(m)Alg   TWCT = {base.twct():12.0f}   makespan = {base.makespan:10.0f}")
    print(f"improvement: {100 * (1 - sched.twct() / base.twct()):.1f}%  "
          "(tiny demo instance — gains grow with m and job count; "
          "benchmarks/run.py reproduces the paper's Fig 5/6 sweeps)")

    bf_g, bf_o = sched.backfilled(), base.backfilled()
    print(f"with backfilling: G-DM-RT-BF {bf_g.twct():.0f} "
          f"vs O(m)Alg-BF {bf_o.twct():.0f}")

    # the event-driven session: submit arrivals, advance wall-clock, read
    # the live frontier — simulate_online/plan_online are thin drivers over
    # exactly this loop (see README "The session API")
    online = scenarios.build("online_poisson", m=12, seed=0, scale=0.04)
    session = SchedulerSession(online.instance.m, "gdm", seed=0)
    for job in sorted(online.instance.jobs, key=lambda j: j.release):
        session.advance(until=job.release)
        session.submit(job)
        f = session.frontier()
        print(f"t={session.now:6.0f}  submit job {job.jid:2d}  "
              f"active={len(f.completions):2d}  busy_until={f.busy_until:.0f}")
    session.advance()
    res = session.result()
    s = res.stats["session"]
    print(f"session drained: twct={res.twct():.0f} "
          f"reschedules={res.reschedules} "
          f"(full={s['full_replans']}, repaired={s['repairs']})")


if __name__ == "__main__":
    main()
