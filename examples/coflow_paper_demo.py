"""Paper walkthrough: BNA optimality, the Lemma 2 gap instance, the FSP
NP-hardness reduction, and the collective planner on a synthetic train step.

  PYTHONPATH=src python examples/coflow_paper_demo.py
"""
import numpy as np

from repro.core import (bna, dma_srt, fsp_to_coflow_job, gap_bounds,
                        gap_instance, gap_optimal_schedule_length,
                        verify_schedule, effective_size)


def main() -> None:
    # 1) BNA schedules any coflow in exactly its effective size (Lemma 1)
    rng = np.random.default_rng(0)
    d = rng.integers(0, 50, size=(6, 6)).astype(np.int64)
    pieces = bna(d, validate=True)
    print(f"BNA: effective size {effective_size(d)}, schedule length "
          f"{sum(t for t, _ in pieces)}, {len(pieces)} matchings")

    # 2) Lemma 2: a DAG whose optimal makespan is Omega(sqrt(mu)) above the
    #    simple lower bounds Delta and T
    K = 4
    inst = gap_instance(K, d=3)
    delta, T = gap_bounds(inst)
    print(f"gap instance: mu={inst.jobs[0].mu}, Delta={delta}, T={T}, "
          f"optimal makespan {gap_optimal_schedule_length(K, 3)} "
          f"(= {gap_optimal_schedule_length(K, 3) / (delta + T):.2f} x (Delta+T))")

    # 3) Theorem 1: flow-shop instances embed as rooted-tree coflow jobs
    p = np.array([[3, 1, 4], [2, 4, 1], [5, 2, 2]])
    fsp = fsp_to_coflow_job(p)
    sched = dma_srt(fsp.jobs[0], fsp.m, rng=np.random.default_rng(0))
    verify_schedule(fsp, sched)
    print(f"FSP reduction: {fsp.jobs[0].mu} coflows, DMA-SRT makespan "
          f"{sched.makespan:.0f}")

    # 4) the collective planner: multi-tenant pod fabric (heterogeneous
    #    port usage — the regime where delay-and-merge wins; see
    #    EXPERIMENTS.md §Planner for the single-step regime analysis)
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.planner_ab import multi_tenant_instance
    from repro.dist.planner import plan
    res = plan(multi_tenant_instance(seed=2))
    print(f"planner (multi-tenant): order {res.order}, makespan "
          f"{res.planner_makespan:.0f} vs naive {res.naive_makespan:.0f} "
          f"({100 * res.makespan_gain:.1f}% shorter)")

    # 5) the unified engine's incremental online path (§VII-C.2 protocol):
    #    same completions as from-scratch rescheduling, with the bytes-keyed
    #    BNA cache hitting across arrivals
    from repro.core import (clear_caches, paper_workload, plan_online,
                            poisson_releases, theta0)
    base = paper_workload(m=12, mu_bar=3, seed=0, scale=0.05)
    online = poisson_releases(base, theta=3 * theta0(base), seed=0)
    clear_caches()
    r = plan_online(online, "gdm", seed=0)
    print(f"online (engine, incremental): twct {r.twct():.0f}, "
          f"{r.reschedules} reschedules, "
          f"BNA cache hit rate {100 * r.stats['bna']['hit_rate']:.0f}%")


if __name__ == "__main__":
    main()
