"""End-to-end training driver: a small LM trained for a few hundred steps
with the full production substrate — deterministic data pipeline, AdamW,
checkpointing + auto-resume, straggler monitoring.

  PYTHONPATH=src python examples/train_lm.py               # ~25M params, CPU
  PYTHONPATH=src python examples/train_lm.py --steps 300   # longer run

(The ~100M+ assigned architectures train with the same TrainRunner via
`python -m repro.launch.train --arch <id>`; on this CPU container use
--smoke there. The dry-run proves the full configs lower + fit on the
production mesh.)"""
import argparse
import tempfile

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.ft import FTConfig, TrainRunner
from repro.models.common import LayerSpec
from repro.train.optim import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("tinyllama-1.1b").replace(
        name="example-lm",
        d_model=args.d_model, n_heads=4, n_kv_heads=2, d_head=args.d_model // 4,
        d_ff=4 * args.d_model, vocab=8192,
        period=(LayerSpec("attn", "dense"),), n_periods=args.layers,
        param_dtype="float32", compute_dtype="float32", remat="none")

    with tempfile.TemporaryDirectory() as ckpt:
        runner = TrainRunner(
            cfg,
            OptConfig(lr=3e-4, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps),
            DataConfig(seq_len=args.seq_len, global_batch=args.batch, seed=0),
            FTConfig(ckpt_dir=ckpt, ckpt_every=max(args.steps // 4, 1)),
        )
        runner.run(args.steps)
        log = runner.metrics_log
        print(f"steps: {len(log)}  loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}"
              f"  stragglers flagged: {len(runner.monitor.flagged)}")
        assert log[-1]["loss"] < log[0]["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
