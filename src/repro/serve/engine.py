"""Batched serving engine with coflow-ordered admission.

Continuous batching over a fixed slot budget: prefill admits requests into
free slots, decode advances all active slots one token per step. Admission
ORDER is the paper's contribution applied to serving: outstanding requests
are modeled as path jobs (prefill coflow -> decode chain; weight = request
priority, release = arrival) on a live
:class:`repro.core.session.SchedulerSession` over an abstract port model of
the serving interconnect.  Arrival ticks advance the session clock, submit
the new requests (suspending the active plan, the paper's §VII-C.2 event
protocol), and read admission order from ``session.frontier()`` — the
planned-completion order under the live plan — instead of re-running the
Algorithm 5 ordering from scratch every batch tick.  Ticks without
arrivals neither replan nor touch the session: they reuse the retained
frontier at O(1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Coflow, Job
from repro.core.session import AdmissionPolicy, SchedulerSession
from repro.models import (ArchConfig, decode_step, init_decode_cache, prefill)

__all__ = ["Request", "ServeConfig", "ServingEngine"]


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # prompt token ids
    max_new: int
    weight: float = 1.0
    arrival: float = 0.0
    out: list[int] = field(default_factory=list)
    done: bool = False
    finish_step: int = -1


@dataclass
class ServeConfig:
    slots: int = 4              # concurrent decode slots (continuous batch)
    capacity: int = 256         # KV capacity per slot
    admission: str = "coflow"   # "coflow" (Algorithm 5) | "fifo"
    ports: int = 8              # abstract port model of the interconnect
    backpressure: AdmissionPolicy | None = None   # hold admissions on debt

    def __post_init__(self):
        # validated like registered scheduler options (core.engine
        # rejects unknown/ill-typed options at construction, not mid-run)
        for name in ("slots", "capacity", "ports"):
            v = getattr(self, name)
            if isinstance(v, bool) or not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        if self.ports < 2:
            raise ValueError(f"ports must be >= 2 (a coflow needs distinct "
                             f"src/dst ports), got {self.ports}")
        if self.admission not in ("coflow", "fifo"):
            raise ValueError(f"unknown admission {self.admission!r}; "
                             f"choose from ('coflow', 'fifo')")
        if self.backpressure is not None and \
                not isinstance(self.backpressure, AdmissionPolicy):
            raise TypeError(f"backpressure must be an AdmissionPolicy or "
                            f"None, got {type(self.backpressure).__name__}")


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, serve: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t))
        # one scheduling session per run() (reset at entry, so an engine is
        # reusable across batches and rid numbering may restart): requests
        # are submitted once on arrival; admission queries the live frontier
        self._session = self._new_session()
        self._submitted: set[int] = set()
        self._frontier = None

    def _new_session(self) -> SchedulerSession:
        return SchedulerSession(self.sc.ports, "om_alg",
                                admission=self.sc.backpressure)

    # --- admission ordering (the paper's machinery) ----------------------
    def _request_job(self, r: Request) -> Job:
        # prefill coflow: prompt bytes spread from the weight ports;
        # decode chain: one small coflow per new token (collapsed to one
        # aggregate coflow to keep ordering O(n))
        m = self.sc.ports
        d1 = np.zeros((m, m), dtype=np.int64)
        d1[r.rid % m, (r.rid + 1) % m] = max(len(r.tokens), 1)
        d2 = np.zeros((m, m), dtype=np.int64)
        d2[r.rid % m, (r.rid + 1) % m] = max(r.max_new, 1)
        return Job(r.rid, [Coflow(r.rid, 0, d1), Coflow(r.rid, 1, d2)],
                   [(0, 1)], weight=r.weight, release=int(r.arrival))

    def _admission_order(self, pending: list[Request],
                         step: int = 0) -> list[Request]:
        if self.sc.admission == "fifo" or len(pending) <= 1:
            return sorted(pending, key=lambda r: (r.arrival, r.rid))
        # only requests that have ARRIVED enter the session (so the session
        # never holds future releases and every submitted job shows a finite
        # planned completion); un-arrived requests sort last until their
        # tick, and duplicate rids share one session job (first wins)
        due = [r for r in pending
               if r.rid not in self._submitted and r.arrival <= step]
        if due and self._session.backpressure():
            # same signal the stream driver budgets on (core.stream): while
            # windowed replan debt exceeds the policy budget, hold the due
            # submissions — they stay pending (FIFO-ordered by the final
            # sort key below) and enter the session at a later tick
            self._session.stats.admission_deferred += len(due)
            due = []
        if due:
            for r in due:
                self._submitted.add(r.rid)
            # only arrival ticks touch the session: advance the fabric clock
            # to the tick, submit, and let frontier() replan once; planned
            # completions are static within an epoch, so no-arrival ticks
            # reuse the previous frontier at O(1)
            if step > self._session.now:
                self._session.advance(until=step)
            for r in due:
                self._session.submit(self._request_job(r))
            self._frontier = self._session.frontier()
        f = self._frontier
        if f is None:   # nothing has arrived yet
            return sorted(pending, key=lambda r: (r.arrival, r.rid))
        return sorted(pending,
                      key=lambda r: (f.completion(r.rid), r.arrival, r.rid))

    # --- serving loop -----------------------------------------------------
    def run(self, requests: list[Request], max_steps: int = 10_000) -> dict:
        self._session = self._new_session()
        self._submitted = set()
        self._frontier = None
        pending = list(requests)
        active: list[tuple[Request, dict]] = []
        step = 0
        while (pending or active) and step < max_steps:
            # admit ARRIVED requests into free slots (ordered by the live
            # session frontier; only ticks with new arrivals replan, per
            # §VII-C.2) — a request cannot be served before its arrival
            pending = self._admission_order(pending, step)
            while pending and len(active) < self.sc.slots \
                    and pending[0].arrival <= step:
                r = pending.pop(0)
                toks = jnp.asarray(r.tokens, jnp.int32)[None, :]
                logits, cache = prefill(self.cfg, self.params, toks)
                cache = self._pad_cache(cache, toks.shape[1])
                nxt = int(jnp.argmax(logits[0]))
                r.out.append(nxt)
                active.append((r, cache))
            # one decode step per active slot (batch=1 per slot: slots may
            # hold different cache lengths; a production engine packs equal-
            # length slots into one batched cache)
            still = []
            for r, cache in active:
                tok = jnp.asarray([[r.out[-1]]], jnp.int32)
                logits, cache = self._decode(self.params, cache, tok)
                nxt = int(jnp.argmax(logits[0]))
                r.out.append(nxt)
                if len(r.out) >= r.max_new:
                    r.done = True
                    r.finish_step = step
                else:
                    still.append((r, cache))
            active = still
            step += 1
        return {
            "steps": step,
            "completed": sum(r.done for r in requests),
            "weighted_finish": sum(r.weight * r.finish_step
                                   for r in requests if r.done),
        }

    def _pad_cache(self, cache: dict, cur: int) -> dict:
        cap = self.sc.capacity

        def pad(x):
            if x.ndim == 5 and x.shape[2] == cur:  # (nP, B, S, Hkv, dh)
                return jnp.pad(
                    x, ((0, 0), (0, 0), (0, cap - cur), (0, 0), (0, 0)))
            return x

        return {"layers": jax.tree.map(pad, cache["layers"]),
                "length": cache["length"]}
