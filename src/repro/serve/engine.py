"""Batched serving engine with coflow-ordered admission.

Continuous batching over a fixed slot budget: prefill admits requests into
free slots, decode advances all active slots one token per step. Admission
ORDER is the paper's contribution applied to serving: outstanding requests
are modeled as path jobs (prefill coflow -> decode chain; weight = request
priority, release = arrival) and ordered by the combinatorial Algorithm 5
(job_order) — weighted-completion-time-optimal admission instead of FIFO.
The paper's online protocol (§VII-B.2) re-runs the ordering every
admission tick.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Instance, Job, Coflow, job_order
from repro.models import (ArchConfig, decode_step, init_decode_cache, prefill)

__all__ = ["Request", "ServeConfig", "ServingEngine"]


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # prompt token ids
    max_new: int
    weight: float = 1.0
    arrival: float = 0.0
    out: list[int] = field(default_factory=list)
    done: bool = False
    finish_step: int = -1


@dataclass
class ServeConfig:
    slots: int = 4              # concurrent decode slots (continuous batch)
    capacity: int = 256         # KV capacity per slot
    admission: str = "coflow"   # "coflow" (Algorithm 5) | "fifo"


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, serve: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t))

    # --- admission ordering (the paper's machinery) ----------------------
    def _admission_order(self, pending: list[Request]) -> list[Request]:
        if self.sc.admission == "fifo" or len(pending) <= 1:
            return sorted(pending, key=lambda r: (r.arrival, r.rid))
        m = 8  # abstract port model of the serving interconnect
        jobs = []
        for i, r in enumerate(pending):
            # prefill coflow: prompt bytes spread from the weight ports;
            # decode chain: one small coflow per new token (collapsed to one
            # aggregate coflow to keep ordering O(n))
            d1 = np.zeros((m, m), dtype=np.int64)
            d1[i % m, (i + 1) % m] = max(len(r.tokens), 1)
            d2 = np.zeros((m, m), dtype=np.int64)
            d2[i % m, (i + 1) % m] = max(r.max_new, 1)
            jobs.append(Job(i, [Coflow(i, 0, d1), Coflow(i, 1, d2)],
                            [(0, 1)], weight=r.weight, release=int(r.arrival)))
        order = job_order(Instance(m, jobs)).order
        return [pending[i] for i in order]

    # --- serving loop -----------------------------------------------------
    def run(self, requests: list[Request], max_steps: int = 10_000) -> dict:
        pending = list(requests)
        active: list[tuple[Request, dict]] = []
        step = 0
        while (pending or active) and step < max_steps:
            # admit into free slots (re-ordered every tick, per the paper's
            # online protocol)
            pending = self._admission_order(pending)
            while pending and len(active) < self.sc.slots:
                r = pending.pop(0)
                toks = jnp.asarray(r.tokens, jnp.int32)[None, :]
                logits, cache = prefill(self.cfg, self.params, toks)
                cache = self._pad_cache(cache, toks.shape[1])
                nxt = int(jnp.argmax(logits[0]))
                r.out.append(nxt)
                active.append((r, cache))
            # one decode step per active slot (batch=1 per slot: slots may
            # hold different cache lengths; a production engine packs equal-
            # length slots into one batched cache)
            still = []
            for r, cache in active:
                tok = jnp.asarray([[r.out[-1]]], jnp.int32)
                logits, cache = self._decode(self.params, cache, tok)
                nxt = int(jnp.argmax(logits[0]))
                r.out.append(nxt)
                if len(r.out) >= r.max_new:
                    r.done = True
                    r.finish_step = step
                else:
                    still.append((r, cache))
            active = still
            step += 1
        return {
            "steps": step,
            "completed": sum(r.done for r in requests),
            "weighted_finish": sum(r.weight * r.finish_step
                                   for r in requests if r.done),
        }

    def _pad_cache(self, cache: dict, cur: int) -> dict:
        cap = self.sc.capacity

        def pad(x):
            if x.ndim == 5 and x.shape[2] == cur:  # (nP, B, S, Hkv, dh)
                return jnp.pad(
                    x, ((0, 0), (0, 0), (0, cap - cur), (0, 0), (0, 0)))
            return x

        return {"layers": jax.tree.map(pad, cache["layers"]),
                "length": cache["length"]}
