"""O(m)Alg — the prior state-of-the-art baseline (Tian et al. [5], [11]).

Their algorithm orders jobs via an LP over ordering variables, then
schedules jobs ONE AT A TIME: each job's coflows run sequentially in
topological order, each coflow scheduled optimally (BNA), with no
interleaving across jobs — the paper identifies exactly this
one-at-a-time behaviour as the reason for the O(m) loss.

No LP solver ships in this environment, so the LP ordering is replaced by
the combinatorial Algorithm 5 ordering — a feasible dual solution for the
SAME relaxation LP (3) (this substitution is documented in DESIGN.md and
EXPERIMENTS.md). This isolates the comparison to the scheduling policy
(one-at-a-time vs delay-and-merge), which is the effect the paper measures.
"""
from __future__ import annotations

import math

from .dma import isolated_job_unit
from .ordering import cached_job_order
from .result import CompositeSchedule
from .timeline import merge_and_fix
from .types import Instance

__all__ = ["om_alg"]


def om_alg(instance: Instance, decompose: bool = False) -> CompositeSchedule:
    by_id = {j.jid: j for j in instance.jobs}
    res = cached_job_order(instance)
    units = []
    delays: dict[int, int] = {}
    t = 0
    for jid in res.order:
        job = by_id[jid]
        start = max(t, int(job.release))
        units.append(isolated_job_unit(job, start=start))
        t = start + sum(c.D for c in job.coflows)
    # jobs never overlap -> every merged interval has alpha <= 1 and the
    # "expansion" is the identity; merge_and_fix just assembles accounting.
    sched = merge_and_fix(units, instance.m, delays, origin=0, decompose=decompose)
    assert (sched.alphas <= 1).all(), "O(m)Alg sub-schedules must not overlap"
    return CompositeSchedule([sched], instance, meta={
        "order": res.order, "algorithm": "O(m)Alg",
    })
