"""Backfilling (paper §VII): allocate under-utilized port capacity to ready
flows of other jobs. Applied identically to every scheduler (G-DM, G-DM-RT,
O(m)Alg) for a fair comparison, exactly as the paper does.

Policy (documented; the paper does not pin one down):
  * sweep the planned schedule's ledger timeline interval by interval;
  * planned transmissions execute per plan (pro-rata within each entry's
    window, capped by what the flow still needs);
  * leftover per-port capacity in an interval is offered greedily to
    *eligible* flows — job released, all Starts-After parents finished —
    earliest-planned-completion coflow first;
  * a coflow completes when its remaining demand reaches zero (backfilling
    can finish it well before its planned window ends; trailing intervals
    then free up automatically).

The sweep is ledger-based (uniform-rate windows), so per-interval placement
is the documented approximation of timeline.py; conservation, precedence,
release and per-port capacity are all respected exactly.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .result import CompositeSchedule, Transcript, TranscriptEntry, twct
from .types import Instance, parents_of

__all__ = ["backfill", "BackfillResult"]


@dataclass
class BackfillResult:
    transcript: Transcript
    coflow_completions: dict[tuple[int, int], float]
    job_completions: dict[int, float]
    makespan: float
    instance: Instance

    def twct(self, from_release: bool = False) -> float:
        return twct(self.job_completions, self.instance, from_release)


def backfill(sched: CompositeSchedule, fill: bool = True) -> BackfillResult:
    """Re-execute `sched`'s ledger under exact port capacity, offering
    leftover capacity to eligible flows (fill=True).

    fill=False is the *null-backfill* comparator: the identical
    capacity-exact sweep with step 2 (filling) disabled.  Because the ledger
    is a uniform-rate approximation of the packet-level plan, capacity
    capping can defer work past its planned window, so the re-executed
    completion times are not pointwise comparable to the plan's ledger
    window-ends (deep chains at larger m exhibit this).  The invariant that
    IS guaranteed — and that the scenario x scheduler matrix asserts — is
    monotonicity in `fill`: filling only ever adds served units, so
    twct(fill=True) <= twct(fill=False)."""
    inst = sched.instance
    m = inst.m
    by_id = {j.jid: j for j in inst.jobs}
    parents = {j.jid: parents_of(j.mu, j.edges) for j in inst.jobs}

    # one planned ledger entry per coflow (top-level schedules guarantee this)
    plan: dict[tuple[int, int], "_Flow"] = {}
    for p in sched.parts:
        for e in p.ledger:
            key = (e.jid, e.cid)
            assert key not in plan, "expected one ledger entry per coflow"
            plan[key] = _Flow(e.jid, e.cid, float(e.e0), float(e.e1),
                              e.srcs.astype(np.int64), e.dsts.astype(np.int64),
                              e.units.astype(np.float64))

    events = sorted({t for f in plan.values() for t in (f.e0, f.e1)})
    out: list[TranscriptEntry] = []
    comp: dict[tuple[int, int], float] = {}
    for key, f in plan.items():
        if f.total <= 0:
            comp[key] = f.e1  # zero-demand marker
    order_by_planned_end = sorted(plan.values(), key=lambda f: (f.e1, f.jid, f.cid))

    def process(a: float, b: float, fill_now: bool = True) -> None:
        L = b - a
        slack_s = np.full(m, L, dtype=np.float64)
        slack_r = np.full(m, L, dtype=np.float64)
        # Starts-After is evaluated against the state AT INTERVAL ENTRY: a
        # parent finishing within [a, b) unblocks its children only from the
        # next interval on (capacity capping can defer a parent past its
        # planned window, so this must be re-checked at execution time)
        done_at_entry = {key: f.rem_total <= 1e-9 for key, f in plan.items()}

        def ready(f) -> bool:
            return all(done_at_entry[(f.jid, q)]
                       for q in parents[f.jid][f.cid])

        # 1) planned transmissions
        for f in order_by_planned_end:
            if f.rem_total <= 1e-9 or f.e0 >= b or f.e1 <= a:
                continue
            if not ready(f):
                continue
            frac = (min(b, f.e1) - max(a, f.e0)) / (f.e1 - f.e0)
            amount = np.minimum(f.units * frac, f.rem)
            # respect port capacity exactly (ledger rates can locally exceed it)
            amount = _cap_to_slack(amount, f.srcs, f.dsts, slack_s, slack_r)
            if amount.sum() <= 0:
                continue
            f.apply(amount)
            out.append(TranscriptEntry(f.jid, f.cid, a, b, f.srcs, f.dsts, amount))
            if f.rem_total <= 1e-9:
                comp[(f.jid, f.cid)] = b
        # 2) backfill into leftover capacity
        if not fill_now:
            return
        if slack_s.max(initial=0) <= 1e-9 and slack_r.max(initial=0) <= 1e-9:
            return
        for f in order_by_planned_end:
            if f.rem_total <= 1e-9:
                continue
            job = by_id[f.jid]
            if job.release > a + 1e-9:
                continue
            if not ready(f):
                continue
            amount = _cap_to_slack(f.rem.copy(), f.srcs, f.dsts, slack_s, slack_r)
            if amount.sum() <= 1e-12:
                continue
            f.apply(amount)
            out.append(TranscriptEntry(f.jid, f.cid, a, b, f.srcs, f.dsts, amount))
            if f.rem_total <= 1e-9:
                comp[(f.jid, f.cid)] = b

    for a, b in zip(events[:-1], events[1:]):
        if b > a:
            process(a, b, fill_now=fill)

    # drain: capacity-capped planned units can spill past the last planned
    # window; keep offering full capacity until everything is transmitted
    # (progress is guaranteed: a topologically-first unfinished coflow of a
    # released job is always eligible).  The drain always fills — with no
    # planned windows left, filling is the only way leftovers move, so the
    # fill=False comparator differs only during the planned timeline.
    t = events[-1] if events else 0.0
    drain_len = max((f.rem_total for f in plan.values()), default=0.0)
    guard = 0
    while any(f.rem_total > 1e-9 for f in plan.values()):
        guard += 1
        assert guard < 10 * max(len(plan), 1), "backfill drain stalled (bug)"
        process(t, t + max(drain_len, 1.0))
        t += max(drain_len, 1.0)

    assert all(f.rem_total <= 1e-6 for f in plan.values()), "backfill lost demand"
    job_comp: dict[int, float] = {}
    for (jid, _), t in comp.items():
        job_comp[jid] = max(job_comp.get(jid, 0.0), t)
    for j in inst.jobs:  # jobs with no coflows
        job_comp.setdefault(j.jid, float(j.release))
    makespan = max((e.t1 for e in out if e.units.sum() > 0), default=0.0)
    return BackfillResult(Transcript(out), comp, job_comp, makespan, inst)


class _Flow:
    __slots__ = ("jid", "cid", "e0", "e1", "srcs", "dsts", "units", "rem",
                 "total", "rem_total")

    def __init__(self, jid, cid, e0, e1, srcs, dsts, units):
        self.jid, self.cid, self.e0, self.e1 = jid, cid, e0, e1
        self.srcs, self.dsts, self.units = srcs, dsts, units
        self.rem = units.copy()
        self.total = float(units.sum())
        self.rem_total = self.total

    def apply(self, amount: np.ndarray) -> None:
        self.rem -= amount
        self.rem_total = float(self.rem.sum())


def _cap_to_slack(
    want: np.ndarray, srcs: np.ndarray, dsts: np.ndarray,
    slack_s: np.ndarray, slack_r: np.ndarray,
) -> np.ndarray:
    """Greedy per-edge cap: amount <= min(want, sender slack, receiver slack),
    updating slacks in place. Sequential because edges share ports."""
    got = np.zeros_like(want)
    for k in range(want.size):
        if want[k] <= 0:
            continue
        s, r = srcs[k], dsts[k]
        x = min(want[k], slack_s[s], slack_r[r])
        if x > 1e-12:
            got[k] = x
            slack_s[s] -= x
            slack_r[r] -= x
    return got
