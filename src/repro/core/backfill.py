"""Backfilling (paper §VII): allocate under-utilized port capacity to ready
flows of other jobs. Applied identically to every scheduler (G-DM, G-DM-RT,
O(m)Alg) for a fair comparison, exactly as the paper does.

Two executors re-execute a planned CompositeSchedule under exact port
capacity (``exec=`` selects; packet is the default):

``exec="packet"`` — matching-granular sweep over the plan's *actual*
  merge-and-fix output (``FinalSchedule.coflow_intervals()``: the expanded
  timed-matching decomposition attributed per coflow).  Planned edges form a
  matching inside every elementary interval, so step 1 — executing the plan
  — is capacity-feasible by construction and never gets capped; leftover
  per-port slack in each interval is offered greedily to *eligible* flows
  (job released, all Starts-After parents finished at interval entry),
  earliest-planned-completion coflow first.  Because planned service is
  always delivered in full, executed progress dominates the plan pointwise
  and ``twct(backfill) <= twct(plan)`` holds on every instance — the paper's
  premise that backfilling only ever helps.

``exec="ledger"`` — the historical executor: the same sweep over the plan's
  *ledger* (per-coflow uniform-rate windows).  The ledger is a documented
  uniform-rate approximation, so per-interval placement can locally exceed
  port capacity and must be capped, deferring work past its planned window;
  re-executed completions are therefore NOT pointwise comparable to the
  plan (deep chains at larger m exhibit this).  What IS guaranteed is
  monotonicity in ``fill``: filling only ever adds served units, so
  ``twct(fill=True) <= twct(fill=False)`` (the null-backfill comparator).

Both executors share the completion semantics: a coflow completes when its
remaining demand reaches zero (backfilling can finish it well before its
planned window ends), and a zero-demand coflow completes instantaneously at
``max(release, parents' completion)`` — not at its planned window end —
with a zero-width marker entry in the transcript so replay agrees.
Conservation, precedence, release and per-port capacity are respected
exactly by both.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .result import CompositeSchedule, Transcript, TranscriptEntry, twct
from .types import Instance, parents_of, topological_order

__all__ = ["backfill", "BackfillResult"]

_EXECUTORS = ("packet", "ledger")


@dataclass
class BackfillResult:
    transcript: Transcript
    coflow_completions: dict[tuple[int, int], float]
    job_completions: dict[int, float]
    makespan: float
    instance: Instance
    executor: str = "packet"

    def twct(self, from_release: bool = False) -> float:
        return twct(self.job_completions, self.instance, from_release)


def backfill(sched: CompositeSchedule, fill: bool = True,
             exec: str = "packet") -> BackfillResult:
    """Re-execute `sched` under exact port capacity, offering leftover
    capacity to eligible flows (fill=True).

    `sched` may be a CompositeSchedule or anything wrapping one behind a
    ``.schedule`` attribute (an engine PlanResult, including the live plan
    a SchedulerSession retains — ``session.backfilled_plan()`` routes
    here), so a session's current residual plan can be backfilled without
    replanning.

    exec="packet" (default) re-executes the timed-matching decomposition and
    restores the pointwise guarantee twct(backfill) <= twct(plan);
    exec="ledger" re-executes the uniform-rate ledger (the pre-packet
    behavior, kept as a comparator).  fill=False disables step 2 (filling)
    in either executor: for packet that is an exact replay of the plan, for
    ledger it is the *null-backfill* monotonicity comparator (see module
    docstring for why ledger window-ends are not pointwise comparable)."""
    sched = getattr(sched, "schedule", sched)
    if isinstance(sched, BackfillResult):
        raise ValueError(
            f"already backfilled with exec={sched.executor!r}; a "
            f"BackfillResult cannot be re-executed — backfill the plain "
            f"scheduler's plan instead")
    if exec not in _EXECUTORS:
        raise ValueError(f"unknown backfill executor {exec!r}; "
                         f"choose from {_EXECUTORS}")
    if exec == "packet":
        return _packet_sweep(sched, fill)
    return _ledger_sweep(sched, fill)


# --------------------------------------------------------------------------
# shared machinery
# --------------------------------------------------------------------------

def _job_maps(inst: Instance):
    by_id = {j.jid: j for j in inst.jobs}
    parents = {j.jid: parents_of(j.mu, j.edges) for j in inst.jobs}
    topo = {j.jid: topological_order(j.mu, j.edges) for j in inst.jobs}
    return by_id, parents, topo


def _stamp_zero_demand(inst, parents, topo, is_zero, comp, out) -> None:
    """Zero-demand coflows complete instantaneously at max(release,
    parents' completion) — NOT at their planned window end, which would
    inflate job completion (and TWCT) for jobs whose last coflow is empty.
    A zero-width marker entry is appended so transcript replay agrees."""
    z = np.zeros(0, dtype=np.int64)
    for j in inst.jobs:
        for cid in topo[j.jid]:
            key = (j.jid, cid)
            if key not in is_zero:
                continue
            t = max([comp[(j.jid, q)] for q in parents[j.jid][cid]]
                    + [float(j.release)])
            comp[key] = t
            out.append(TranscriptEntry(j.jid, cid, t, t, z, z,
                                       np.zeros(0, dtype=np.float64)))


def _finalize(inst, comp, out, executor) -> BackfillResult:
    job_comp: dict[int, float] = {}
    for (jid, _), t in comp.items():
        job_comp[jid] = max(job_comp.get(jid, 0.0), t)
    for j in inst.jobs:  # jobs with no coflows
        job_comp.setdefault(j.jid, float(j.release))
    # makespan must be consistent with completions: zero-demand markers and
    # late releases count even though they transmit nothing
    makespan = max(comp.values(), default=0.0)
    return BackfillResult(Transcript(out), comp, job_comp, makespan, inst,
                          executor)


# --------------------------------------------------------------------------
# packet-level executor (exec="packet")
# --------------------------------------------------------------------------

class _PFlow:
    __slots__ = ("jid", "cid", "srcs", "dsts", "units", "rem", "total",
                 "rem_total", "eidx", "packet_end")

    def __init__(self, jid, cid, srcs, dsts, units):
        self.jid, self.cid = jid, cid
        self.srcs, self.dsts, self.units = srcs, dsts, units
        self.rem = units.copy()
        self.total = float(units.sum())
        self.rem_total = self.total
        self.eidx = {(int(s), int(r)): k
                     for k, (s, r) in enumerate(zip(srcs, dsts))}
        self.packet_end = 0.0  # planned packet-exact completion


def _packet_sweep(sched: CompositeSchedule, fill: bool) -> BackfillResult:
    inst = sched.instance
    m = inst.m
    by_id, parents, topo = _job_maps(inst)

    # one planned ledger entry per coflow (top-level schedules guarantee
    # this); the ledger supplies the demand, the decomposition the timing
    plan: dict[tuple[int, int], _PFlow] = {}
    for p in sched.parts:
        for e in p.ledger:
            key = (e.jid, e.cid)
            assert key not in plan, "expected one ledger entry per coflow"
            plan[key] = _PFlow(e.jid, e.cid, e.srcs.astype(np.int64),
                               e.dsts.astype(np.int64),
                               e.units.astype(np.float64))
    segs = [p.coflow_intervals() for p in sched.parts]
    from .timeline import EdgeIntervals
    segs = EdgeIntervals.concat(segs)

    # map each planned segment row to its flow + demand-edge index
    row_flow: list[_PFlow] = []
    row_eidx: list[int] = []
    for i in range(segs.size):
        f = plan[(int(segs.jid[i]), int(segs.cid[i]))]
        row_flow.append(f)
        row_eidx.append(f.eidx[(int(segs.s[i]), int(segs.r[i]))])
        f.packet_end = max(f.packet_end, float(segs.t1[i]))

    out: list[TranscriptEntry] = []
    comp: dict[tuple[int, int], float] = {}
    is_zero = {key for key, f in plan.items() if f.total <= 0}
    # fill priority: earliest planned (packet-exact) completion first
    pending = sorted((f for f in plan.values() if f.total > 0),
                     key=lambda f: (f.packet_end, f.jid, f.cid))

    # Starts-After state, evaluated at interval ENTRY (a parent finishing
    # within [a, b) unblocks its children from the next interval on); a
    # zero-demand coflow counts as finished only once all its parents do —
    # precedence through empty coflows is transitive
    finished: set[tuple[int, int]] = set()

    def propagate_zero() -> None:
        changed = True
        while changed:
            changed = False
            for key in is_zero:
                if key in finished:
                    continue
                jid, cid = key
                if all((jid, q) in finished for q in parents[jid][cid]):
                    finished.add(key)
                    changed = True

    propagate_zero()

    if segs.size:
        events = np.unique(np.concatenate([segs.t0, segs.t1]))
        si = np.searchsorted(events, segs.t0)
        ei = np.searchsorted(events, segs.t1)
        K = events.size - 1
        add_at: list[list[int]] = [[] for _ in range(K + 1)]
        rem_at: list[list[int]] = [[] for _ in range(K + 1)]
        for i in range(segs.size):
            add_at[si[i]].append(i)
            rem_at[ei[i]].append(i)
    else:
        events = np.zeros(0, dtype=np.int64)
        K = 0
        add_at = rem_at = []

    active: set[int] = set()
    for k in range(K):
        for i in rem_at[k]:
            active.discard(i)
        for i in add_at[k]:
            active.add(i)
        a = float(events[k])
        b = float(events[k + 1])
        L = b - a
        slack_s = np.full(m, L, dtype=np.float64)
        slack_r = np.full(m, L, dtype=np.float64)
        newly: list[tuple[int, int]] = []

        # 1) planned transmissions — the active segments form a matching
        #    (the decomposition is a refinement of timed matchings), so
        #    planned service is never capacity-capped; a segment whose flow
        #    was already finished early by filling frees its ports
        touched: dict[tuple[int, int], list[tuple[int, float]]] = {}
        for i in sorted(active):
            f = row_flow[i]
            if f.rem_total <= 1e-9:
                continue
            kedge = row_eidx[i]
            x = min(L, float(f.rem[kedge]))
            if x <= 1e-12:
                continue
            slack_s[f.srcs[kedge]] -= x
            slack_r[f.dsts[kedge]] -= x
            touched.setdefault((f.jid, f.cid), []).append((kedge, x))
        assert slack_s.min(initial=0.0) > -1e-9 and \
            slack_r.min(initial=0.0) > -1e-9, \
            "planned segments exceeded port capacity (decomposition bug)"
        for key, lst in touched.items():
            f = plan[key]
            idx = np.array([k_ for k_, _ in lst], dtype=np.int64)
            amt = np.array([x for _, x in lst], dtype=np.float64)
            f.rem[idx] -= amt
            f.rem_total = float(f.rem.sum())
            out.append(TranscriptEntry(f.jid, f.cid, a, b,
                                       f.srcs[idx], f.dsts[idx], amt))
            if f.rem_total <= 1e-9:
                comp[key] = b
                newly.append(key)

        # 2) backfill into leftover capacity
        if fill and slack_s.max(initial=0.0) > 1e-9 \
                and slack_r.max(initial=0.0) > 1e-9:
            for f in pending:
                if f.rem_total <= 1e-9:
                    continue
                if by_id[f.jid].release > a + 1e-9:
                    continue
                key = (f.jid, f.cid)
                if not all((f.jid, q) in finished
                           for q in parents[f.jid][f.cid]):
                    continue
                amount = _cap_to_slack(f.rem.copy(), f.srcs, f.dsts,
                                       slack_s, slack_r)
                if amount.sum() <= 1e-12:
                    continue
                f.rem -= amount
                f.rem_total = float(f.rem.sum())
                out.append(TranscriptEntry(f.jid, f.cid, a, b,
                                           f.srcs, f.dsts, amount))
                if f.rem_total <= 1e-9:
                    comp[key] = b
                    newly.append(key)
                if slack_s.max(initial=0.0) <= 1e-9 or \
                        slack_r.max(initial=0.0) <= 1e-9:
                    break
        if newly:
            finished.update(newly)
            propagate_zero()
            pending = [f for f in pending if f.rem_total > 1e-9]

    # planned service is delivered in full, so no drain phase exists: the
    # executor finishes no later than the plan, pointwise
    assert all(f.rem_total <= 1e-6 for f in plan.values()), \
        "packet backfill lost demand"
    _stamp_zero_demand(inst, parents, topo, is_zero, comp, out)
    return _finalize(inst, comp, out, "packet")


# --------------------------------------------------------------------------
# ledger executor (exec="ledger")
# --------------------------------------------------------------------------

def _ledger_sweep(sched: CompositeSchedule, fill: bool) -> BackfillResult:
    inst = sched.instance
    m = inst.m
    by_id, parents, topo = _job_maps(inst)

    # one planned ledger entry per coflow (top-level schedules guarantee this)
    plan: dict[tuple[int, int], "_Flow"] = {}
    for p in sched.parts:
        for e in p.ledger:
            key = (e.jid, e.cid)
            assert key not in plan, "expected one ledger entry per coflow"
            plan[key] = _Flow(e.jid, e.cid, float(e.e0), float(e.e1),
                              e.srcs.astype(np.int64), e.dsts.astype(np.int64),
                              e.units.astype(np.float64))

    events = sorted({t for f in plan.values() for t in (f.e0, f.e1)})
    out: list[TranscriptEntry] = []
    comp: dict[tuple[int, int], float] = {}
    is_zero = {key for key, f in plan.items() if f.total <= 0}
    order_by_planned_end = sorted(plan.values(), key=lambda f: (f.e1, f.jid, f.cid))

    def process(a: float, b: float, fill_now: bool = True) -> None:
        L = b - a
        slack_s = np.full(m, L, dtype=np.float64)
        slack_r = np.full(m, L, dtype=np.float64)
        # Starts-After is evaluated against the state AT INTERVAL ENTRY: a
        # parent finishing within [a, b) unblocks its children only from the
        # next interval on (capacity capping can defer a parent past its
        # planned window, so this must be re-checked at execution time);
        # a zero-demand coflow counts as finished only once all its parents
        # do — precedence through empty coflows is transitive
        done_at_entry = {key: f.rem_total <= 1e-9 and key not in is_zero
                         for key, f in plan.items()}
        for j in inst.jobs:
            for cid in topo[j.jid]:
                key = (j.jid, cid)
                if key in is_zero:
                    done_at_entry[key] = all(done_at_entry[(j.jid, q)]
                                             for q in parents[j.jid][cid])

        def ready(f) -> bool:
            return all(done_at_entry[(f.jid, q)]
                       for q in parents[f.jid][f.cid])

        # 1) planned transmissions
        for f in order_by_planned_end:
            if f.rem_total <= 1e-9 or f.e0 >= b or f.e1 <= a:
                continue
            if not ready(f):
                continue
            frac = (min(b, f.e1) - max(a, f.e0)) / (f.e1 - f.e0)
            amount = np.minimum(f.units * frac, f.rem)
            # respect port capacity exactly (ledger rates can locally exceed it)
            amount = _cap_to_slack(amount, f.srcs, f.dsts, slack_s, slack_r)
            if amount.sum() <= 0:
                continue
            f.apply(amount)
            out.append(TranscriptEntry(f.jid, f.cid, a, b, f.srcs, f.dsts, amount))
            if f.rem_total <= 1e-9:
                comp[(f.jid, f.cid)] = b
        # 2) backfill into leftover capacity
        if not fill_now:
            return
        if slack_s.max(initial=0) <= 1e-9 and slack_r.max(initial=0) <= 1e-9:
            return
        for f in order_by_planned_end:
            if f.rem_total <= 1e-9 or f.total <= 0:
                continue
            job = by_id[f.jid]
            if job.release > a + 1e-9:
                continue
            if not ready(f):
                continue
            amount = _cap_to_slack(f.rem.copy(), f.srcs, f.dsts, slack_s, slack_r)
            if amount.sum() <= 1e-12:
                continue
            f.apply(amount)
            out.append(TranscriptEntry(f.jid, f.cid, a, b, f.srcs, f.dsts, amount))
            if f.rem_total <= 1e-9:
                comp[(f.jid, f.cid)] = b

    for a, b in zip(events[:-1], events[1:]):
        if b > a:
            process(a, b, fill_now=fill)

    # drain: capacity-capped planned units can spill past the last planned
    # window; keep offering full capacity until everything is transmitted
    # (progress is guaranteed: a topologically-first unfinished coflow of a
    # released job is always eligible).  The drain always fills — with no
    # planned windows left, filling is the only way leftovers move, so the
    # fill=False comparator differs only during the planned timeline.
    t = events[-1] if events else 0.0
    drain_len = max((f.rem_total for f in plan.values()), default=0.0)
    guard = 0
    while any(f.rem_total > 1e-9 for f in plan.values()):
        guard += 1
        assert guard < 10 * max(len(plan), 1), "backfill drain stalled (bug)"
        process(t, t + max(drain_len, 1.0))
        t += max(drain_len, 1.0)

    assert all(f.rem_total <= 1e-6 for f in plan.values()), "backfill lost demand"
    _stamp_zero_demand(inst, parents, topo, is_zero, comp, out)
    return _finalize(inst, comp, out, "ledger")


class _Flow:
    __slots__ = ("jid", "cid", "e0", "e1", "srcs", "dsts", "units", "rem",
                 "total", "rem_total")

    def __init__(self, jid, cid, e0, e1, srcs, dsts, units):
        self.jid, self.cid, self.e0, self.e1 = jid, cid, e0, e1
        self.srcs, self.dsts, self.units = srcs, dsts, units
        self.rem = units.copy()
        self.total = float(units.sum())
        self.rem_total = self.total

    def apply(self, amount: np.ndarray) -> None:
        self.rem -= amount
        self.rem_total = float(self.rem.sum())


def _cap_to_slack(
    want: np.ndarray, srcs: np.ndarray, dsts: np.ndarray,
    slack_s: np.ndarray, slack_r: np.ndarray,
) -> np.ndarray:
    """Greedy per-edge cap: amount <= min(want, sender slack, receiver slack),
    updating slacks in place.  The inner loop of every sweep interval.

    Greedy edge ORDER only matters when edges share a port AND capacity
    binds there, so two vectorized fast paths return exactly the scalar
    loop's result: (A) per-port grouped demand fits inside the slack
    everywhere — take everything; (B) every port appears at most once —
    edges are independent, elementwise min.  Anything else (shared port
    with binding capacity) falls back to the sequential scalar loop."""
    got = np.zeros_like(want)
    act = np.flatnonzero(want > 1e-12)
    if act.size == 0:
        return got
    w = want[act]
    s = srcs[act]
    r = dsts[act]
    # (A) nothing binds: grouped per-port sums all fit
    tot_s = np.zeros_like(slack_s)
    tot_r = np.zeros_like(slack_r)
    np.add.at(tot_s, s, w)
    np.add.at(tot_r, r, w)
    if (tot_s <= slack_s).all() and (tot_r <= slack_r).all():
        got[act] = w
        np.subtract.at(slack_s, s, w)
        np.subtract.at(slack_r, r, w)
        return got
    # (B) conflict-free: ports distinct, edges independent
    if np.unique(s).size == s.size and np.unique(r).size == r.size:
        x = np.minimum(w, np.minimum(slack_s[s], slack_r[r]))
        x[x <= 1e-12] = 0.0
        got[act] = x
        slack_s[s] -= x
        slack_r[r] -= x
        return got
    _cap_to_slack_scalar(want, srcs, dsts, slack_s, slack_r, got)
    return got


def _cap_to_slack_scalar(
    want: np.ndarray, srcs: np.ndarray, dsts: np.ndarray,
    slack_s: np.ndarray, slack_r: np.ndarray, got: np.ndarray | None = None,
) -> np.ndarray:
    """Sequential greedy reference (edges share ports; order matters)."""
    if got is None:
        got = np.zeros_like(want)
    for k in range(want.size):
        if want[k] <= 0:
            continue
        s, r = srcs[k], dsts[k]
        x = min(want[k], slack_s[s], slack_r[r])
        if x > 1e-12:
            got[k] = x
            slack_s[s] -= x
            slack_r[r] -= x
    return got
