"""G-DM and G-DM-RT — total weighted completion time minimization
(paper Algorithm 4, §VI).

1. Order jobs with the combinatorial primal-dual Algorithm 5.
2. D_j = effective size of the aggregate coflow of the first j jobs in that
   order; T_j = critical path size; rho_j = release time.
3. Partition jobs into groups J_b by which geometric interval
   (gamma 2^{b-1}, gamma 2^b] contains T_j + rho_j + D_j.
4. Schedule the groups in order; group b starts once the previous group is
   done AND all its jobs have arrived; each group is scheduled by DMA
   (general DAGs) or DMA-RT (rooted trees).

Approximation: O(mu g(m)) for general DAGs (Theorem 5);
O(sqrt(mu) g(m) h(m, mu)) for rooted trees (Corollary 1).

Pinned gamma (session-stable grouping)
--------------------------------------
The paper's gamma is the min positive flow size of the *instance*; in the
online protocol the residual instance changes on every arrival, so the
bucket boundaries — and with them group memberships — drift on nearly
every replan, defeating the session's block-granular plan reuse.
``group_jobs(..., gamma=...)`` therefore accepts an externally pinned
gamma, and :class:`GammaEpoch` is the session-side policy that owns it:
pin to the first residual's natural gamma, then rescale **monotonically
downward by powers of two** only when a later residual's natural gamma
drops below the pin (natural >= pinned keeps the pin — the factor-2 band
is one-sided because residual minima only matter downward: a gamma
*smaller* than natural just splits the geometric intervals finer, which
preserves the grouping analysis up to the bounded ratio, while a gamma
above natural would break the (gamma 2^{b-1}, gamma 2^b] covering).
Under heavy-tail traces the natural residual gamma oscillates between 1
and the smallest undrained flow; the monotone pin converges (typically to
1) and then never moves, making group membership a stable function of the
residual jobs — the lever that turns most replans into reassemblies of
cached group blocks (``backend.group_block``).  Rescale counts surface in
``SessionStats.gamma_rescales``.
"""
from __future__ import annotations

import math
from fractions import Fraction

import numpy as np

from .ordering import cached_job_order
from .result import CompositeSchedule
from .types import Instance

__all__ = ["gdm", "group_jobs", "GammaEpoch", "geometric_bucket"]


class GammaEpoch:
    """The session's pinned gamma (module docstring): power-of-two
    monotone-downward rescales, exact ``Fraction`` arithmetic (halving an
    odd natural gamma leaves the integers — the bucket computation stays
    exact on rationals).  ``fixed=True`` freezes the pin (an explicit
    numeric ``gamma=`` on the session).  ``state()`` round-trips through
    :class:`~repro.core.session.SessionSnapshot` for kill-and-resume."""

    def __init__(self, pinned: "Fraction | None" = None, rescales: int = 0,
                 fixed: bool = False):
        if pinned is not None:
            pinned = Fraction(pinned)
            if pinned <= 0:
                raise ValueError(f"pinned gamma must be positive, "
                                 f"got {pinned}")
        self.pinned = pinned
        self.rescales = int(rescales)
        self.fixed = bool(fixed)

    def observe(self, natural: int) -> Fraction:
        """Fold one planning event's natural residual gamma into the pin
        and return the gamma to plan with."""
        if natural <= 0:
            raise ValueError(f"natural gamma must be positive, "
                             f"got {natural}")
        if self.fixed:
            return self.pinned
        if self.pinned is None:
            self.pinned = Fraction(natural)
            return self.pinned
        while self.pinned > natural:
            self.pinned /= 2
            self.rescales += 1
        return self.pinned

    def state(self) -> tuple:
        """(numerator, denominator, rescales, fixed) — or None-pinned as
        (0, 1, rescales, fixed)."""
        num = self.pinned.numerator if self.pinned is not None else 0
        den = self.pinned.denominator if self.pinned is not None else 1
        return (num, den, self.rescales, self.fixed)

    @classmethod
    def from_state(cls, state: tuple) -> "GammaEpoch":
        num, den, rescales, fixed = state
        pinned = Fraction(num, den) if num else None
        return cls(pinned=pinned, rescales=rescales, fixed=fixed)

    @classmethod
    def from_policy(cls, gamma) -> "GammaEpoch | None":
        """Map the session-level ``gamma=`` policy value to an epoch:
        ``"residual"`` -> None (the paper's per-plan natural gamma),
        ``"pinned"`` -> fresh adaptive epoch, positive int/Fraction ->
        fixed pin.  Shared by :class:`~repro.core.session.SchedulerSession`
        and ``simulate_online``'s batch driver so the two validate — and
        pin — identically."""
        if gamma == "residual":
            return None
        if gamma == "pinned":
            return cls()
        if isinstance(gamma, (int, Fraction)) \
                and not isinstance(gamma, bool) and gamma > 0:
            return cls(pinned=Fraction(gamma), fixed=True)
        raise ValueError(f"gamma must be 'residual', 'pinned', or a "
                         f"positive int/Fraction, got {gamma!r}")

    def __repr__(self) -> str:
        return (f"GammaEpoch(pinned={self.pinned}, "
                f"rescales={self.rescales}, fixed={self.fixed})")


def geometric_bucket(key: int, gamma) -> int:
    """Smallest b >= 0 with key <= gamma * 2^b, exactly: for gamma = p/q
    the condition is 2^b >= ceil(q*key / p), and the smallest power of two
    at or above a positive integer x is ``(x - 1).bit_length()`` — all
    integer arithmetic, no float log, no guard loops."""
    if key <= 0:
        return 0
    g = Fraction(gamma)
    return ((g.denominator * int(key) - 1) // g.numerator).bit_length()


def group_jobs(instance: Instance, order: list[int],
               gamma=None) -> list[list[int]]:
    """Steps 2-3: geometric grouping by T_j + rho_j + D_j (prefix aggregate).

    ``gamma`` defaults to the instance's natural gamma (min positive flow
    size, the paper's definition); a session pins it across replans via
    :class:`GammaEpoch` so bucket boundaries — and group memberships —
    stay translation-stable (module docstring).  Accepts any positive
    int/Fraction.  The prefix effective sizes come from the backend's
    memoized cumsum (``grouping_prefix``), which extends a cached prefix
    for appended arrivals instead of recomputing.

    Returns groups as lists of job ids, in increasing b; empty groups are
    dropped (they contribute nothing to the schedule)."""
    from . import backend

    by_id = {j.jid: j for j in instance.jobs}
    if gamma is None:
        gamma = instance.gamma()
    g = Fraction(gamma)
    if g <= 0:
        raise ValueError(f"gamma must be positive, got {gamma!r}")
    D = backend.grouping_prefix(instance, order)
    groups: dict[int, list[int]] = {}
    for i, jid in enumerate(order):
        job = by_id[jid]
        key = job.T + job.release + int(D[i])
        groups.setdefault(geometric_bucket(key, g), []).append(jid)
    return [groups[b] for b in sorted(groups)]


def gdm(
    instance: Instance,
    beta: float = 2.0,
    rng: np.random.Generator | None = None,
    rooted: bool = False,
    decompose: bool = False,
    use_kernel: bool | None = None,
    nested: bool = True,
    require_tree: bool = True,
    delays: str = "random",
    gamma=None,
) -> CompositeSchedule:
    """G-DM (rooted=False) / G-DM-RT (rooted=True).

    require_tree=False lets G-DM-RT accept non-tree jobs: DMA-SRT's start
    times fall back to start-after-parents for those jobs (precedence-exact;
    only the rooted-tree analysis constant is lost).

    delays="spread" selects the deterministic evenly-spaced Step 2 delays
    (dma.draw_delays with rng=None): the plan becomes rng-independent, and
    the per-group layouts are assembled from the backend's group-block
    cache — each group is built once at origin 0 and slid to its chain
    position (``FinalSchedule.shifted_expanded``), bit-identical to direct
    construction by translation invariance — which is what makes the
    session's group-granular plan repair certifiable AND its full replans
    cheap (see core/session.py).

    ``gamma`` overrides the geometric-grouping scale (None: the instance's
    natural gamma) — the session's pinned-gamma epochs thread through
    here; the grouping analysis holds up to the pin's bounded ratio."""
    from .dma import check_delays_mode, dma
    from .dma_srt import dma_rt

    check_delays_mode(delays)
    if rng is None:
        rng = np.random.default_rng(0)
    by_id = {j.jid: j for j in instance.jobs}
    res = cached_job_order(instance)
    eff_gamma = Fraction(gamma) if gamma is not None \
        else Fraction(instance.gamma())
    groups = group_jobs(instance, res.order, gamma=eff_gamma)
    kind = "gdm_rt" if rooted else "gdm"
    parts = []
    t_cur = 0
    for g in groups:
        jobs = [by_id[jid] for jid in g]
        start = max(t_cur, max((j.release for j in jobs), default=0))
        if delays == "spread":
            from . import backend

            sub = backend.group_block(
                kind, jobs, instance.m, beta=beta, decompose=decompose,
                use_kernel=use_kernel, nested=nested,
                require_tree=require_tree,
                delays=delays).shifted_expanded(int(start))
        elif rooted:
            sub = dma_rt(jobs, instance.m, beta=beta, rng=rng,
                         origin=int(start), decompose=decompose,
                         use_kernel=use_kernel, nested=nested,
                         require_tree=require_tree, delays=delays)
        else:
            sub = dma(jobs, instance.m, beta=beta, rng=rng,
                      origin=int(start), decompose=decompose,
                      use_kernel=use_kernel, delays=delays)
        parts.append(sub)
        t_cur = int(math.ceil(sub.makespan))
    return CompositeSchedule(parts, instance, meta={
        "order": res.order, "groups": groups,
        "algorithm": "G-DM-RT" if rooted else "G-DM",
        "beta": beta, "gamma": eff_gamma,
    })
