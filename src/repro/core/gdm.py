"""G-DM and G-DM-RT — total weighted completion time minimization
(paper Algorithm 4, §VI).

1. Order jobs with the combinatorial primal-dual Algorithm 5.
2. D_j = effective size of the aggregate coflow of the first j jobs in that
   order; T_j = critical path size; rho_j = release time.
3. Partition jobs into groups J_b by which geometric interval
   (gamma 2^{b-1}, gamma 2^b] contains T_j + rho_j + D_j.
4. Schedule the groups in order; group b starts once the previous group is
   done AND all its jobs have arrived; each group is scheduled by DMA
   (general DAGs) or DMA-RT (rooted trees).

Approximation: O(mu g(m)) for general DAGs (Theorem 5);
O(sqrt(mu) g(m) h(m, mu)) for rooted trees (Corollary 1).
"""
from __future__ import annotations

import math

import numpy as np

from .dma import dma
from .dma_srt import dma_rt
from .ordering import cached_job_order
from .result import CompositeSchedule
from .types import Instance, effective_size

__all__ = ["gdm", "group_jobs"]


def group_jobs(instance: Instance, order: list[int]) -> list[list[int]]:
    """Steps 2-3: geometric grouping by T_j + rho_j + D_j (prefix aggregate).

    Returns groups as lists of job ids, in increasing b; empty groups are
    dropped (they contribute nothing to the schedule)."""
    from . import backend

    by_id = {j.jid: j for j in instance.jobs}
    m = instance.m
    gamma = instance.gamma()
    keys: dict[int, float] = {}
    loads = backend.plan_order_loads(instance)
    if loads is not None:
        # effective_size of a prefix aggregate = max port load of the
        # prefix = max over 2m ports of the cumsum of per-job load
        # vectors (row sums commute with prefix sums) — no (m, m)
        # accumulation needed.  Exact: float64 holds the integer loads.
        row = {j.jid: k for k, j in enumerate(instance.jobs)}
        cum = np.cumsum(loads[[row[jid] for jid in order]], axis=0)
        D = cum.max(axis=1)
        for i, jid in enumerate(order):
            job = by_id[jid]
            keys[jid] = job.T + job.release + int(D[i])
    else:
        agg = np.zeros((m, m), dtype=np.int64)
        for jid in order:
            job = by_id[jid]
            agg += job.aggregate_demand()
            D_j = effective_size(agg)
            keys[jid] = job.T + job.release + D_j
    groups: dict[int, list[int]] = {}
    for jid in order:
        key = keys[jid]
        if key <= 0:
            b = 0
        else:
            # smallest b >= 0 with key <= gamma * 2^b
            b = max(0, math.ceil(math.log2(key / gamma)))
            while gamma * (2 ** b) < key:  # float-log guard
                b += 1
            while b > 0 and gamma * (2 ** (b - 1)) >= key:
                b -= 1
        groups.setdefault(b, []).append(jid)
    return [groups[b] for b in sorted(groups)]


def gdm(
    instance: Instance,
    beta: float = 2.0,
    rng: np.random.Generator | None = None,
    rooted: bool = False,
    decompose: bool = False,
    use_kernel: bool | None = None,
    nested: bool = True,
    require_tree: bool = True,
    delays: str = "random",
) -> CompositeSchedule:
    """G-DM (rooted=False) / G-DM-RT (rooted=True).

    require_tree=False lets G-DM-RT accept non-tree jobs: DMA-SRT's start
    times fall back to start-after-parents for those jobs (precedence-exact;
    only the rooted-tree analysis constant is lost).

    delays="spread" selects the deterministic evenly-spaced Step 2 delays
    (dma.draw_delays with rng=None): the plan becomes rng-independent, and
    with singleton geometric groups it coincides with the job-sequential
    O(m)Alg layout — which is what makes the session's frontier-append
    plan repair certifiable for spread-mode G-DM (see core/session.py)."""
    from .dma import check_delays_mode

    check_delays_mode(delays)
    if rng is None:
        rng = np.random.default_rng(0)
    by_id = {j.jid: j for j in instance.jobs}
    res = cached_job_order(instance)
    groups = group_jobs(instance, res.order)
    parts = []
    t_cur = 0
    for g in groups:
        jobs = [by_id[jid] for jid in g]
        start = max(t_cur, max((j.release for j in jobs), default=0))
        if rooted:
            sub = dma_rt(jobs, instance.m, beta=beta, rng=rng,
                         origin=int(start), decompose=decompose,
                         use_kernel=use_kernel, nested=nested,
                         require_tree=require_tree, delays=delays)
        else:
            sub = dma(jobs, instance.m, beta=beta, rng=rng,
                      origin=int(start), decompose=decompose,
                      use_kernel=use_kernel, delays=delays)
        parts.append(sub)
        t_cur = int(math.ceil(sub.makespan))
    return CompositeSchedule(parts, instance, meta={
        "order": res.order, "groups": groups, "algorithm": "G-DM-RT" if rooted else "G-DM",
        "beta": beta,
    })
