"""Composite scheduling results + metrics (TWCT, makespan, transcripts)."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .timeline import FinalSchedule, MappedEntry
from .types import Instance

__all__ = ["CompositeSchedule", "twct", "Transcript", "TranscriptEntry"]


@dataclass
class TranscriptEntry:
    """Executed transmissions: coflow (jid, cid) moves units[k] on edge
    (srcs[k], dsts[k]) uniformly over wall-clock [t0, t1)."""

    jid: int
    cid: int
    t0: float
    t1: float
    srcs: np.ndarray
    dsts: np.ndarray
    units: np.ndarray


@dataclass
class Transcript:
    """Flat record of everything a schedule transmits; the online driver and
    the metrics layer consume only this."""

    entries: list[TranscriptEntry]

    def coflow_completions(self) -> dict[tuple[int, int], float]:
        remaining: dict[tuple[int, int], float] = {}
        total: dict[tuple[int, int], float] = {}
        last: dict[tuple[int, int], float] = {}
        for e in self.entries:
            key = (e.jid, e.cid)
            total[key] = total.get(key, 0.0) + float(e.units.sum())
            last.setdefault(key, e.t1)
        comp: dict[tuple[int, int], float] = {}
        # completion = earliest time cumulative units reach total
        per: dict[tuple[int, int], list[TranscriptEntry]] = {}
        for e in self.entries:
            per.setdefault((e.jid, e.cid), []).append(e)
        for key, es in per.items():
            tot = total[key]
            if tot <= 0:
                comp[key] = max(e.t1 for e in es)
                continue
            es_sorted = sorted(es, key=lambda e: e.t1)
            acc = 0.0
            for e in es_sorted:
                acc += float(e.units.sum())
                if acc >= tot - 1e-9:
                    comp[key] = e.t1
                    break
        return comp

    def job_completions(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for (jid, _), t in self.coflow_completions().items():
            out[jid] = max(out.get(jid, 0.0), t)
        return out


@dataclass
class CompositeSchedule:
    """A sequence of FinalSchedules on a shared wall-clock (G-DM groups,
    or the baseline's one-sub-schedule result)."""

    parts: list[FinalSchedule]
    instance: Instance
    meta: dict = field(default_factory=dict)

    def job_completions(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for p in self.parts:
            for jid, t in p.job_completions().items():
                out[jid] = max(out.get(jid, 0.0), t)
        return out

    def coflow_completions(self) -> dict[tuple[int, int], float]:
        out: dict[tuple[int, int], float] = {}
        for p in self.parts:
            for key, t in p.coflow_completions().items():
                out[key] = max(out.get(key, 0.0), t)
        return out

    @property
    def makespan(self) -> float:
        return max((p.makespan for p in self.parts), default=0.0)

    def twct(self, from_release: bool = False) -> float:
        return twct(self.job_completions(), self.instance, from_release)

    def transcript(self) -> Transcript:
        entries = [
            TranscriptEntry(e.jid, e.cid, float(e.e0), float(e.e1), e.srcs, e.dsts, e.units)
            for p in self.parts
            for e in p.ledger
        ]
        return Transcript(entries)


def twct(
    completions: dict[int, float], instance: Instance, from_release: bool = False
) -> float:
    """Total weighted completion time; from_release=True measures each job
    from its arrival (the paper's online metric)."""
    total = 0.0
    for j in instance.jobs:
        c = completions.get(j.jid)
        if c is None:
            raise KeyError(f"job {j.jid} has no completion")
        total += j.weight * (c - (j.release if from_release else 0.0))
    return total
