"""Lemma 2 gap instance: a DAG job whose optimal makespan is
Omega(sqrt(mu) * (Delta + T)) — a sqrt(mu) factor above both simple lower
bounds. We build the paper's construction, its hand-crafted optimal-order
schedule, and expose the quantities for tests.

Construction (paper, 1-indexed; here 0-indexed): mu = (2K)^2 coflows in an
m x m switch, m > 2K. Level i in {0..2K-1} holds coflows i*2K .. (i+1)*2K-1,
each a single flow of size d from sender i to receiver i+1. Parents of
coflow c at level i >= 1:
  first half of the level  -> { c-2K .. c-K-1 }
  second half of the level -> { c-3K+1 .. c-2K }
Then T = Delta = 2Kd while C_opt = (2K+1)K d = Omega(mu d).
"""
from __future__ import annotations

import numpy as np

from .types import Coflow, Instance, Job

__all__ = ["gap_instance", "gap_optimal_schedule_length", "gap_bounds"]


def gap_instance(K: int, d: int = 1, m: int | None = None) -> Instance:
    if m is None:
        m = 2 * K + 2
    assert m > 2 * K, "need m > 2K"
    mu = (2 * K) ** 2
    coflows: list[Coflow] = []
    for c in range(mu):
        level = c // (2 * K)
        dm = np.zeros((m, m), dtype=np.int64)
        dm[level, level + 1] = d
        coflows.append(Coflow(0, c, dm))
    edges: list[tuple[int, int]] = []
    for c in range(2 * K, mu):
        level = c // (2 * K)
        pos = c - level * 2 * K  # 0..2K-1 within the level
        if pos < K:  # first half: parents c-2K .. c-K-1
            lo, hi = c - 2 * K, c - K - 1
        else:        # second half: parents c-3K+1 .. c-2K
            lo, hi = c - 3 * K + 1, c - 2 * K
        for p in range(lo, hi + 1):
            edges.append((p, c))
    return Instance(m, [Job(0, coflows, edges, weight=1.0)])


def gap_optimal_schedule_length(K: int, d: int = 1) -> int:
    """(2K+1) K d — the hand schedule's makespan (paper's optimal order:
    K sequential coflows, then 2K-1 rounds of K simultaneous pairs, then K
    sequential)."""
    return (2 * K + 1) * K * d


def gap_bounds(inst: Instance) -> tuple[int, int]:
    """(Delta_j, T_j) of the gap job — both equal 2Kd by construction."""
    job = inst.jobs[0]
    return job.delta, job.T


def gap_hand_schedule(K: int, d: int = 1) -> list[tuple[int, list[int]]]:
    """The paper's explicit feasible schedule: list of (start, coflow ids run
    back-to-back... each tuple is a *round* of simultaneously-running coflows
    occupying [start, start + d)). Used by tests to check feasibility and the
    (2K+1)Kd makespan."""
    rounds: list[list[int]] = []
    # K initial coflows of level 0, sequential
    for c in range(K):
        rounds.append([c])
    # pairs: for i = 1..2K-1, c = 1..K: coflows 2(i-1/2)K + c and 2iK + c
    # (1-indexed) run together -> 0-indexed: (2i-1)K + c-1 and 2iK + c-1
    for i in range(1, 2 * K):
        for c in range(K):
            rounds.append([(2 * i - 1) * K + c, 2 * i * K + c])
    # last K coflows sequential
    for c in range(4 * K * K - K, 4 * K * K):
        rounds.append([c])
    return [(t * d, r) for t, r in enumerate(rounds)]
