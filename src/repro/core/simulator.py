"""Schedule verification — the invariants every algorithm must satisfy.

Used by unit/property tests: (i) per-coflow demand conservation through the
ledger, (ii) Starts-After precedence, (iii) release times, (iv) packet-level
validity of decompositions (matchings, time-disjoint, aggregate-conserving).
"""
from __future__ import annotations

import numpy as np

from .result import CompositeSchedule, Transcript
from .timeline import FinalSchedule
from .types import Instance

__all__ = ["verify_schedule", "verify_decomposition", "verify_transcript"]


def verify_schedule(instance: Instance, sched: CompositeSchedule | FinalSchedule,
                    check_packets: bool | None = None) -> None:
    parts = sched.parts if isinstance(sched, CompositeSchedule) else [sched]
    by_job = {j.jid: j for j in instance.jobs}

    # gather ledger per coflow
    per: dict[tuple[int, int], list] = {}
    for p in parts:
        for e in p.ledger:
            per.setdefault((e.jid, e.cid), []).append(e)

    for j in instance.jobs:
        for c in j.coflows:
            key = (j.jid, c.cid)
            entries = per.get(key, [])
            assert entries, f"coflow {key} never scheduled"
            # (i) conservation: ledger units == demand, edge by edge
            got = np.zeros_like(c.demand, dtype=np.float64)
            for e in entries:
                if e.units.size:
                    np.add.at(got, (e.srcs, e.dsts), e.units)
            assert np.allclose(got, c.demand), f"conservation violated for {key}"
            # (iii) release
            t0 = min(e.e0 for e in entries)
            assert t0 >= j.release - 1e-6, f"coflow {key} starts before release"

    # (ii) precedence through ledger windows
    for j in instance.jobs:
        comp = {}
        start = {}
        for c in j.coflows:
            es = per[(j.jid, c.cid)]
            comp[c.cid] = max(e.e1 for e in es)
            start[c.cid] = min(e.e0 for e in es)
        for a, b in j.edges:
            assert start[b] >= comp[a] - 1e-6, (
                f"precedence violated: job {j.jid}: {a} -> {b} "
                f"(start {start[b]} < parent end {comp[a]})")

    # (iv) packet level, when a decomposition is present
    for p in parts:
        if p.decomposition is not None:
            verify_decomposition(p)
    if check_packets:
        assert any(p.decomposition is not None for p in parts), \
            "packet check requested but no decomposition present"

    # aggregate conservation at packet level across the whole composite
    if all(p.decomposition is not None for p in parts):
        m = instance.m
        total = np.zeros((m, m), dtype=np.int64)
        for j in instance.jobs:
            for c in j.coflows:
                total += c.demand
        moved = np.zeros((m, m), dtype=np.int64)
        for p in parts:
            for piece in p.decomposition:
                np.add.at(moved, (piece.srcs, piece.dsts), piece.dur)
        assert (moved == total).all(), "packet-level aggregate conservation violated"


def verify_transcript(
    instance: Instance, transcript: Transcript,
    check_capacity: bool = False, tol: float = 1e-6,
    makespan: float | None = None,
) -> None:
    """Invariants of an executed-transmission Transcript (any scheduler,
    including backfilled results which have no CompositeSchedule parts):

    (i)   conservation — per coflow, transmitted units == demand edge-wise;
    (ii)  release — no transmission before its job's release;
    (iii) Starts-After precedence — a child's first transmission does not
          precede its last parent's completion;
    (iv)  optionally, uniform-rate port capacity: within every elementary
          interval of the transcript's event partition, the units each port
          sends/receives fit in the interval length.  Only backfilled
          transcripts are exactly capacity-feasible at this level — plain
          schedulers' ledgers are a documented uniform-rate approximation
          (their exact feasibility is packet-level: `verify_schedule` with
          decompose=True);
    (v)   optionally, makespan consistency: pass the executor's reported
          `makespan` and it must cover every coflow completion — including
          zero-demand markers, which transmit nothing but still complete
          (an instance whose jobs are all empty has a positive makespan).
    """
    per: dict[tuple[int, int], list] = {}
    for e in transcript.entries:
        per.setdefault((e.jid, e.cid), []).append(e)

    for j in instance.jobs:
        for c in j.coflows:
            key = (j.jid, c.cid)
            entries = per.get(key, [])
            if (c.demand > 0).any():
                assert entries, f"coflow {key} never transmitted"
            got = np.zeros(c.demand.shape, dtype=np.float64)
            for e in entries:
                if e.units.size:
                    np.add.at(got, (e.srcs, e.dsts), e.units)
            assert np.allclose(got, c.demand, atol=1e-5), \
                f"conservation violated for {key}"
            if entries:
                assert min(e.t0 for e in entries) >= j.release - tol, \
                    f"coflow {key} transmits before release"

    comp = transcript.coflow_completions()
    if makespan is not None and comp:
        worst = max(comp.values())
        assert makespan >= worst - tol, \
            f"makespan {makespan} < last coflow completion {worst}"
    for j in instance.jobs:
        for a, b in j.edges:
            if (j.jid, a) not in comp or (j.jid, b) not in per:
                continue
            # zero-demand children carry only an instantaneous marker entry;
            # its window stands in for the start
            moving = [e for e in per[(j.jid, b)]
                      if e.units.size and e.units.sum() > 0]
            child_start = min(e.t0 for e in (moving or per[(j.jid, b)]))
            assert child_start >= comp[(j.jid, a)] - tol, (
                f"precedence violated: job {j.jid}: {a} -> {b} "
                f"(start {child_start} < parent end {comp[(j.jid, a)]})")

    if check_capacity:
        moving = [e for e in transcript.entries
                  if e.units.size and e.units.sum() > 0 and e.t1 > e.t0]
        events = sorted({t for e in moving for t in (e.t0, e.t1)})
        for a, b in zip(events[:-1], events[1:]):
            if b <= a:
                continue
            sent = np.zeros(instance.m)
            recv = np.zeros(instance.m)
            for e in moving:
                lo, hi = max(a, e.t0), min(b, e.t1)
                if hi <= lo:
                    continue
                frac = (hi - lo) / (e.t1 - e.t0)
                np.add.at(sent, e.srcs, e.units * frac)
                np.add.at(recv, e.dsts, e.units * frac)
            cap = (b - a) * (1 + 1e-9) + tol
            assert sent.max(initial=0) <= cap and recv.max(initial=0) <= cap, \
                f"port capacity exceeded in [{a}, {b})"


def verify_decomposition(p: FinalSchedule) -> None:
    """Every piece a matching; pieces time-disjoint (unit port capacity)."""
    pieces = sorted(p.decomposition, key=lambda x: x.t0)
    prev_end = -np.inf
    for x in pieces:
        assert x.dur > 0
        assert len(np.unique(x.srcs)) == x.srcs.size, "sender used twice in a slot"
        assert len(np.unique(x.dsts)) == x.dsts.size, "receiver used twice in a slot"
        assert x.t0 >= prev_end, "pieces overlap in time"
        prev_end = x.t0 + x.dur
