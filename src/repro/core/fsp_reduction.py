"""Theorem 1 reduction: preemptive Flow Shop -> single rooted-tree coflow job.

FSP instance: n jobs x m machines, task i of job j needs p[i][j] time on
machine i, same machine order for all jobs. The constructed coflow job is a
fan-out tree: a dummy root coflow (one flow of size 1, sender 1 -> receiver
0), and n branches of m coflows each; branch j level l (0-indexed levels
1..m-1 of the tree) has one flow sender l-1 -> receiver l of size p[l-1][j],
and the final level a flow sender m-1 -> receiver 0 of size p[m-1][j].
An optimal makespan for the coflow job gives an optimal preemptive FSP
makespan after dropping the dummy's first time unit.
"""
from __future__ import annotations

import numpy as np

from .types import Coflow, Instance, Job

__all__ = ["fsp_to_coflow_job"]


def fsp_to_coflow_job(p: np.ndarray) -> Instance:
    """p: (m_machines, n_jobs) positive processing times."""
    p = np.asarray(p, dtype=np.int64)
    m_mach, n = p.shape
    assert (p > 0).all()
    ports = max(m_mach, 2)
    coflows: list[Coflow] = []
    edges: list[tuple[int, int]] = []

    def flow(s: int, r: int, size: int) -> np.ndarray:
        d = np.zeros((ports, ports), dtype=np.int64)
        d[s, r] = size
        return d

    coflows.append(Coflow(0, 0, flow(1, 0, 1)))  # dummy root
    cid = 1
    for j in range(n):
        prev = 0  # root
        for l in range(m_mach):
            if l < m_mach - 1:
                d = flow(l, l + 1, int(p[l, j]))
            else:
                d = flow(m_mach - 1, 0, int(p[l, j]))
            coflows.append(Coflow(0, cid, d))
            edges.append((prev, cid))
            prev = cid
            cid += 1
    return Instance(ports, [Job(0, coflows, edges, weight=1.0)])
