"""Core coflow-DAG scheduling library (Shafiee & Ghaderi 2020) — the paper's
contribution, implemented faithfully: BNA, DMA, DMA-SRT, DMA-RT, the
primal-dual job ordering, G-DM / G-DM-RT, the O(m)Alg baseline, backfilling,
the online driver, and the paper's workload/verification machinery."""

from .backend import (bna_pieces_many, cache_stats, clear_caches,
                      compute_alphas, group_block, grouping_prefix,
                      prefetch_bna, prefetch_plan,
                      set_alpha_backend, set_bna_backend, set_plan_backend,
                      use_alpha_backend, use_bna_backend, use_plan_backend)
from .backfill import BackfillResult, backfill
from .baseline import om_alg
from .bna import bna, verify_bna_schedule
from .dma import cached_bna, dma, isolated_job_unit
from .matching import bna_many
from .dma_srt import dma_rt, dma_srt, path_subjobs, srt_start_times
from .engine import (PlanResult, Scheduler, available_schedulers,
                     make_scheduler, plan, plan_online, register_scheduler,
                     scheduler_options)
from .fsp_reduction import fsp_to_coflow_job
from .gap_instance import (gap_bounds, gap_hand_schedule, gap_instance,
                           gap_optimal_schedule_length)
from .gdm import GammaEpoch, gdm, geometric_bucket, group_jobs
from .online import OnlineResult, simulate_online
from .session import (AdmissionPolicy, Frontier, SchedulerSession,
                      SessionSnapshot, SessionStats)
from .stream import (StreamDriver, StreamResult, arrival_times, run_stream,
                     stream_jobs)
from .ordering import OrderResult, cached_job_order, job_order
from .result import CompositeSchedule, Transcript, twct
from .simulator import verify_schedule, verify_transcript
from .timeline import FinalSchedule, UnitSchedule, merge_and_fix
from .traces import (PAPER_STATS, build_jobs, dag_edges, fb_like_coflows,
                     paper_workload, poisson_releases, port_skew,
                     sample_coflows, sample_sizes, sample_width, theta0,
                     workload_stats)
from .types import (Coflow, Instance, Job, aggregate_size, coflow_layers,
                    critical_path_size, effective_size, is_rooted_tree,
                    topological_order)

__all__ = [name for name in dir() if not name.startswith("_")]
