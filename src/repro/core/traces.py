"""Workload generation (paper §VII).

The paper evaluates on a Facebook Hive/MapReduce trace (150 racks, 267
coflows, flow sizes in [1, 2472], coflow effective sizes in [5, 232145],
aggregate effective size Delta = 440419). That trace is not redistributable
offline, so `fb_like_coflows` generates a calibrated synthetic workload that
matches the published marginal statistics: log-uniform coflow widths in
[10, 21170] flows, heavy-tailed (lognormal) flow sizes clipped to [1, 2472],
uniform port mapping. EXPERIMENTS.md records the achieved statistics next
to the paper's.

Job construction follows §VII exactly: coflows are randomly partitioned into
jobs with mu_bar coflows on average; general-DAG jobs draw each forward edge
with probability 0.5; rooted-tree jobs convert the random graph to a fan-in
tree (equivalently: each non-root node keeps one out-edge to a random
higher-indexed node). Weights are equal or Uniform(0, 1]; releases are 0
(offline) or Poisson arrivals with rate theta (online).

Beyond the paper's single calibrated trace, this module also exposes the
*generalized* primitives the scenario registry (`repro.scenarios`) is built
on: parameterized width/size distributions (`sample_width`, `sample_sizes`),
port-skew maps (`port_skew` — uniform / hotspot / zipf popularity), a
generic coflow sampler (`sample_coflows`), and a DAG-family sampler
(`dag_edges` — general / tree / chain / star / independent).  `build_jobs`
accepts `dag=` / `mu_fixed=` to pick a family explicitly; the legacy
`rooted=` flag keeps its exact RNG stream.
"""
from __future__ import annotations

import math

import numpy as np

from .types import (Coflow, Instance, Job, children_of, coflow_layers,
                    is_rooted_tree, parents_of)

__all__ = [
    "fb_like_coflows",
    "build_jobs",
    "paper_workload",
    "poisson_releases",
    "theta0",
    "workload_stats",
    "sample_width",
    "sample_sizes",
    "port_skew",
    "sample_coflows",
    "dag_edges",
]

# Published trace statistics (paper §VII "Workload")
PAPER_STATS = dict(m=150, n_coflows=267, min_flow=1, max_flow=2472,
                   min_width=10, max_width=21170, delta=440419)


def fb_like_coflows(
    m: int = 150,
    n_coflows: int = 267,
    seed: int = 0,
    scale: float = 1.0,
    min_flow: int = 1,
    max_flow: int = 2472,
    min_width: int = 10,
    max_width: int = 21170,
) -> list[np.ndarray]:
    """Synthetic FB-like coflows: list of (m, m) int64 demand matrices.

    scale < 1 shrinks coflow count and widths proportionally (benchmark fast
    mode); statistics per coflow are preserved."""
    rng = np.random.default_rng(seed)
    n = max(1, int(round(n_coflows * scale)))
    wmax = max(min_width, int(round(max_width * scale)))
    demands: list[np.ndarray] = []
    for _ in range(n):
        width = int(round(10 ** rng.uniform(math.log10(min_width),
                                            math.log10(max(wmax, min_width + 1)))))
        width = min(width, m * (m - 1))
        sizes = np.clip(np.round(rng.lognormal(mean=3.0, sigma=1.6, size=width)),
                        min_flow, max_flow).astype(np.int64)
        d = np.zeros((m, m), dtype=np.int64)
        s = rng.integers(0, m, size=width)
        r = rng.integers(0, m, size=width)
        bad = s == r
        r[bad] = (r[bad] + 1 + rng.integers(0, m - 1, size=int(bad.sum()))) % m
        np.add.at(d, (s, r), sizes)
        demands.append(d)
    return demands


# --------------------------------------------------------------------------
# generalized primitives (scenario registry building blocks)
# --------------------------------------------------------------------------

def sample_width(rng: np.random.Generator, dist: tuple, cap: int) -> int:
    """One coflow width from a parameterized distribution, capped at `cap`.

    dist forms: ("loguniform", lo, hi) | ("uniform", lo, hi) | ("fixed", k).
    """
    kind = dist[0]
    if kind == "loguniform":
        lo, hi = int(dist[1]), max(int(dist[2]), int(dist[1]) + 1)
        w = int(round(10 ** rng.uniform(math.log10(max(lo, 1)),
                                        math.log10(hi))))
    elif kind == "uniform":
        w = int(rng.integers(int(dist[1]), int(dist[2]) + 1))
    elif kind == "fixed":
        w = int(dist[1])
    else:
        raise ValueError(f"unknown width distribution {kind!r}")
    return max(1, min(w, cap))


def sample_sizes(
    rng: np.random.Generator, n: int, dist: tuple,
    clip: tuple[int, int] = (1, 2472),
) -> np.ndarray:
    """`n` flow sizes from a parameterized distribution, clipped to `clip`.

    dist forms: ("lognormal", mean, sigma) | ("uniform", lo, hi) |
    ("pareto", shape, scale) | ("fixed", v).
    """
    kind = dist[0]
    if kind == "lognormal":
        raw = rng.lognormal(mean=float(dist[1]), sigma=float(dist[2]), size=n)
    elif kind == "uniform":
        raw = rng.uniform(float(dist[1]), float(dist[2]), size=n)
    elif kind == "pareto":
        raw = float(dist[2]) * (1.0 + rng.pareto(float(dist[1]), size=n))
    elif kind == "fixed":
        raw = np.full(n, float(dist[1]))
    else:
        raise ValueError(f"unknown size distribution {kind!r}")
    return np.clip(np.round(raw), clip[0], clip[1]).astype(np.int64)


def port_skew(m: int, kind: str = "uniform", *, hot: int = 1,
              hot_mass: float = 0.9, a: float = 1.2) -> np.ndarray | None:
    """Port-popularity map: probability vector over the m ports (or None
    for uniform).

    kinds: "uniform"; "hotspot" — `hot` ports share `hot_mass` of the
    traffic (incast/alibaba fan-in); "zipf" — p(rank) ∝ 1/rank^a.
    """
    if kind == "uniform":
        return None
    if kind == "hotspot":
        hot = max(1, min(int(hot), m))
        p = np.full(m, (1.0 - hot_mass) / max(m - hot, 1))
        p[:hot] = hot_mass / hot
        if hot == m:
            p[:] = 1.0 / m
        return p / p.sum()
    if kind == "zipf":
        p = 1.0 / np.arange(1, m + 1, dtype=np.float64) ** a
        return p / p.sum()
    raise ValueError(f"unknown port skew {kind!r}")


def sample_coflows(
    m: int,
    n_coflows: int,
    seed: int = 0,
    *,
    width_dist: tuple = ("loguniform", 10, 21170),
    size_dist: tuple = ("lognormal", 3.0, 1.6),
    size_clip: tuple[int, int] = (1, 2472),
    src_skew: np.ndarray | None = None,
    dst_skew: np.ndarray | None = None,
) -> list[np.ndarray]:
    """Generalized coflow sampler: `fb_like_coflows` with parameterized
    width/size distributions and per-port popularity maps.

    Flows landing on the same (src, dst) pair accumulate, exactly like the
    FB sampler; self-loops are remapped to a uniformly-random other port."""
    rng = np.random.default_rng(seed)
    demands: list[np.ndarray] = []
    for _ in range(max(1, n_coflows)):
        width = sample_width(rng, width_dist, cap=m * (m - 1))
        sizes = sample_sizes(rng, width, size_dist, size_clip)
        s = rng.choice(m, size=width, p=src_skew)
        r = rng.choice(m, size=width, p=dst_skew)
        bad = s == r
        r[bad] = (r[bad] + 1 + rng.integers(0, m - 1, size=int(bad.sum()))) % m
        d = np.zeros((m, m), dtype=np.int64)
        np.add.at(d, (s, r), sizes)
        demands.append(d)
    return demands


def dag_edges(
    n: int, family: str, rng: np.random.Generator, edge_prob: float = 0.5,
) -> list[tuple[int, int]]:
    """Starts-After edges over coflows 0..n-1 from a named DAG family.

    families: "general" (each forward edge w.p. `edge_prob` — the paper's
    §VII random DAG), "tree" (fan-in tree toward root n-1 — the paper's
    rooted conversion), "chain" (0 -> 1 -> ... -> n-1), "star" (every
    non-root -> root n-1: wide-and-shallow map-reduce), "independent"
    (no edges).  "general"/"tree" consume the same RNG stream as the
    legacy `build_jobs` branches."""
    edges: list[tuple[int, int]] = []
    if n <= 1:
        return edges
    if family == "tree":
        for a in range(n - 1):
            b = int(rng.integers(a + 1, n))
            edges.append((a, b))
    elif family == "general":
        for a in range(n):
            for b in range(a + 1, n):
                if rng.random() < edge_prob:
                    edges.append((a, b))
    elif family == "chain":
        edges = [(k, k + 1) for k in range(n - 1)]
    elif family == "star":
        edges = [(a, n - 1) for a in range(n - 1)]
    elif family == "independent":
        pass
    else:
        raise ValueError(f"unknown DAG family {family!r}")
    return edges


def build_jobs(
    demands: list[np.ndarray],
    mu_bar: int = 5,
    seed: int = 0,
    rooted: bool = False,
    weights: str = "equal",   # "equal" | "random"
    dag: str | None = None,   # None -> "tree" if rooted else "general"
    mu_fixed: int | None = None,  # exact coflows per job (else ~mu_bar avg)
) -> Instance:
    rng = np.random.default_rng(seed + 1)
    m = demands[0].shape[0]
    order = rng.permutation(len(demands))
    family = dag if dag is not None else ("tree" if rooted else "general")
    jobs: list[Job] = []
    pos = 0
    jid = 0
    while pos < len(order):
        if mu_fixed is not None:
            size = max(1, int(mu_fixed))
        else:
            size = int(rng.integers(1, 2 * mu_bar)) if mu_bar > 1 else 1
        group = order[pos:pos + size]
        pos += size
        coflows = [Coflow(jid, k, demands[g]) for k, g in enumerate(group)]
        edges = dag_edges(len(coflows), family, rng)
        w = 1.0 if weights == "equal" else float(rng.uniform(0.0, 1.0)) or 1e-3
        jobs.append(Job(jid, coflows, edges, weight=w, release=0))
        jid += 1
    return Instance(m, jobs)


def theta0(instance: Instance) -> float:
    """Base arrival rate (paper §VII-B.2): total #coflows / sum of coflow
    effective sizes."""
    n_cf = sum(j.mu for j in instance.jobs)
    tot = sum(c.D for j in instance.jobs for c in j.coflows)
    return n_cf / max(tot, 1)


def poisson_releases(instance: Instance, theta: float, seed: int = 0) -> Instance:
    """Return a copy of the instance with Poisson(theta) arrival times."""
    rng = np.random.default_rng(seed + 2)
    gaps = rng.exponential(1.0 / theta, size=len(instance.jobs))
    cum = np.cumsum(gaps)
    if cum.size and cum[-1] >= 2.0**53:
        # float64 integer exactness ends at 2^53; see stream.arrival_times
        raise ValueError(
            f"cumulative release time {cum[-1]:.3g} exceeds the float64 "
            "integer-exact range (2^53); raise theta or shrink the instance")
    times = np.floor(cum).astype(np.int64)
    jobs = []
    for j, t in zip(instance.jobs, times):
        import dataclasses
        jobs.append(dataclasses.replace(j, release=int(t)))
    return Instance(instance.m, jobs)


def paper_workload(
    m: int = 150,
    mu_bar: int = 5,
    seed: int = 0,
    scale: float = 1.0,
    rooted: bool = False,
    weights: str = "equal",
) -> Instance:
    """One line to the paper's §VII setup (synthetic-calibrated)."""
    demands = fb_like_coflows(m=m, seed=seed, scale=scale)
    return build_jobs(demands, mu_bar=mu_bar, seed=seed, rooted=rooted, weights=weights)


def workload_stats(instance: Instance) -> dict:
    sizes = [int(c.demand[c.demand > 0].min()) for j in instance.jobs
             for c in j.coflows if (c.demand > 0).any()]
    sizes_max = [int(c.demand.max()) for j in instance.jobs for c in j.coflows]
    eff = [c.D for j in instance.jobs for c in j.coflows]
    widths = [int((c.demand > 0).sum()) for j in instance.jobs for c in j.coflows]
    # DAG-shape statistics: depth = longest Starts-After path (edges), fan-in/
    # fan-out = max parent/child count of any coflow, tree fraction = share of
    # jobs whose dependency graph is a rooted (fan-in or fan-out) tree.
    depths = [max(len(coflow_layers(j)) - 1, 0) for j in instance.jobs]
    fan_in = [max((len(p) for p in parents_of(j.mu, j.edges)), default=0)
              for j in instance.jobs]
    fan_out = [max((len(c) for c in children_of(j.mu, j.edges)), default=0)
               for j in instance.jobs]
    trees = [is_rooted_tree(j) for j in instance.jobs]
    return dict(
        m=instance.m,
        n_jobs=instance.n,
        n_coflows=sum(j.mu for j in instance.jobs),
        min_flow=min(sizes, default=0),
        max_flow=max(sizes_max, default=0),
        min_width=min(widths, default=0),
        max_width=max(widths, default=0),
        min_eff=min(eff, default=0),
        max_eff=max(eff, default=0),
        delta=instance.delta(),
        dag_depth_max=max(depths, default=0),
        dag_depth_mean=float(np.mean(depths)) if depths else 0.0,
        max_fan_in=max(fan_in, default=0),
        max_fan_out=max(fan_out, default=0),
        tree_fraction=float(np.mean(trees)) if trees else 0.0,
    )
