"""Workload generation (paper §VII).

The paper evaluates on a Facebook Hive/MapReduce trace (150 racks, 267
coflows, flow sizes in [1, 2472], coflow effective sizes in [5, 232145],
aggregate effective size Delta = 440419). That trace is not redistributable
offline, so `fb_like_coflows` generates a calibrated synthetic workload that
matches the published marginal statistics: log-uniform coflow widths in
[10, 21170] flows, heavy-tailed (lognormal) flow sizes clipped to [1, 2472],
uniform port mapping. EXPERIMENTS.md records the achieved statistics next
to the paper's.

Job construction follows §VII exactly: coflows are randomly partitioned into
jobs with mu_bar coflows on average; general-DAG jobs draw each forward edge
with probability 0.5; rooted-tree jobs convert the random graph to a fan-in
tree (equivalently: each non-root node keeps one out-edge to a random
higher-indexed node). Weights are equal or Uniform(0, 1]; releases are 0
(offline) or Poisson arrivals with rate theta (online).
"""
from __future__ import annotations

import math

import numpy as np

from .types import Coflow, Instance, Job

__all__ = [
    "fb_like_coflows",
    "build_jobs",
    "paper_workload",
    "poisson_releases",
    "theta0",
    "workload_stats",
]

# Published trace statistics (paper §VII "Workload")
PAPER_STATS = dict(m=150, n_coflows=267, min_flow=1, max_flow=2472,
                   min_width=10, max_width=21170, delta=440419)


def fb_like_coflows(
    m: int = 150,
    n_coflows: int = 267,
    seed: int = 0,
    scale: float = 1.0,
    min_flow: int = 1,
    max_flow: int = 2472,
    min_width: int = 10,
    max_width: int = 21170,
) -> list[np.ndarray]:
    """Synthetic FB-like coflows: list of (m, m) int64 demand matrices.

    scale < 1 shrinks coflow count and widths proportionally (benchmark fast
    mode); statistics per coflow are preserved."""
    rng = np.random.default_rng(seed)
    n = max(1, int(round(n_coflows * scale)))
    wmax = max(min_width, int(round(max_width * scale)))
    demands: list[np.ndarray] = []
    for _ in range(n):
        width = int(round(10 ** rng.uniform(math.log10(min_width),
                                            math.log10(max(wmax, min_width + 1)))))
        width = min(width, m * (m - 1))
        sizes = np.clip(np.round(rng.lognormal(mean=3.0, sigma=1.6, size=width)),
                        min_flow, max_flow).astype(np.int64)
        d = np.zeros((m, m), dtype=np.int64)
        s = rng.integers(0, m, size=width)
        r = rng.integers(0, m, size=width)
        bad = s == r
        r[bad] = (r[bad] + 1 + rng.integers(0, m - 1, size=int(bad.sum()))) % m
        np.add.at(d, (s, r), sizes)
        demands.append(d)
    return demands


def build_jobs(
    demands: list[np.ndarray],
    mu_bar: int = 5,
    seed: int = 0,
    rooted: bool = False,
    weights: str = "equal",   # "equal" | "random"
) -> Instance:
    rng = np.random.default_rng(seed + 1)
    m = demands[0].shape[0]
    order = rng.permutation(len(demands))
    jobs: list[Job] = []
    pos = 0
    jid = 0
    while pos < len(order):
        size = int(rng.integers(1, 2 * mu_bar)) if mu_bar > 1 else 1
        group = order[pos:pos + size]
        pos += size
        coflows = [Coflow(jid, k, demands[g]) for k, g in enumerate(group)]
        n = len(coflows)
        edges: list[tuple[int, int]] = []
        if rooted and n > 1:
            # fan-in tree toward root n-1: each node keeps one out-edge
            for a in range(n - 1):
                b = int(rng.integers(a + 1, n))
                edges.append((a, b))
        elif n > 1:
            for a in range(n):
                for b in range(a + 1, n):
                    if rng.random() < 0.5:
                        edges.append((a, b))
        w = 1.0 if weights == "equal" else float(rng.uniform(0.0, 1.0)) or 1e-3
        jobs.append(Job(jid, coflows, edges, weight=w, release=0))
        jid += 1
    return Instance(m, jobs)


def theta0(instance: Instance) -> float:
    """Base arrival rate (paper §VII-B.2): total #coflows / sum of coflow
    effective sizes."""
    n_cf = sum(j.mu for j in instance.jobs)
    tot = sum(c.D for j in instance.jobs for c in j.coflows)
    return n_cf / max(tot, 1)


def poisson_releases(instance: Instance, theta: float, seed: int = 0) -> Instance:
    """Return a copy of the instance with Poisson(theta) arrival times."""
    rng = np.random.default_rng(seed + 2)
    gaps = rng.exponential(1.0 / theta, size=len(instance.jobs))
    times = np.floor(np.cumsum(gaps)).astype(np.int64)
    jobs = []
    for j, t in zip(instance.jobs, times):
        import dataclasses
        jobs.append(dataclasses.replace(j, release=int(t)))
    return Instance(instance.m, jobs)


def paper_workload(
    m: int = 150,
    mu_bar: int = 5,
    seed: int = 0,
    scale: float = 1.0,
    rooted: bool = False,
    weights: str = "equal",
) -> Instance:
    """One line to the paper's §VII setup (synthetic-calibrated)."""
    demands = fb_like_coflows(m=m, seed=seed, scale=scale)
    return build_jobs(demands, mu_bar=mu_bar, seed=seed, rooted=rooted, weights=weights)


def workload_stats(instance: Instance) -> dict:
    sizes = [int(c.demand[c.demand > 0].min()) for j in instance.jobs
             for c in j.coflows if (c.demand > 0).any()]
    sizes_max = [int(c.demand.max()) for j in instance.jobs for c in j.coflows]
    eff = [c.D for j in instance.jobs for c in j.coflows]
    widths = [int((c.demand > 0).sum()) for j in instance.jobs for c in j.coflows]
    return dict(
        m=instance.m,
        n_jobs=instance.n,
        n_coflows=sum(j.mu for j in instance.jobs),
        min_flow=min(sizes, default=0),
        max_flow=max(sizes_max, default=0),
        min_width=min(widths, default=0),
        max_width=max(widths, default=0),
        min_eff=min(eff, default=0),
        max_eff=max(eff, default=0),
        delta=instance.delta(),
    )
