"""Event-driven scheduling session — the paper's §VII-C.2 protocol as a
stateful API.

The online protocol is inherently event-driven: arrivals suspend the active
plan and trigger a reschedule over residual demand.  ``SchedulerSession``
exposes exactly that shape —

    session = SchedulerSession(m, "gdm", seed=0)
    session.submit(job)          # enqueue an arrival (release may be future)
    session.advance(until=t)     # execute the active plan up to wall-clock t
    session.frontier()           # live view: planned completions, busy end
    session.snapshot()           # residual-demand ledger, for introspection
    session.result()             # OnlineResult once everything drained

— and owns the two pieces of state that previously lived as locals inside
``simulate_online``: the **residual-demand ledger** (integer packets
remaining per coflow edge) and the **cumulative-flooring executor** (partial
plan windows bank integer packets against a running fractional total, so
backfilled transcripts cannot livelock the reschedule loop).
``simulate_online`` and ``engine.plan_online`` are thin, results-identical
drivers over a session; the historical closed batch loop is retained as
``simulate_online(..., driver="batch")``, the reference comparator.

Plan repair (frontier append)
-----------------------------
A ``submit`` normally invalidates the active plan and the next ``advance``
replans the full residual instance (the paper's protocol).  When the
arrival *only appends work past the current frontier*, the session instead
splices the new job into the retained merge-and-fix expansion
(``FinalSchedule.spliced``) and plans only the new job — the ROADMAP's
incremental plan-repair item.  The fast path fires only when it is provably
results-identical to the full replan, which currently means the
job-sequential ``om_alg`` scheduler with:

* every unfinished coflow untouched since the epoch's plan (its residual
  demand bit-equal to the plan-time demand — the arrival landed on a clean
  cut of the sequential schedule);
* the Algorithm 5 order of the new residual instance keeping the retained
  jobs in their planned order with every new job appended at the tail;
* the retained ledger windows equal to the windows a from-scratch
  ``om_alg`` replan would emit (checked structurally: back-to-back
  effective-size windows in topological order — this check is what makes
  the path self-verifying rather than trusted).

Spread-mode G-DM and G-DM-RT (``delays="spread"``) take a group-aware
variant of the fast path: their delays are deterministic (zero rng draws),
so a DMA/DMA-SRT group layout is a pure function of the group's member
jobs and residual demands, and it is translation invariant —
``dma(jobs, origin=o)`` is ``dma(jobs, origin=0)`` slid by ``o``.  The
repair therefore re-derives the Algorithm 5 order and geometric grouping
of the residual instance and walks the replan's group chain: a retained
group whose membership matches an old group verbatim and whose residuals
are bit-equal to the plan-time snapshot is **reused as one block**, slid
from its old chain position to the one the replan would assign
(``FinalSchedule.shifted_expanded`` — sound at *any* integer offset by
translation invariance, not just the aligned ``origin == tau + cursor``
position the pre-PR-10 gate demanded) — including non-singleton and
expanded (alpha > 1) groups; every other group (the in-flight group an
arrival interrupted, groups whose membership changed, groups holding new
jobs) is rebuilt through the backend's **group-block cache**
(``backend.group_block``): the exact spread-mode ``dma``/``dma_rt``
construction — including DMA-SRT's forest/start-after-parents fallback —
built once at origin 0 and slid into place.  The result is bit-identical
to the full replan by construction; the repair is counted as a hit when
at least one block was reused, and per-group reuse counts land in
``SessionStats.groups_reused`` / ``groups_replanned``.  Randomized
G-DM/G-DM-RT always fall back (their delays re-draw per plan).
Repair/replan counts, the repair hit rate, and warm-replan wall-clock are
reported in :class:`SessionStats` alongside the engine's BNA/order cache
stats.  ``repair="legacy"`` keeps the pre-generalization gate (om_alg +
singleton spread-mode G-DM, whole plan retained at its aligned position)
for before/after hit-rate comparisons — ``benchmarks/serve_stream.py``
reports the delta.

Pinned gamma (``gamma="pinned"``)
---------------------------------
Even with the grouped certification, the repair fires rarely in pure mode
because the *geometric grouping itself* drifts: the paper's gamma is the
residual instance's min positive flow size, which changes on nearly every
arrival and re-buckets every retained job.  ``gamma="pinned"`` hands
ownership of gamma to the session: a :class:`~repro.core.gdm.GammaEpoch`
pins the first residual's natural gamma and thereafter rescales
monotonically downward by powers of two only when a later residual's
natural gamma drops below the pin (counted in
``SessionStats.gamma_rescales``; the grouping analysis holds up to the
pin's bounded ratio — see core/gdm.py).  The pin is observed once per
planning event from the residual instance — a pure function of the
residual sequence, replicated verbatim by ``simulate_online``'s batch
driver, so stream-vs-batch bit-identity is preserved — and threaded to
both the repair's ``group_jobs`` call and the full replan
(``plan_full(sub, gamma=...)``).  ``gamma=<positive int/Fraction>`` pins
a fixed value instead; ``gamma="residual"`` (default) keeps the paper's
per-residual gamma.  Pinning requires an engine scheduler whose factory
takes the ``gamma`` plan option (the G-DM family); the epoch state rides
along in :class:`SessionSnapshot` so kill-and-resume keeps the pin.

Backpressure (sustained arrivals)
---------------------------------
Under sustained arrivals, full replans are the expensive event: when too
many recent reschedules missed the repair path, a serving layer should
stop admitting work mid-window and wait for a clean cut.  The session
tracks exactly that signal: ``replan_debt`` is the full-replan fraction
over a sliding window of recent reschedules, and with an
:class:`AdmissionPolicy` attached, :meth:`SchedulerSession.backpressure`
turns on once the debt exceeds ``replan_budget`` (after ``window // 2``
reschedules of warm-up).  The policy also carries ``max_pending``, the
bound on the *caller's* deferred-arrivals queue — ``core.stream`` defers
arrivals to the next planned completion boundary while backpressure holds
and rejects beyond the bound, and ``serve.engine`` holds its admission
queue under the same signal; deferral/reject counts are surfaced in
``SessionStats.admission_deferred`` / ``admission_rejects``.

Engine-backed planning events prefetch the whole residual instance's
decompositions in one batched call — ``backend.prefetch_plan``, issued
inside ``plan_full``; it dispatches to the jit planning pipeline or to
``bna_pieces_many`` per ``REPRO_PLAN_BACKEND`` — before the
scheduler walks jobs one by one — the engine's instance-level batching
(see ``core/matching.py``); the repair path prefetches the newly-arrived
jobs the same way.  Plain-callable schedulers are left unprefetched (the
session cannot know whether they decompose demands at all).
"""
from __future__ import annotations

import math
import time
from bisect import insort
from dataclasses import dataclass, field

import numpy as np

from .result import CompositeSchedule, Transcript
from .types import Coflow, Instance, Job, effective_size, topological_order

__all__ = [
    "AdmissionPolicy",
    "SchedulerSession",
    "SessionStats",
    "Frontier",
    "SessionSnapshot",
    "sub_instance",
    "execute_transcript",
]

_EPS = 1e-9


# --------------------------------------------------------------------------
# the residual-demand machinery (previously simulate_online's locals)
# --------------------------------------------------------------------------

def sub_instance(
    active: list[Job],
    remaining: dict[tuple[int, int], np.ndarray],
    done: dict[tuple[int, int], float],
    m: int,
) -> tuple[Instance, dict[int, list[int]]]:
    """Remaining-demand instance at a rescheduling point; all jobs present
    (release 0). cid_maps[jid] maps sub-instance cid -> original cid."""
    sub_jobs: list[Job] = []
    cid_maps: dict[int, list[int]] = {}
    for j in active:
        keep = [c.cid for c in j.coflows if (j.jid, c.cid) not in done]
        if not keep:
            continue
        idx = {orig: k for k, orig in enumerate(keep)}
        coflows = [Coflow(j.jid, idx[orig], remaining[(j.jid, orig)]) for orig in keep]
        edges = [(idx[a], idx[b]) for a, b in j.edges if a in idx and b in idx]
        sub_jobs.append(Job(j.jid, coflows, edges, weight=j.weight, release=0))
        cid_maps[j.jid] = keep
    return Instance(m, sub_jobs), cid_maps


def execute_transcript(
    transcript: Transcript,
    horizon: float,
    t0_abs: float,
    cid_maps: dict[int, list[int]],
    remaining: dict[tuple[int, int], np.ndarray],
    done: dict[tuple[int, int], float],
) -> None:
    """Apply transcript (local time) up to `horizon`; floor partial windows.

    Flooring is *cumulative* per coflow edge, not per entry: backfilled
    transcripts split a flow's units fractionally across many windows, and
    flooring each window independently can yield zero progress forever
    (0.5 + 0.5 -> 0 + 0), livelocking the reschedule loop.  Accumulating
    the fractional units and banking integer packets whenever the running
    total crosses an integer keeps partial windows conservative while
    guaranteeing progress (the 1e-6 slack absorbs the backfill sweep's
    conservation tolerance)."""
    acc: dict[tuple[int, int], np.ndarray] = {}
    banked: dict[tuple[int, int], np.ndarray] = {}
    for e in sorted(transcript.entries, key=lambda e: e.t1):
        if e.units.size == 0:
            if e.t1 <= horizon + _EPS:
                key = (e.jid, cid_maps[e.jid][e.cid])
                done.setdefault(key, t0_abs + e.t1)
            continue
        if e.t0 >= horizon:
            continue
        if e.t1 <= horizon + _EPS:
            amount = e.units
            end = e.t1
        else:
            frac = (horizon - e.t0) / (e.t1 - e.t0)
            amount = np.floor(e.units * frac)
            end = horizon
        key = (e.jid, cid_maps[e.jid][e.cid])
        rem = remaining[key]
        a = acc.setdefault(key, np.zeros_like(rem, dtype=np.float64))
        t = banked.setdefault(key, np.zeros_like(rem))
        a[e.srcs, e.dsts] += amount
        cur = a[e.srcs, e.dsts]
        if cur.size and float(cur.max()) >= 2.0**53:
            # past 2^53 float64 drops integer precision and the banked
            # floor could silently lose (or invent) packets
            raise ValueError(
                "cumulative edge units exceed the float64 integer-exact "
                f"range (2^53) for job {e.jid} coflow {e.cid}")
        avail = np.floor(cur + 1e-6).astype(np.int64) \
            - t[e.srcs, e.dsts]
        take = np.minimum(np.maximum(avail, 0), rem[e.srcs, e.dsts])
        t[e.srcs, e.dsts] += take
        rem[e.srcs, e.dsts] -= take
        if rem.sum() == 0 and key not in done:
            done[key] = t0_abs + end


# --------------------------------------------------------------------------
# public session state views
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class AdmissionPolicy:
    """Replan-budget backpressure policy for sustained arrivals.

    ``replan_budget`` is the tolerated full-replan fraction over the last
    ``window`` reschedules (the session's ``replan_debt``); above it,
    :meth:`SchedulerSession.backpressure` turns on and admission layers
    (``core.stream``, ``serve.engine``) hold arrivals for the next clean
    cut.  ``max_pending`` bounds the caller's deferred-arrivals queue —
    past it, arrivals are rejected (counted in
    ``SessionStats.admission_rejects``)."""

    max_pending: int = 64
    replan_budget: float = 0.5
    window: int = 32

    def __post_init__(self):
        if not (isinstance(self.max_pending, int) and self.max_pending >= 1):
            raise ValueError(f"max_pending must be a positive int, "
                             f"got {self.max_pending!r}")
        if not 0.0 <= self.replan_budget <= 1.0:
            raise ValueError(f"replan_budget must be in [0, 1], "
                             f"got {self.replan_budget!r}")
        if not (isinstance(self.window, int) and self.window >= 2):
            raise ValueError(f"window must be an int >= 2, "
                             f"got {self.window!r}")


@dataclass
class SessionStats:
    """Planning-side counters for one session.

    ``reschedules`` counts every planning event; ``repairs`` of those took
    the frontier-append fast path, ``full_replans`` planned the residual
    instance from scratch, and ``repair_rejects`` attempted the fast path
    but failed a soundness check (and fell back — they are counted inside
    ``full_replans`` too).  The grouped repair path (spread-mode G-DM /
    G-DM-RT) additionally counts reused vs recomputed geometric groups;
    ``gamma_rescales`` is the pinned-gamma epoch's cumulative power-of-two
    downscale count (0 under ``gamma="residual"``);
    ``replan_debt`` is the windowed full-replan fraction the
    :class:`AdmissionPolicy` compares against its budget, and
    ``admission_deferred`` / ``admission_rejects`` count arrivals the
    admission layer held for a clean cut / dropped at the queue bound."""

    reschedules: int = 0
    full_replans: int = 0
    repairs: int = 0
    repair_rejects: int = 0
    groups_reused: int = 0
    groups_replanned: int = 0
    gamma_rescales: int = 0
    admission_deferred: int = 0
    admission_rejects: int = 0
    replan_debt: float = 0.0
    plan_wall_s: float = 0.0
    first_plan_wall_s: float = 0.0
    repair_wall_s: float = 0.0

    @property
    def repair_hit_rate(self) -> float:
        return self.repairs / self.reschedules if self.reschedules else 0.0

    @property
    def warm_replan_wall_s(self) -> float:
        """Wall-clock spent planning after the cold first plan."""
        return max(self.plan_wall_s - self.first_plan_wall_s, 0.0)

    def as_dict(self) -> dict:
        return {
            "reschedules": self.reschedules,
            "full_replans": self.full_replans,
            "repairs": self.repairs,
            "repair_rejects": self.repair_rejects,
            "repair_hit_rate": self.repair_hit_rate,
            "groups_reused": self.groups_reused,
            "groups_replanned": self.groups_replanned,
            "gamma_rescales": self.gamma_rescales,
            "admission_deferred": self.admission_deferred,
            "admission_rejects": self.admission_rejects,
            "replan_debt": self.replan_debt,
            "plan_wall_s": self.plan_wall_s,
            "first_plan_wall_s": self.first_plan_wall_s,
            "warm_replan_wall_s": self.warm_replan_wall_s,
            "repair_wall_s": self.repair_wall_s,
        }


@dataclass
class Frontier:
    """The session's live planning frontier at wall-clock ``now``.

    ``completions`` maps every job with unfinished work to its *planned*
    absolute completion under the active plan; ``finished`` maps drained
    jobs to their actual completion (a live VIEW of session state, not a
    copy — treat it as read-only); ``pending`` lists submitted jobs whose
    release is still in the future.  ``busy_until`` is the absolute end of
    the currently planned work (== ``now`` when the system is idle)."""

    now: float
    busy_until: float
    completions: dict[int, float]
    finished: dict[int, float]
    pending: tuple[int, ...]

    def completion(self, jid: int, default: float = math.inf) -> float:
        """Planned (active) or actual (finished) completion of a job."""
        if jid in self.completions:
            return self.completions[jid]
        return self.finished.get(jid, default)

    def order(self) -> list[int]:
        """Active + finished jids by (planned or actual) completion."""
        known = {**self.finished, **self.completions}
        return sorted(known, key=lambda jid: (known[jid], jid))


@dataclass
class SessionSnapshot:
    """Deep-copied view of the session's residual-demand ledger.  Carries
    everything :meth:`SchedulerSession.restore` needs (besides the Job
    objects themselves) to continue bit-identically after a driver kill."""

    now: float
    m: int
    submitted: tuple[int, ...]
    active: tuple[int, ...]           # jids with unfinished work
    pending: tuple[int, ...]          # jids not yet released
    remaining: dict[tuple[int, int], np.ndarray]
    done: dict[tuple[int, int], float]
    reschedules: int
    gamma_epoch: tuple | None = None   # GammaEpoch.state(), for pinned gamma

    def remaining_total(self) -> int:
        return int(sum(int(r.sum()) for r in self.remaining.values()))


# --------------------------------------------------------------------------
# epoch (one plan's lifetime between reschedules)
# --------------------------------------------------------------------------

@dataclass
class _Epoch:
    t0: float                          # absolute plan time
    transcript: Transcript
    cid_maps: dict[int, list[int]]
    sub: Instance
    plan: "object | None"              # engine PlanResult when available
    base_remaining: dict[tuple[int, int], np.ndarray]
    exec_horizon: float = 0.0          # relative horizon executed so far
    completions: dict[int, float] = field(default_factory=dict)

    _busy_end: float | None = None

    @property
    def busy_end(self) -> float:
        """Relative end of the last transcript entry; past this the epoch is
        fully executed and further advances are no-ops."""
        if self._busy_end is None:
            self._busy_end = max((e.t1 for e in self.transcript.entries),
                                 default=0.0)
        return self._busy_end


class SchedulerSession:
    """One stateful scheduling surface for offline, online, and serving-time
    coflow scheduling (see module docstring)."""

    def __init__(self, m: int, scheduler="gdm", *, repair: "bool | str" = True,
                 admission: AdmissionPolicy | None = None,
                 gamma: "str | int | object" = "residual", **opts):
        from . import backend
        from .gdm import GammaEpoch

        self.m = int(m)
        if repair not in (True, False, "legacy"):
            raise ValueError(f"repair must be True, False, or 'legacy', "
                             f"got {repair!r}")
        self.repair = repair
        self.admission = admission
        self._gamma_epoch = GammaEpoch.from_policy(gamma)
        window = admission.window if admission is not None else 32
        self._recent_outcomes: list[int] = []   # 1 = full replan, 0 = repair
        self._recent_window = window
        self._scheduler_name = scheduler if isinstance(scheduler, str) \
            else getattr(scheduler, "name", None)
        if isinstance(scheduler, str):
            from .engine import make_scheduler

            scheduler = make_scheduler(scheduler, **opts)
        elif opts:
            raise TypeError("scheduler options are only accepted with a "
                            "scheduler name, not a prebuilt scheduler")
        self._scheduler = scheduler
        if self._gamma_epoch is not None:
            from .engine import scheduler_options

            try:
                gamma_ok = isinstance(self._scheduler_name, str) and \
                    "gamma" in scheduler_options(self._scheduler_name)
            except KeyError:
                gamma_ok = False
            if not gamma_ok:
                raise ValueError(
                    f"gamma={gamma!r} needs an engine scheduler taking the "
                    f"'gamma' plan option (the G-DM family); "
                    f"got {self._scheduler_name!r}")
        self._jobs: list[Job] = []                     # submission order
        self._by_jid: dict[int, Job] = {}
        self._pending: list[tuple[float, int, Job]] = []   # (release, jid, job)
        self._active: list[Job] = []
        self._finished: dict[int, float] = {}          # drained jid -> completion
        self._remaining: dict[tuple[int, int], np.ndarray] = {}
        self._done: dict[tuple[int, int], float] = {}
        self._t = 0.0
        self._dirty = False
        self._arrived_since_plan: list[Job] = []
        self._epoch: _Epoch | None = None
        self._last_plan = None                         # last engine PlanResult
        self.stats = SessionStats()
        self._cache_before = backend.cache_stats()

    @classmethod
    def restore(cls, snapshot: SessionSnapshot, jobs: list[Job], scheduler="gdm",
                *, repair: "bool | str" = True,
                admission: AdmissionPolicy | None = None,
                gamma: "str | int | object" = "residual",
                **opts) -> "SchedulerSession":
        """Rebuild a session from a :meth:`snapshot` plus the submitted Job
        objects — the kill-and-resume path.  The restored session holds the
        same residual-demand ledger and completion stamps; its first
        planning event is a full replan of the residual instance (the
        retained expansion is not serialized), which the repair
        certification already guarantees is results-identical — so a stream
        resumed from a snapshot taken at an arrival event continues
        bit-identically (tests/test_stream.py proves it across the online
        matrix).  Stats counters restart from zero — except the gamma
        epoch, which resumes from ``snapshot.gamma_epoch`` (pin AND
        cumulative rescale count) when the restored session also pins, so
        the grouping scale continues exactly where the killed session left
        it."""
        s = cls(snapshot.m, scheduler, repair=repair, admission=admission,
                gamma=gamma, **opts)
        if s._gamma_epoch is not None and not s._gamma_epoch.fixed \
                and snapshot.gamma_epoch is not None:
            from .gdm import GammaEpoch

            s._gamma_epoch = GammaEpoch.from_state(snapshot.gamma_epoch)
        by_jid = {j.jid: j for j in jobs}
        missing = [jid for jid in snapshot.submitted if jid not in by_jid]
        if missing:
            raise ValueError(f"restore needs every submitted job; "
                             f"missing jids {missing}")
        s._t = float(snapshot.now)
        pending = set(snapshot.pending)
        active = set(snapshot.active)
        for jid in snapshot.submitted:
            job = by_jid[jid]
            s._jobs.append(job)
            s._by_jid[jid] = job
        s._remaining = {k: v.copy() for k, v in snapshot.remaining.items()}
        s._done = dict(snapshot.done)
        s._active = [by_jid[jid] for jid in snapshot.submitted
                     if jid in active]
        for jid in snapshot.submitted:
            if jid in pending:
                job = by_jid[jid]
                insort(s._pending, (float(job.release), jid, job))
            elif jid not in active:
                job = by_jid[jid]
                cs = [s._done[(jid, c.cid)] for c in job.coflows
                      if (jid, c.cid) in s._done]
                s._finished[jid] = max(cs, default=float(job.release))
        s._dirty = bool(s._active)
        return s

    # --- basic views --------------------------------------------------------

    @property
    def now(self) -> float:
        return self._t

    @property
    def done(self) -> bool:
        """True once every submitted job has drained."""
        return not self._pending and not self._work_remaining()

    @property
    def last_plan(self):
        """The engine PlanResult of the most recent planning event (None for
        plain-callable schedulers, which expose only a transcript)."""
        return self._last_plan

    @property
    def replan_debt(self) -> float:
        """Full-replan fraction over the recent-reschedule window (0.0 while
        the window is empty) — the signal the admission policy budgets."""
        if not self._recent_outcomes:
            return 0.0
        return sum(self._recent_outcomes) / len(self._recent_outcomes)

    def backpressure(self) -> bool:
        """True when the attached :class:`AdmissionPolicy` says admission
        should hold arrivals for a clean cut: the windowed replan debt
        exceeds the replan budget.  Always False without a policy, and
        during the warm-up half-window (a single cold full replan must not
        stall admission)."""
        pol = self.admission
        if pol is None:
            return False
        if len(self._recent_outcomes) < max(2, pol.window // 2):
            return False
        return self.replan_debt > pol.replan_budget

    # --- event API ----------------------------------------------------------

    def submit(self, job: Job) -> None:
        """Enqueue an arrival.  A job released at or before ``now`` joins the
        active set immediately and suspends the current plan (the §VII-C.2
        protocol); a future release is admitted when ``advance`` reaches it."""
        if job.jid in self._by_jid:
            raise ValueError(f"job {job.jid} already submitted")
        if job.coflows and job.m != self.m:
            raise ValueError(f"job {job.jid} is on {job.m} ports, "
                             f"session on {self.m}")
        self._jobs.append(job)
        self._by_jid[job.jid] = job
        for c in job.coflows:
            rem = c.demand.astype(np.int64).copy()
            self._remaining[(job.jid, c.cid)] = rem
            if rem.sum() == 0:   # empty from the start: completes at release
                self._done[(job.jid, c.cid)] = float(job.release)
        if job.release <= self._t + _EPS:
            self._admit_job(job)
        else:
            insort(self._pending, (float(job.release), job.jid, job))

    def advance(self, until: float | None = None) -> float:
        """Run the event loop up to wall-clock ``until`` (None: drain every
        submitted job, jumping across idle gaps to future releases — the
        closed-batch behaviour).  Replans lazily whenever arrivals have
        suspended the active plan; returns the new ``now``."""
        if until is not None and until < self._t - _EPS:
            raise ValueError(f"cannot advance backwards "
                             f"(now={self._t}, until={until})")
        target = math.inf if until is None else float(until)
        drain = until is None
        while True:
            self._admit_due()
            self._prune_active()
            if not self._work_remaining():
                nxt = self._next_release()
                if nxt is not None and (drain or nxt <= target + _EPS):
                    self._t = max(self._t, nxt)   # idle jump to next arrival
                    continue
                break
            self._ensure_plan()
            nxt = self._next_release()
            horizon = min(target, nxt if nxt is not None else math.inf)
            self._execute_to(horizon)
            if math.isinf(horizon):
                # executed the full plan; land on the last completion and
                # loop around to drain any still-pending future releases
                self._t = max(self._t,
                              max(self._done.values(), default=self._t))
                continue
            self._t = max(self._t, horizon)
            if horizon >= target - _EPS:
                break
        if not drain:
            self._t = max(self._t, target)
        self._admit_due()   # arrivals landing exactly on `until` are due now
        return self._t

    def frontier(self) -> Frontier:
        """The live planning frontier.  Replans first if submissions have
        suspended the active plan (time does not move)."""
        if self._work_remaining():
            self._ensure_plan()
        self._prune_active()
        completions: dict[int, float] = {}
        busy = self._t
        if self._epoch is not None:
            for jid, t in self._epoch.completions.items():
                if jid not in self._finished:
                    completions[jid] = t
                    busy = max(busy, t)
        return Frontier(now=self._t, busy_until=busy, completions=completions,
                        finished=self._finished,
                        pending=tuple(jid for _, jid, _ in self._pending))

    def snapshot(self) -> SessionSnapshot:
        return SessionSnapshot(
            now=self._t,
            m=self.m,
            submitted=tuple(j.jid for j in self._jobs),
            active=tuple(j.jid for j in self._active if self._unfinished(j)),
            pending=tuple(jid for _, jid, _ in self._pending),
            remaining={k: v.copy() for k, v in self._remaining.items()},
            done=dict(self._done),
            reschedules=self.stats.reschedules,
            gamma_epoch=self._gamma_epoch.state()
            if self._gamma_epoch is not None else None,
        )

    def result(self):
        """OnlineResult over every submitted job; requires a drained session
        (``advance()`` with no ``until`` drains)."""
        from . import backend
        from .online import OnlineResult

        if not self.done:
            raise RuntimeError("result() before the session drained; call "
                               "advance() (no until) first, or inspect "
                               "snapshot()/frontier() mid-run")
        job_comp: dict[int, float] = {}
        for j in self._jobs:
            cs = [self._done[(j.jid, c.cid)] for c in j.coflows]
            job_comp[j.jid] = max(cs, default=float(j.release))
        stats: dict = {"session": self.stats.as_dict()}
        after = backend.cache_stats()
        for cache in ("bna", "order", "group"):
            hits = after[cache]["hits"] - self._cache_before[cache]["hits"]
            misses = after[cache]["misses"] - self._cache_before[cache]["misses"]
            total = hits + misses
            stats[cache] = {"hits": hits, "misses": misses,
                            "hit_rate": (hits / total) if total else 0.0}
        return OnlineResult(job_comp, Instance(self.m, list(self._jobs)),
                            self.stats.reschedules, stats)

    def backfilled_plan(self, exec: str = "packet"):
        """Backfill the current epoch's residual plan (§VII) without
        replanning — the session-aware entry into ``core.backfill``.
        Requires an engine scheduler (a plan, not just a transcript) and a
        plan that was not already backfilled."""
        from .backfill import backfill

        if self._work_remaining():
            self._ensure_plan()
        if self._epoch is None or self._epoch.plan is None:
            raise ValueError("no engine plan to backfill (idle session, or "
                             "a plain-callable scheduler)")
        return backfill(self._epoch.plan, exec=exec)

    # --- internals ----------------------------------------------------------

    def _admit_job(self, job: Job) -> None:
        self._active.append(job)
        self._arrived_since_plan.append(job)
        self._dirty = True

    def _admit_due(self) -> None:
        while self._pending and self._pending[0][0] <= self._t + _EPS:
            _, _, job = self._pending.pop(0)
            self._admit_job(job)

    def _next_release(self) -> float | None:
        return self._pending[0][0] if self._pending else None

    def _unfinished(self, job: Job) -> bool:
        return any((job.jid, c.cid) not in self._done for c in job.coflows)

    def _prune_active(self) -> None:
        """Retire drained jobs from the active set (their coflow residuals
        are all stamped done, so they contribute nothing to replans).  Keeps
        the per-tick cost of a long-lived session — the serving engine runs
        one per batch stream — proportional to the jobs still in flight,
        not to everything ever submitted."""
        still: list[Job] = []
        for j in self._active:
            if not j.coflows:   # nothing to transmit: complete at release
                self._finished[j.jid] = float(j.release)
            elif not self._unfinished(j):
                self._finished[j.jid] = max(self._done[(j.jid, c.cid)]
                                            for c in j.coflows)
            else:
                still.append(j)
        self._active = still

    def _work_remaining(self) -> bool:
        return any(self._remaining[(j.jid, c.cid)].sum() > 0
                   for j in self._active for c in j.coflows)

    def _ensure_plan(self) -> None:
        if not self._dirty and self._epoch is not None:
            return
        sub, cid_maps = sub_instance(self._active, self._remaining,
                                     self._done, self.m)
        if not sub.jobs:
            self._epoch = None
            self._dirty = False
            self._arrived_since_plan = []
            return
        pinned = None
        if self._gamma_epoch is not None:
            pinned = self._gamma_epoch.observe(sub.gamma())
            self.stats.gamma_rescales = self._gamma_epoch.rescales
        t0 = time.perf_counter()
        epoch = self._try_repair(sub, cid_maps, pinned)
        repaired = epoch is not None
        if repaired:
            wall = time.perf_counter() - t0
            self.stats.repairs += 1
            self.stats.repair_wall_s += wall
        else:
            plan, transcript = self._plan(sub, pinned)
            wall = time.perf_counter() - t0
            epoch = self._make_epoch(transcript, plan, cid_maps, sub)
            self.stats.full_replans += 1
        self._recent_outcomes.append(0 if repaired else 1)
        del self._recent_outcomes[:-self._recent_window]
        self.stats.replan_debt = self.replan_debt
        self.stats.reschedules += 1
        self.stats.plan_wall_s += wall
        if self.stats.reschedules == 1:
            self.stats.first_plan_wall_s = wall
        self._epoch = epoch
        self._dirty = False
        self._arrived_since_plan = []

    def _make_epoch(self, transcript: Transcript, plan,
                    cid_maps: dict[int, list[int]], sub: Instance) -> _Epoch:
        """Epoch state for a plan made NOW: the plan-time residual snapshot
        (re-execution baseline) and planned absolute completions.  Shared by
        the full-replan and repair paths so their epoch semantics cannot
        diverge."""
        return _Epoch(
            t0=self._t, transcript=transcript, cid_maps=cid_maps,
            sub=sub, plan=plan,
            base_remaining={(jid, orig): self._remaining[(jid, orig)].copy()
                            for jid in cid_maps for orig in cid_maps[jid]},
            completions={jid: self._t + t for jid, t in
                         transcript.job_completions().items()},
        )

    def _plan(self, sub: Instance, pinned=None):
        s = self._scheduler
        plan_full = getattr(s, "plan_full", None)
        if callable(plan_full):
            # engine path: plan_full prefetches itself; a pinned gamma
            # overrides the grouping scale for this event only
            p = plan_full(sub, gamma=pinned) if pinned is not None \
                else plan_full(sub)
            self._last_plan = p
            return p, p.transcript()
        # plain callables get NO speculative prefetch: the session cannot
        # know they decompose demands at all, and a non-BNA heuristic
        # would pay every coflow's decomposition for nothing.  BNA-based
        # callables still share the LRU scalar-style; register through the
        # engine to batch.
        plan = getattr(s, "plan", None)
        if callable(plan) and not isinstance(s, type):
            return None, plan(sub)
        return None, s(sub)

    def _execute_to(self, horizon_abs: float) -> None:
        """Execute the epoch's transcript up to absolute ``horizon_abs``.

        Execution is re-run from the epoch's plan-time snapshot each time,
        so the state after the *last* advance of an epoch is bit-identical
        to a single closed-batch execution at that horizon (the cumulative
        flooring bank is per-epoch, exactly as in the batch loop).  Mid-
        epoch advances are consistent intermediate snapshots; completion
        stamps keep their first (earliest-observed) value."""
        ep = self._epoch
        if ep is None:
            return
        h_rel = horizon_abs - ep.t0
        if h_rel <= ep.exec_horizon + _EPS:
            return
        if ep.exec_horizon >= ep.busy_end - _EPS:
            # epoch fully executed: nothing past busy_end can change state,
            # so ticking callers (serve advances every decode step) pay O(1)
            ep.exec_horizon = h_rel
            return
        rem = {k: v.copy() for k, v in ep.base_remaining.items()}
        local_done: dict[tuple[int, int], float] = {}
        execute_transcript(ep.transcript, h_rel, ep.t0, ep.cid_maps,
                           rem, local_done)
        for k, v in rem.items():
            self._remaining[k] = v
        for k, v in local_done.items():
            self._done.setdefault(k, v)
        ep.exec_horizon = h_rel

    # --- frontier-append plan repair ---------------------------------------

    def _try_repair(self, sub: Instance, cid_maps: dict[int, list[int]],
                    pinned=None):
        """Splice the newly-arrived jobs past the retained plan's frontier,
        when provably identical to a full replan (module docstring).
        Returns the repaired _Epoch, or None to fall back."""
        if not self.repair:
            return None
        name = self._scheduler_name
        opts = getattr(self._scheduler, "opts", None) or {}
        spread = opts.get("delays") == "spread"
        # om_alg is job-sequential by construction; spread-mode G-DM and
        # G-DM-RT are deterministic per group, so they take the group-aware
        # path below.  Randomized G-DM/G-DM-RT always fall back (their
        # delays re-draw per plan).  repair="legacy" keeps the
        # pre-generalization gate — om_alg plus singleton spread-mode G-DM
        # — for before/after hit-rate comparisons.
        gdm_names = ("gdm",) if self.repair == "legacy" else ("gdm", "gdm_rt")
        grouped = name in gdm_names and spread
        if not (name == "om_alg" or grouped):
            return None
        ep = self._epoch
        if ep is None or ep.plan is None or not self._arrived_since_plan:
            return None
        new_jids = {j.jid for j in self._arrived_since_plan}
        old_keys = [(jid, orig) for jid in cid_maps if jid not in new_jids
                    for orig in cid_maps[jid]]
        if not old_keys:
            return None   # nothing retained: a plain (cheap) replan
        parts = ep.plan.schedule.parts \
            if isinstance(ep.plan.schedule, CompositeSchedule) else None
        if not parts:
            return None   # no retained expansion (transcript-only scheduler)

        def reject():
            self.stats.repair_rejects += 1
            return None

        if grouped:
            return self._repair_grouped(sub, cid_maps, parts, new_jids, ep,
                                        name, opts, reject, pinned)

        # (1) every unfinished retained coflow untouched since the plan
        for key in old_keys:
            base = ep.base_remaining.get(key)
            if base is None or not np.array_equal(self._remaining[key], base):
                return reject()

        # (2) Algorithm 5 keeps retained jobs in planned order, new at tail
        from .ordering import cached_job_order

        order = cached_job_order(sub).order
        old_order = [jid for jid in ep.plan.schedule.meta.get("order", ())
                     if jid in cid_maps and jid not in new_jids]
        n_old = len(old_order)
        if order[:n_old] != old_order or set(order[n_old:]) != new_jids:
            return reject()

        # (3) retained ledger windows == the windows a from-scratch om_alg
        # replan would emit: back-to-back effective-size windows per coflow
        # in topological order, starting at the arrival cut
        tau = self._t - ep.t0
        win: dict[tuple[int, int], tuple[int, object]] = {}
        for pi, part in enumerate(parts):   # one entry per coflow, across parts
            for e in part.ledger:
                win[(e.jid, e.cid)] = (pi, e)
        by_jid = {j.jid: j for j in sub.jobs}
        old_cid = {jid: {orig: k for k, orig in enumerate(ep.cid_maps[jid])}
                   for jid in ep.cid_maps}
        keep: list[set[tuple[int, int]]] = [set() for _ in parts]
        remap: dict[tuple[int, int], int] = {}
        cursor = 0.0
        for jid in order[:n_old]:
            job = by_jid[jid]
            for cid_sub in topological_order(job.mu, job.edges):
                orig = cid_maps[jid][cid_sub]
                oc = old_cid[jid].get(orig)
                hit = win.get((jid, oc)) if oc is not None else None
                if hit is None:
                    return reject()
                pi, e = hit
                D = effective_size(self._remaining[(jid, orig)])
                if abs(e.e0 - tau - cursor) > 1e-6 or \
                        abs(e.e1 - tau - (cursor + D)) > 1e-6:
                    return reject()
                keep[pi].add((jid, oc))
                remap[(jid, oc)] = cid_sub
                cursor += D

        # splice: retained expansion suffix (compacted into one part, so
        # chained repairs stay O(1) parts) + new jobs planned in isolation
        from .dma import isolated_job_unit
        from .engine import PlanResult
        from .timeline import FinalSchedule, merge_and_fix

        try:
            suffixes = [part.spliced(tau, keep[pi], remap)
                        for pi, part in enumerate(parts) if keep[pi]]
            new_parts = suffixes if len(suffixes) <= 1 else \
                [FinalSchedule.concat_expansion_free(suffixes, self.m)]
        except ValueError:
            return reject()
        t_new = int(round(cursor))
        units = []
        from . import backend

        backend.prefetch_plan(c.demand for jid in order[n_old:]
                              for c in by_jid[jid].coflows)
        for jid in order[n_old:]:
            job = by_jid[jid]
            units.append(isolated_job_unit(job, start=t_new))
            t_new += sum(c.D for c in job.coflows)
        if units:
            new_parts.append(merge_and_fix(units, self.m, origin=0))
        sched = CompositeSchedule(new_parts, sub, meta={
            "order": list(order),
            "algorithm": ep.plan.schedule.meta.get("algorithm", "O(m)Alg"),
            "repaired": True})
        plan = PlanResult(ep.plan.name, sched)
        self._last_plan = plan
        return self._make_epoch(plan.transcript(), plan, cid_maps, sub)

    def _repair_grouped(self, sub: Instance, cid_maps: dict[int, list[int]],
                        parts, new_jids: set, ep: _Epoch, name: str,
                        opts: dict, reject, pinned=None):
        """Group-aware repair for spread-mode G-DM / G-DM-RT (module
        docstring): re-derive the Algorithm 5 order and geometric grouping
        of the residual instance (under the session's pinned gamma when
        one is active — the same value the full replan would use), then
        walk the replan's group chain — sliding each retained group part
        whose inputs are untouched to its new chain position as one block,
        and rebuilding the rest through the backend's group-block cache.
        Bit-identical to the full replan by construction: spread-mode
        DMA/DMA-SRT layouts are deterministic functions of (group jobs,
        residual demands, origin), and translation invariant in the
        origin — so a block built at any origin is exact at any other."""
        from .engine import PlanResult
        from .gdm import group_jobs
        from .ordering import cached_job_order

        old_groups = ep.plan.schedule.meta.get("groups")
        if old_groups is None or len(old_groups) != len(parts):
            return reject()
        legacy = self.repair == "legacy"
        tau = self._t - ep.t0
        itau = int(round(tau))
        if legacy and abs(tau - itau) > 1e-6:
            return reject()   # legacy's aligned reuse needs the packet clock
        order = cached_job_order(sub).order
        groups = group_jobs(sub, order, gamma=pinned)
        if legacy and any(len(g) != 1 for g in groups):
            return reject()
        old_idx = {tuple(g): i for i, g in enumerate(old_groups)}
        by_jid = {j.jid: j for j in sub.jobs}

        def untouched(g) -> bool:
            """Same member coflows as at plan time, residuals bit-equal."""
            for jid in g:
                if ep.cid_maps.get(jid) != cid_maps.get(jid):
                    return False
                for orig in cid_maps[jid]:
                    base = ep.base_remaining.get((jid, orig))
                    if base is None or \
                            not np.array_equal(self._remaining[(jid, orig)],
                                               base):
                        return False
            return True

        static = []   # per group: the old part to reuse, or None
        for g in groups:
            i = old_idx.get(tuple(g))
            ok = i is not None and not (set(g) & new_jids) and untouched(g)
            static.append(parts[i] if ok else None)
        if not any(p is not None for p in static):
            return None   # nothing reusable: the replan does the same work
        if legacy and not all(p is not None for p in static):
            return reject()   # legacy path required the whole plan retained

        from . import backend

        backend.prefetch_plan(
            c.demand for g, p in zip(groups, static) if p is None
            for jid in g for c in by_jid[jid].coflows)

        beta = float(opts.get("beta", 2.0))
        decompose = bool(opts.get("decompose", False))
        nested = bool(opts.get("nested", True))
        require_tree = bool(opts.get("require_tree", True))

        new_parts = []
        reused = 0
        cursor = 0
        for g, old_part in zip(groups, static):
            # gdm(): start = max(t_cur, releases) — sub releases are all 0
            if old_part is not None and \
                    (not legacy or old_part.origin == itau + cursor):
                # the replan would rebuild this group from the same inputs:
                # slide the whole retained block to its new chain position
                # (legacy only reuses at the exact aligned position)
                part = old_part.shifted_expanded(cursor - int(old_part.origin))
                reused += 1
            else:
                jobs_g = [by_jid[jid] for jid in g]
                part = backend.group_block(
                    name, jobs_g, self.m, beta=beta, decompose=decompose,
                    nested=nested, require_tree=require_tree,
                    delays="spread").shifted_expanded(cursor)
            new_parts.append(part)
            cursor = int(math.ceil(part.makespan))
        if reused == 0:
            return None   # chain never aligned; the work done == a replan's
        self.stats.groups_reused += reused
        self.stats.groups_replanned += len(groups) - reused
        sched = CompositeSchedule(new_parts, sub, meta={
            "order": list(order),
            "groups": [list(g) for g in groups],
            "algorithm": ep.plan.schedule.meta.get(
                "algorithm", "G-DM-RT" if name == "gdm_rt" else "G-DM"),
            "beta": beta,
            "repaired": True})
        plan = PlanResult(ep.plan.name, sched)
        self._last_plan = plan
        return self._make_epoch(plan.transcript(), plan, cid_maps, sub)
