"""Backend dispatch + compute caches for the scheduler engine.

A small ``GlobalConfig``-style module (after alpa's ``global_config``): one
process-wide :class:`BackendConfig` instance, initialized from environment
variables, selects how the engine's two hot paths execute:

* **alpha backend** — ``merge_and_fix`` (timeline.py, Lemma 6 Steps 3-4)
  computes alpha_I per merged interval.  ``"numpy"`` runs the chunked
  prefix-sum oracle (`timeline._alphas_vectorized`); ``"pallas"`` routes
  through the ``kernels/coflow_merge`` Pallas kernel (interpret mode on CPU,
  compiled on TPU); ``"auto"`` picks pallas iff a TPU backend is attached.
  Any kernel failure falls back to the numpy oracle (warned once) — the two
  are bit-identical, so the fallback is safe.

* **BNA cache** — a bounded LRU keyed on ``demand.tobytes()`` memoizing BNA
  decompositions (Algorithm 1).  Unlike the old per-``Coflow``-object memo,
  the bytes key survives the online driver's ``_sub_instance`` rebuilding
  fresh ``Coflow`` objects on every arrival, so untouched coflows hit across
  reschedules.  Hit/miss counters feed the benchmark report.

* **order cache** — a bounded LRU over the exact scheduling state (port
  count, and per job: id, weight, release, DAG edges, demand bytes)
  memoizing the primal-dual job order (Algorithm 5).  Keyed on the full
  state, reuse is results-identical by construction; it fires whenever the
  same state is re-planned (algorithm A/B pairs on one instance, beta
  sweeps, and online reschedules whose surviving jobs are untouched).

Environment switches (read once at import; also settable in-process)::

    REPRO_ALPHA_BACKEND    auto | numpy | pallas      (default: auto)
    REPRO_BNA_CACHE_SIZE   max cached decompositions  (default: 4096; 0 off)
    REPRO_ORDER_CACHE_SIZE max cached job orders      (default: 256;  0 off)
"""
from __future__ import annotations

import os
import warnings
from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass

import numpy as np

__all__ = [
    "BackendConfig",
    "config",
    "set_alpha_backend",
    "use_alpha_backend",
    "resolve_alpha_backend",
    "compute_alphas",
    "bna_pieces",
    "cache_stats",
    "clear_caches",
    "no_caches",
]

_ALPHA_BACKENDS = ("auto", "numpy", "pallas")


@dataclass
class BackendConfig:
    """Process-wide engine knobs (env-initialized, mutable in-process)."""

    alpha_backend: str = "auto"
    bna_cache_size: int = 4096
    order_cache_size: int = 256

    @staticmethod
    def from_env() -> "BackendConfig":
        cfg = BackendConfig(
            alpha_backend=os.environ.get("REPRO_ALPHA_BACKEND", "auto").lower(),
            bna_cache_size=int(os.environ.get("REPRO_BNA_CACHE_SIZE", "4096")),
            order_cache_size=int(os.environ.get("REPRO_ORDER_CACHE_SIZE", "256")),
        )
        if cfg.alpha_backend not in _ALPHA_BACKENDS:
            raise ValueError(
                f"REPRO_ALPHA_BACKEND={cfg.alpha_backend!r}; "
                f"expected one of {_ALPHA_BACKENDS}")
        return cfg


config = BackendConfig.from_env()


def set_alpha_backend(name: str) -> None:
    """One-line switch: route merge_and_fix alphas through `name`."""
    if name not in _ALPHA_BACKENDS:
        raise ValueError(f"unknown alpha backend {name!r}; "
                         f"expected one of {_ALPHA_BACKENDS}")
    config.alpha_backend = name


@contextmanager
def use_alpha_backend(name: str):
    prev = config.alpha_backend
    set_alpha_backend(name)
    try:
        yield
    finally:
        config.alpha_backend = prev


def resolve_alpha_backend(force: str | None = None) -> str:
    """Concrete backend for this call: explicit override > config > auto."""
    name = force or config.alpha_backend
    if name == "auto":
        try:
            import jax
            return "pallas" if jax.default_backend() == "tpu" else "numpy"
        except Exception:  # jax unavailable / misconfigured
            return "numpy"
    return name


_warned_fallback = False


def compute_alphas(events: np.ndarray, edges, m: int,
                   force: str | None = None) -> np.ndarray:
    """Per-interval alphas (max per-port packet count) for merge_and_fix.

    `edges` is a timeline.EdgeIntervals; `events` the sorted unique interval
    boundaries.  Dispatches per :func:`resolve_alpha_backend` (the two
    backends agree exactly — both count integer edge activations per port).
    A kernel error falls back to the numpy oracle ONLY when pallas was
    picked by "auto"; an explicitly requested pallas backend (force, env
    var, or set_alpha_backend) propagates the error so parity tests and
    benchmarks cannot silently pass on the oracle alone.
    """
    from .timeline import _alphas_vectorized  # oracle (import cycle: lazy)

    requested = force or config.alpha_backend
    backend = resolve_alpha_backend(force)
    if backend == "pallas" and edges.size and events.size > 1:
        try:
            from repro.kernels.coflow_merge.ops import edge_interval_alphas

            return np.asarray(
                edge_interval_alphas(events, edges.t0, edges.t1,
                                     edges.s, edges.r, m),
                dtype=np.int64)
        except Exception as exc:  # pragma: no cover - env-dependent
            if requested == "pallas":
                raise
            global _warned_fallback
            if not _warned_fallback:
                _warned_fallback = True
                warnings.warn(
                    f"coflow_merge pallas backend failed ({exc!r}); "
                    "auto-dispatch falling back to the numpy oracle",
                    RuntimeWarning)
    return _alphas_vectorized(events, edges, m)


# --------------------------------------------------------------------------
# bounded LRU caches with hit/miss counters
# --------------------------------------------------------------------------

class LRUCache:
    """Tiny bounded LRU with hit/miss counters; maxsize <= 0 disables."""

    def __init__(self, maxsize: int, name: str):
        self.name = name
        self.maxsize = maxsize
        self._od: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, key):
        """(found, value); counts a hit/miss and refreshes recency."""
        if self.maxsize <= 0:
            self.misses += 1
            return False, None
        try:
            val = self._od[key]
        except KeyError:
            self.misses += 1
            return False, None
        self._od.move_to_end(key)
        self.hits += 1
        return True, val

    def store(self, key, val) -> None:
        if self.maxsize <= 0:
            return
        self._od[key] = val
        self._od.move_to_end(key)
        while len(self._od) > self.maxsize:
            self._od.popitem(last=False)

    def __len__(self) -> int:
        return len(self._od)

    def clear(self) -> None:
        self._od.clear()
        self.hits = 0
        self.misses = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._od),
                "hit_rate": (self.hits / total) if total else 0.0}


bna_cache = LRUCache(config.bna_cache_size, "bna")
order_cache = LRUCache(config.order_cache_size, "order")


def bna_pieces(demand: np.ndarray) -> list:
    """BNA decomposition of `demand`, memoized on the demand bytes.

    The returned pieces are shared across callers and must be treated as
    read-only (every consumer in core/ only reads them).
    """
    from .bna import bna

    bna_cache.maxsize = config.bna_cache_size
    key = (demand.shape[0], demand.tobytes())
    found, pieces = bna_cache.lookup(key)
    if not found:
        pieces = bna(demand)
        bna_cache.store(key, pieces)
    return pieces


def cache_stats() -> dict:
    return {"bna": bna_cache.stats(), "order": order_cache.stats()}


def clear_caches() -> None:
    bna_cache.clear()
    order_cache.clear()


@contextmanager
def no_caches():
    """Disable (and clear) both caches — the from-scratch comparator."""
    prev = (config.bna_cache_size, config.order_cache_size)
    saved_bna = (bna_cache.maxsize, dict(bna_cache._od),
                 bna_cache.hits, bna_cache.misses)
    saved_ord = (order_cache.maxsize, dict(order_cache._od),
                 order_cache.hits, order_cache.misses)
    config.bna_cache_size = 0
    config.order_cache_size = 0
    bna_cache.clear()
    order_cache.clear()
    bna_cache.maxsize = 0
    order_cache.maxsize = 0
    try:
        yield
    finally:
        config.bna_cache_size, config.order_cache_size = prev
        bna_cache.maxsize = saved_bna[0]
        bna_cache._od = OrderedDict(saved_bna[1])
        bna_cache.hits, bna_cache.misses = saved_bna[2], saved_bna[3]
        order_cache.maxsize = saved_ord[0]
        order_cache._od = OrderedDict(saved_ord[1])
        order_cache.hits, order_cache.misses = saved_ord[2], saved_ord[3]
