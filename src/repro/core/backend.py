"""Backend dispatch + compute caches for the scheduler engine.

A small ``GlobalConfig``-style module (after alpa's ``global_config``): one
process-wide :class:`BackendConfig` instance, initialized from environment
variables, selects how the engine's two hot paths execute:

* **alpha backend** — ``merge_and_fix`` (timeline.py, Lemma 6 Steps 3-4)
  computes alpha_I per merged interval.  ``"numpy"`` runs the chunked
  prefix-sum oracle (`timeline._alphas_vectorized`); ``"pallas"`` routes
  through the ``kernels/coflow_merge`` Pallas kernel (interpret mode on CPU,
  compiled on TPU); ``"auto"`` picks pallas iff a TPU backend is attached.
  Any kernel failure falls back to the numpy oracle (warned once) — the two
  are bit-identical, so the fallback is safe.

* **BNA backend** — the batched matching layer (``core/matching.py``,
  ``bna_many``) vectorizes the multi-coflow BNA decomposition and
  dispatches its inner step per ``REPRO_BNA_BACKEND``: ``"numpy"`` runs the
  in-place vectorized step, ``"pallas"`` routes the same integer arithmetic
  through the ``kernels/bna_step`` kernel (interpret mode on CPU, compiled
  on TPU), ``"auto"`` picks pallas iff a TPU backend is attached.  The two
  are bit-identical, so the auto fallback on kernel failure is safe (an
  explicitly requested pallas backend propagates the error, mirroring the
  alpha backend).

* **BNA cache** — a bounded LRU keyed on ``(shape, dtype, bytes)`` of the
  demand, memoizing BNA decompositions (Algorithm 1).  Unlike the old
  per-``Coflow``-object memo, the content key survives the online driver's
  ``_sub_instance`` rebuilding fresh ``Coflow`` objects on every arrival,
  so untouched coflows hit across reschedules; including shape and dtype
  keeps differently-typed or differently-shaped demands from colliding.
  :func:`bna_pieces_many` is the batch entry: it consults the LRU first and
  hands ONLY the misses to ``bna_many`` in one batched call — this is what
  the engine's instance-level prefetch (``engine.plan`` /
  ``SchedulerSession``) goes through.  Hit/miss counters (scalar and
  per-batch) feed the benchmark report.

* **order cache** — a bounded LRU over the exact scheduling state (port
  count, and per job: id, weight, release, DAG edges, demand bytes)
  memoizing the primal-dual job order (Algorithm 5).  Keyed on the full
  state, reuse is results-identical by construction; it fires whenever the
  same state is re-planned (algorithm A/B pairs on one instance, beta
  sweeps, and online reschedules whose surviving jobs are untouched).

* **group-block cache** — a bounded LRU over spread-mode G-DM / G-DM-RT
  *group parts*: the DMA / DMA-RT schedule of one geometric group, built
  at origin 0 and keyed on the construction's full input (scheduler kind,
  port count, beta/decompose/nested/require_tree knobs, and the ordered
  member tuple with each job's DAG edges and per-coflow demand bytes).
  Spread-mode layouts are deterministic (zero rng draws) and translation
  invariant in the origin, so ``group_block(...).shifted_expanded(start)``
  is bit-identical to rebuilding the group at ``start`` — this is what
  lets full replans under a session-pinned gamma reassemble untouched
  groups as shifted blocks instead of re-running DMA (see
  ``core/session.py``).  Randomized delay modes are never cached (their
  layouts consume rng draws, so a cached result would corrupt the
  caller's stream).

* **loads / grouping-key caches** — per-job Algorithm 5 load vectors
  keyed on demand bytes (``ordering.job_load_vectors``), and the
  geometric-grouping prefix-load cumsum keyed on the ordered demand
  signature (:func:`grouping_keys`).  The cumsum cache is *incremental*:
  a replan whose Algorithm 5 order extends a cached prefix (appended
  arrivals) extends the cached cumsum with the new rows instead of
  recomputing the whole prefix — exact, because the loads are integers
  far below 2^53 (guarded).

* **plan backend** — the whole-planning-path knob (``core/pipeline.py``):
  ``"python"`` runs the classic per-coflow loop; ``"jit"`` routes the
  per-instance prefetch, the per-coflow edge-interval construction, and the
  Algorithm 5 ordering inputs through fixed-shape compiled XLA programs
  (bit-identical plans — all-integer arithmetic); ``"auto"`` picks jit iff
  a TPU backend is attached (on CPU the compile latency only pays off for
  large instances, so it is opt-in there — same policy as the alpha/BNA
  knobs).  A pipeline failure under ``auto`` falls back to the python path
  with a one-time warning; an explicitly requested jit backend propagates
  the error.

Environment switches (read once at import; also settable in-process)::

    REPRO_ALPHA_BACKEND    auto | numpy | pallas      (default: auto)
    REPRO_BNA_BACKEND      auto | numpy | pallas      (default: auto)
    REPRO_PLAN_BACKEND     auto | python | jit        (default: auto)
    REPRO_BNA_BATCH        1 | 0: instance-level batched BNA prefetch
                           (default: 1)
    REPRO_BNA_CACHE_SIZE   max cached decompositions  (default: 4096; 0 off)
    REPRO_ORDER_CACHE_SIZE max cached job orders      (default: 256;  0 off)
    REPRO_GROUP_CACHE_SIZE max cached group blocks    (default: 512;  0 off)
    REPRO_LOADS_CACHE_SIZE max cached per-job load
                           vectors (Algorithm 5)      (default: 4096; 0 off)
    REPRO_GKEY_CACHE_SIZE  max cached grouping-key
                           prefix cumsums             (default: 512;  0 off)
"""
from __future__ import annotations

import os
import sys
import warnings
from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Iterable

import numpy as np

__all__ = [
    "BackendConfig",
    "config",
    "set_alpha_backend",
    "use_alpha_backend",
    "resolve_alpha_backend",
    "set_bna_backend",
    "use_bna_backend",
    "resolve_bna_backend",
    "set_plan_backend",
    "use_plan_backend",
    "resolve_plan_backend",
    "compute_alphas",
    "fused_merge_fix",
    "plan_edges",
    "plan_order_loads",
    "prefetch_plan",
    "bna_pieces",
    "bna_pieces_many",
    "prefetch_bna",
    "group_block",
    "grouping_prefix",
    "cache_stats",
    "clear_caches",
    "no_caches",
]

_ALPHA_BACKENDS = ("auto", "numpy", "pallas")
_BNA_BACKENDS = ("auto", "numpy", "pallas")
_PLAN_BACKENDS = ("auto", "python", "jit")


@dataclass
class BackendConfig:
    """Process-wide engine knobs (env-initialized, mutable in-process)."""

    alpha_backend: str = "auto"
    bna_backend: str = "auto"
    plan_backend: str = "auto"
    bna_batch: bool = True
    bna_cache_size: int = 4096
    order_cache_size: int = 256
    group_cache_size: int = 512
    loads_cache_size: int = 4096
    gkey_cache_size: int = 512

    @staticmethod
    def from_env() -> "BackendConfig":
        cfg = BackendConfig(
            alpha_backend=os.environ.get("REPRO_ALPHA_BACKEND", "auto").lower(),
            bna_backend=os.environ.get("REPRO_BNA_BACKEND", "auto").lower(),
            plan_backend=os.environ.get("REPRO_PLAN_BACKEND", "auto").lower(),
            bna_batch=os.environ.get("REPRO_BNA_BATCH", "1") != "0",
            bna_cache_size=int(os.environ.get("REPRO_BNA_CACHE_SIZE", "4096")),
            order_cache_size=int(os.environ.get("REPRO_ORDER_CACHE_SIZE", "256")),
            group_cache_size=int(os.environ.get("REPRO_GROUP_CACHE_SIZE", "512")),
            loads_cache_size=int(os.environ.get("REPRO_LOADS_CACHE_SIZE", "4096")),
            gkey_cache_size=int(os.environ.get("REPRO_GKEY_CACHE_SIZE", "512")),
        )
        if cfg.alpha_backend not in _ALPHA_BACKENDS:
            raise ValueError(
                f"REPRO_ALPHA_BACKEND={cfg.alpha_backend!r}; "
                f"expected one of {_ALPHA_BACKENDS}")
        if cfg.bna_backend not in _BNA_BACKENDS:
            raise ValueError(
                f"REPRO_BNA_BACKEND={cfg.bna_backend!r}; "
                f"expected one of {_BNA_BACKENDS}")
        if cfg.plan_backend not in _PLAN_BACKENDS:
            raise ValueError(
                f"REPRO_PLAN_BACKEND={cfg.plan_backend!r}; "
                f"expected one of {_PLAN_BACKENDS}")
        return cfg


config = BackendConfig.from_env()


def set_alpha_backend(name: str) -> None:
    """One-line switch: route merge_and_fix alphas through `name`."""
    if name not in _ALPHA_BACKENDS:
        raise ValueError(f"unknown alpha backend {name!r}; "
                         f"expected one of {_ALPHA_BACKENDS}")
    config.alpha_backend = name


@contextmanager
def use_alpha_backend(name: str):
    prev = config.alpha_backend
    set_alpha_backend(name)
    try:
        yield
    finally:
        config.alpha_backend = prev


def _resolve_auto() -> str:
    try:
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "numpy"
    except Exception:  # jax unavailable / misconfigured
        return "numpy"


def resolve_alpha_backend(force: str | None = None) -> str:
    """Concrete backend for this call: explicit override > config > auto."""
    name = force or config.alpha_backend
    return _resolve_auto() if name == "auto" else name


def set_bna_backend(name: str) -> None:
    """One-line switch: route the batched BNA step through `name`."""
    if name not in _BNA_BACKENDS:
        raise ValueError(f"unknown BNA backend {name!r}; "
                         f"expected one of {_BNA_BACKENDS}")
    config.bna_backend = name


@contextmanager
def use_bna_backend(name: str):
    prev = config.bna_backend
    set_bna_backend(name)
    try:
        yield
    finally:
        config.bna_backend = prev


def resolve_bna_backend(force: str | None = None) -> str:
    """Concrete BNA-step backend for this call (mirrors the alpha knob)."""
    name = force or config.bna_backend
    if name not in _BNA_BACKENDS:
        raise ValueError(f"unknown BNA backend {name!r}; "
                         f"expected one of {_BNA_BACKENDS}")
    return _resolve_auto() if name == "auto" else name


def set_plan_backend(name: str) -> None:
    """One-line switch: route whole-instance planning through `name`."""
    if name not in _PLAN_BACKENDS:
        raise ValueError(f"unknown plan backend {name!r}; "
                         f"expected one of {_PLAN_BACKENDS}")
    config.plan_backend = name


@contextmanager
def use_plan_backend(name: str):
    prev = config.plan_backend
    set_plan_backend(name)
    try:
        yield
    finally:
        config.plan_backend = prev


def resolve_plan_backend(force: str | None = None) -> str:
    """Concrete plan backend for this call: "auto" picks jit iff a TPU is
    attached (CPU compile latency only pays off for large instances, so jit
    is opt-in there — exactly the alpha/BNA auto policy)."""
    name = force or config.plan_backend
    if name not in _PLAN_BACKENDS:
        raise ValueError(f"unknown plan backend {name!r}; "
                         f"expected one of {_PLAN_BACKENDS}")
    if name == "auto":
        return "jit" if _resolve_auto() == "pallas" else "python"
    return name


_warned_fallback = False


def compute_alphas(events: np.ndarray, edges, m: int,
                   force: str | None = None) -> np.ndarray:
    """Per-interval alphas (max per-port packet count) for merge_and_fix.

    `edges` is a timeline.EdgeIntervals; `events` the sorted unique interval
    boundaries.  Dispatches per :func:`resolve_alpha_backend` (the two
    backends agree exactly — both count integer edge activations per port).
    A kernel error falls back to the numpy oracle ONLY when pallas was
    picked by "auto"; an explicitly requested pallas backend (force, env
    var, or set_alpha_backend) propagates the error so parity tests and
    benchmarks cannot silently pass on the oracle alone.
    """
    from .timeline import _alphas_vectorized  # oracle (import cycle: lazy)

    requested = force or config.alpha_backend
    backend = resolve_alpha_backend(force)
    if backend == "pallas" and edges.size and events.size > 1:
        try:
            from repro.kernels.coflow_merge.ops import edge_interval_alphas

            return np.asarray(
                edge_interval_alphas(events, edges.t0, edges.t1,
                                     edges.s, edges.r, m),
                dtype=np.int64)
        except Exception as exc:  # pragma: no cover - env-dependent
            if requested == "pallas":
                raise
            global _warned_fallback
            if not _warned_fallback:
                _warned_fallback = True
                warnings.warn(
                    f"coflow_merge pallas backend failed ({exc!r}); "
                    "auto-dispatch falling back to the numpy oracle",
                    RuntimeWarning)
    return _alphas_vectorized(events, edges, m)


# --------------------------------------------------------------------------
# jit planning pipeline dispatch (REPRO_PLAN_BACKEND; see core/pipeline.py)
# --------------------------------------------------------------------------

_warned_plan_fallback = False


def _plan_fallback(exc: Exception) -> None:
    """Auto falls back to the python plan path (warned once); an explicitly
    requested jit backend propagates the error — mirroring the kernel
    knobs, so equivalence tests cannot silently pass on the python path."""
    global _warned_plan_fallback
    if config.plan_backend == "jit":
        raise exc
    if not _warned_plan_fallback:
        _warned_plan_fallback = True
        warnings.warn(
            f"jit planning pipeline failed ({exc!r}); auto-dispatch "
            "falling back to the python plan path", RuntimeWarning)


def prefetch_plan(demands: "Iterable[np.ndarray]") -> None:
    """Instance-level prefetch dispatched on the plan backend: under jit it
    warms the BNA *and* edge-interval caches through the compiled
    width-bucketed sweep (pipeline.prefetch_demands); otherwise — or on an
    auto-mode pipeline failure — it is exactly :func:`prefetch_bna`."""
    ds = list(demands)
    if resolve_plan_backend() == "jit":
        try:
            from . import pipeline

            pipeline.prefetch_demands(ds)
            return
        except Exception as exc:  # pragma: no cover - env-dependent
            _plan_fallback(exc)
    prefetch_bna(ds)


def plan_edges(demand: np.ndarray):
    """Relative (t0, t1, s, r) edge intervals of one coflow's BNA schedule
    under the jit plan backend; None routes the caller to the python path
    (backend resolves python, or auto-mode pipeline failure)."""
    if resolve_plan_backend() != "jit":
        return None
    try:
        from . import pipeline

        return pipeline.coflow_edges_rel(demand)
    except Exception as exc:  # pragma: no cover - env-dependent
        _plan_fallback(exc)
        return None


def plan_order_loads(instance):
    """Algorithm 5 load vectors from the jitted segment-sum (bit-identical
    integer sums); None routes the caller to the host computation."""
    if resolve_plan_backend() != "jit":
        return None
    try:
        from . import pipeline

        return pipeline.instance_load_vectors(instance)
    except Exception as exc:  # pragma: no cover - env-dependent
        _plan_fallback(exc)
        return None


def fused_merge_fix(events: np.ndarray, edges, m: int,
                    force: str | None = None):
    """(alphas, expansion deltas) in one compiled call via the
    ``kernels/merge_fix`` fused step — engaged only when the plan backend
    resolves jit AND the alpha backend resolves pallas (on CPU the numpy
    oracle stays the better default).  None → the caller runs the classic
    two-stage path.  Bit-identical: same kernel alphas, integer deltas."""
    if resolve_plan_backend() != "jit":
        return None
    requested = force or config.alpha_backend
    if resolve_alpha_backend(force) != "pallas":
        return None
    if not (edges.size and events.size > 1):
        return None
    try:
        from repro.kernels.merge_fix.ops import merge_fix_step

        alphas, deltas = merge_fix_step(events, edges.t0, edges.t1,
                                        edges.s, edges.r, m)
        return (np.asarray(alphas, dtype=np.int64),
                np.asarray(deltas, dtype=np.int64))
    except Exception as exc:  # pragma: no cover - env-dependent
        if requested == "pallas":
            raise
        _plan_fallback(exc)
        return None


# --------------------------------------------------------------------------
# bounded LRU caches with hit/miss counters
# --------------------------------------------------------------------------

class LRUCache:
    """Tiny bounded LRU with hit/miss counters; maxsize <= 0 disables."""

    def __init__(self, maxsize: int, name: str):
        self.name = name
        self.maxsize = maxsize
        self._od: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, key):
        """(found, value); counts a hit/miss and refreshes recency."""
        if self.maxsize <= 0:
            self.misses += 1
            return False, None
        try:
            val = self._od[key]
        except KeyError:
            self.misses += 1
            return False, None
        self._od.move_to_end(key)
        self.hits += 1
        return True, val

    def peek(self, key):
        """(found, value) WITHOUT touching counters or recency — for
        secondary probes (the grouping-key prefix scan) whose hits/misses
        would otherwise distort the primary lookup's rates."""
        if self.maxsize <= 0 or key not in self._od:
            return False, None
        return True, self._od[key]

    def store(self, key, val) -> None:
        if self.maxsize <= 0:
            return
        self._od[key] = val
        self._od.move_to_end(key)
        while len(self._od) > self.maxsize:
            self._od.popitem(last=False)

    def __len__(self) -> int:
        return len(self._od)

    def clear(self) -> None:
        self._od.clear()
        self.hits = 0
        self.misses = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._od),
                "hit_rate": (self.hits / total) if total else 0.0}


bna_cache = LRUCache(config.bna_cache_size, "bna")
order_cache = LRUCache(config.order_cache_size, "order")
group_cache = LRUCache(config.group_cache_size, "group")
loads_cache = LRUCache(config.loads_cache_size, "loads")
gkey_cache = LRUCache(config.gkey_cache_size, "gkey")

# per-batch counters for bna_pieces_many (surfaced in cache_stats()["bna"]
# ["batch"]): how many batched lookups ran, and how their members split
# into cache hits, misses handed to the batched decomposition (unique
# demands), and in-batch duplicates that shared a miss's result
_bna_batch = {"batches": 0, "hits": 0, "misses": 0, "deduped": 0}


def _bna_key(demand: np.ndarray) -> tuple:
    """BNA cache key: (shape, dtype, bytes).  Keying on the full identity —
    not just the port count and raw bytes — means demands that happen to
    share a byte string across dtypes/shapes can neither collide nor
    spuriously hit each other's entries."""
    return (demand.shape, demand.dtype.str, demand.tobytes())


def bna_pieces(demand: np.ndarray) -> list:
    """BNA decomposition of `demand`, memoized on (shape, dtype, bytes).

    The returned pieces are shared across callers and must be treated as
    read-only (every consumer in core/ only reads them).
    """
    from .bna import bna

    bna_cache.maxsize = config.bna_cache_size
    key = _bna_key(demand)
    found, pieces = bna_cache.lookup(key)
    if not found:
        pieces = bna(demand)
        bna_cache.store(key, pieces)
    return pieces


def bna_pieces_many(demands: list, keys: list | None = None) -> list:
    """BNA decompositions for a whole batch of demands: the LRU is
    consulted first, and ONLY the misses (deduplicated — repeated demands
    in one batch decompose once) go through the batched ``bna_many``
    decomposition in a single call.  Results are bit-identical to
    ``[bna_pieces(d) for d in demands]``; per-batch hit/miss counts land in
    ``cache_stats()["bna"]["batch"]``.  ``keys`` accepts precomputed
    ``_bna_key`` values (same order as ``demands``) so callers that
    already serialized the batch — the prefetch guard — don't pay the
    hashing twice."""
    from .matching import bna_many

    bna_cache.maxsize = config.bna_cache_size
    out: list = [None] * len(demands)
    miss_keys: list = []
    miss_demands: list = []
    by_key: dict = {}
    hits = 0
    for i, dem in enumerate(demands):
        key = _bna_key(dem) if keys is None else keys[i]
        found, pieces = bna_cache.lookup(key)
        if found:
            out[i] = pieces
            hits += 1
            continue
        slot = by_key.get(key)
        if slot is None:
            by_key[key] = [i]
            miss_keys.append(key)
            miss_demands.append(dem)
        else:
            slot.append(i)
    if miss_demands:
        for key, pieces in zip(miss_keys, bna_many(miss_demands)):
            bna_cache.store(key, pieces)
            for i in by_key[key]:
                out[i] = pieces
    _bna_batch["batches"] += 1
    _bna_batch["hits"] += hits
    _bna_batch["misses"] += len(miss_demands)
    _bna_batch["deduped"] += len(demands) - hits - len(miss_demands)
    return out


def prefetch_bna(demands: "Iterable[np.ndarray]") -> None:
    """Warm the BNA cache for every demand in one batched call — the
    instance-level prefetch ``engine.plan`` and ``SchedulerSession`` issue
    before ``dma.isolated_job_unit`` / ``dma_srt`` walk jobs one by one.

    A no-op when batching is off (REPRO_BNA_BATCH=0), the cache is
    disabled, or the instance's distinct demands cannot all FIT in the
    cache: a batch bigger than ``maxsize`` necessarily evicts some of its
    own entries — refreshed hits included — before the scheduler's walk
    reads them (sequential-LRU thrash: those lookups miss and re-run
    scalar BNA on top of the batched work, strictly worse than the scalar
    path).  Raise REPRO_BNA_CACHE_SIZE to batch bigger instances."""
    if not config.bna_batch or config.bna_cache_size <= 0:
        return
    ds = list(demands)
    if not ds:
        return
    keys = [_bna_key(d) for d in ds]
    if len(set(keys)) > config.bna_cache_size:
        return
    bna_pieces_many(ds, keys=keys)


# --------------------------------------------------------------------------
# spread-mode group-block cache (G-DM / G-DM-RT geometric groups)
# --------------------------------------------------------------------------

def _group_sig(jobs) -> tuple:
    """Per-job identity a spread-mode DMA/DMA-SRT layout is a function of:
    job id (embedded in the emitted ledger/expansion), weight and release
    (unread by the constructions but kept for soundness against future
    changes — both are constant per job across replans, so they cost no
    hits), DAG edges, and per-coflow (cid, shape, dtype, bytes)."""
    return tuple(
        (int(j.jid), float(j.weight), int(j.release), tuple(j.edges),
         tuple((c.cid, c.demand.shape, c.demand.dtype.str,
                c.demand.tobytes()) for c in j.coflows))
        for j in jobs)


def group_block(kind: str, jobs, m: int, *, beta: float = 2.0,
                decompose: bool = False, use_kernel: "bool | None" = None,
                nested: bool = True, require_tree: bool = True,
                delays: str = "spread"):
    """One geometric group's DMA (kind="gdm") / DMA-RT (kind="gdm_rt")
    schedule built at **origin 0**, memoized on the construction's full
    input.  Spread-mode layouts are deterministic (zero rng draws) and
    translation invariant in the origin, so callers place the block with
    ``.shifted_expanded(start)`` — bit-identical to rebuilding the group at
    ``start``.  This is what turns a "full replan" under a session-pinned
    gamma into a reassembly of already-built blocks (core/gdm.py group
    loop, core/session.py grouped repair).

    The returned FinalSchedule is shared across callers and must be
    treated as read-only (the same contract as the shared BNA pieces; its
    lazy decomposition fields are idempotent).  Randomized delay modes are
    rejected: their layouts consume rng draws, so a cached result would
    corrupt the caller's stream.
    """
    from .dma import dma
    from .dma_srt import dma_rt

    if kind not in ("gdm", "gdm_rt"):
        raise ValueError(f"unknown group-block kind {kind!r}; "
                         f"choose from ('gdm', 'gdm_rt')")
    if delays != "spread":
        raise ValueError(
            f"group_block caches spread-mode layouts only (got "
            f"delays={delays!r}): randomized modes consume rng draws")
    group_cache.maxsize = config.group_cache_size
    key = (kind, int(m), float(beta), bool(decompose), use_kernel,
           bool(nested), bool(require_tree), delays) + _group_sig(jobs)
    found, part = group_cache.lookup(key)
    if not found:
        if kind == "gdm_rt":
            part = dma_rt(list(jobs), m, beta=beta, rng=None, origin=0,
                          decompose=decompose, use_kernel=use_kernel,
                          nested=nested, require_tree=require_tree,
                          delays=delays)
        else:
            part = dma(list(jobs), m, beta=beta, rng=None, origin=0,
                       decompose=decompose, use_kernel=use_kernel,
                       delays=delays)
        group_cache.store(key, part)
    return part


# --------------------------------------------------------------------------
# incremental Algorithm 5 grouping-key prefix (geometric grouping, step 2)
# --------------------------------------------------------------------------

# how far back the prefix probe scans: appended-arrival replans extend the
# previous event's entry, and arrival batches are small, so a handful of
# probe lengths covers the streaming case without scanning the cache
_GKEY_PREFIX_PROBES = 4

# exact hits / prefix extensions / cold recomputes (cache_stats()["gkey"])
_gkey_counts = {"exact": 0, "extended": 0, "cold": 0}


def _gkey_sig(job) -> tuple:
    """What a job contributes to the prefix-load cumsum: its per-coflow
    demands (the load vector is their row/column sums)."""
    return tuple((c.demand.shape, c.demand.dtype.str, c.demand.tobytes())
                 for c in job.coflows)


def grouping_prefix(instance, order: list) -> np.ndarray:
    """D_i for the geometric grouping (paper §VI step 2): the effective
    size of the aggregate coflow of the first i jobs of ``order`` — the
    max over 2m ports of the prefix cumsum of per-job load vectors (row
    sums commute with prefix sums, so no (m, m) accumulation is needed;
    both the old fast path and the old dense fallback now share this one
    O(n·m) computation).

    Memoized on (m, ordered per-job demand signature) with **incremental
    prefix extension**: when the exact key misses but a recent prefix of
    the order is cached — the appended-arrivals replan shape — only the
    new rows are cumsum-extended from the cached last row.  Exact in
    float64 below 2^53 (guarded).  Returns an int64 array aligned with
    ``order``.
    """
    from .ordering import job_load_vectors

    gkey_cache.maxsize = config.gkey_cache_size
    if not order:
        return np.zeros(0, dtype=np.int64)
    by_id = {j.jid: j for j in instance.jobs}
    m = instance.m
    sigs = tuple(_gkey_sig(by_id[jid]) for jid in order)
    key = (m,) + sigs
    found, val = gkey_cache.lookup(key)
    if found:
        _gkey_counts["exact"] += 1
        return val[1]
    n = len(order)
    base_row, base_D, start = None, None, 0
    for p in range(n - 1, max(n - 1 - _GKEY_PREFIX_PROBES, 0), -1):
        hit, pv = gkey_cache.peek((m,) + sigs[:p])
        if hit:
            base_row, base_D, start = pv[0], pv[1], p
            break
    _gkey_counts["extended" if base_row is not None else "cold"] += 1
    rows = job_load_vectors([by_id[jid] for jid in order[start:]], m)
    cum = np.cumsum(rows, axis=0)
    if base_row is not None:
        cum += base_row
    if cum.size and float(cum[-1].max()) >= 2.0**53:
        # past 2^53 float64 drops integer precision and the prefix maxima
        # would silently stop being the exact effective sizes
        raise ValueError(
            "prefix load cumsum exceeds the float64 integer-exact "
            "range (2^53); the geometric grouping keys would be inexact")
    D_new = cum.max(axis=1).astype(np.int64)
    D = D_new if base_D is None else np.concatenate([base_D, D_new])
    last_row = cum[-1].copy() if cum.size else \
        (base_row if base_row is not None else np.zeros(2 * m))
    gkey_cache.store(key, (last_row, D))
    return D


def cache_stats() -> dict:
    stats = {"bna": {**bna_cache.stats(), "batch": dict(_bna_batch)},
             "order": order_cache.stats(),
             "group": group_cache.stats(),
             "loads": loads_cache.stats(),
             "gkey": {**gkey_cache.stats(), "prefix": dict(_gkey_counts)}}
    if "repro.core.pipeline" in sys.modules:
        stats["plan"] = sys.modules["repro.core.pipeline"].pipeline_stats()
    return stats


def _result_caches() -> "list[tuple[str, LRUCache]]":
    """(config size attr, cache) for every result memo this module owns —
    the single list clear_caches/no_caches iterate, so a new cache cannot
    be forgotten by one of them."""
    return [("bna_cache_size", bna_cache),
            ("order_cache_size", order_cache),
            ("group_cache_size", group_cache),
            ("loads_cache_size", loads_cache),
            ("gkey_cache_size", gkey_cache)]


def clear_caches() -> None:
    for _, cache in _result_caches():
        cache.clear()
    for k in _bna_batch:
        _bna_batch[k] = 0
    for k in _gkey_counts:
        _gkey_counts[k] = 0
    if "repro.core.pipeline" in sys.modules:
        # result caches only; compiled executables are data-independent
        sys.modules["repro.core.pipeline"].clear_pipeline_caches()


@contextmanager
def no_caches():
    """Disable (and clear) the result caches — the from-scratch comparator.
    Covers the jit pipeline's edge cache too (compiled executables stay:
    they are data-independent, caching them is not a result memo)."""
    pairs = _result_caches()
    edge_cache = None
    if "repro.core.pipeline" in sys.modules:
        edge_cache = sys.modules["repro.core.pipeline"].edge_cache
    saved_cfg = {attr: getattr(config, attr) for attr, _ in pairs}
    caches = [c for _, c in pairs] + ([edge_cache] if edge_cache else [])
    saved = [(c.maxsize, dict(c._od), c.hits, c.misses) for c in caches]
    for attr, _ in pairs:
        setattr(config, attr, 0)
    for c in caches:
        c.clear()
        c.maxsize = 0
    try:
        yield
    finally:
        for attr, _ in pairs:
            setattr(config, attr, saved_cfg[attr])
        for c, (maxsize, od, hits, misses) in zip(caches, saved):
            c.maxsize = maxsize
            c._od = OrderedDict(od)
            c.hits, c.misses = hits, misses
