"""Unified scheduler engine: one registry, one plan() entry point.

Every scheduler in the repo is registered here under a string key and
exposed behind the same protocol — ``plan(instance) -> Transcript`` — so the
online driver, the benchmarks, and the examples stop hand-wiring closures
around ``gdm``/``om_alg``/``backfill``.

Registered schedulers and their paper algorithms (Shafiee & Ghaderi 2020):

========== ==============================================================
key        paper construction
========== ==============================================================
gdm        G-DM (Algorithm 4, §VI): primal-dual order (Algorithm 5) +
           geometric grouping + DMA (Algorithm 2) per group
gdm_rt     G-DM-RT (Algorithm 4 over rooted trees): groups scheduled by
           DMA-RT (Algorithm 3 / §V-B); ``nested=False`` selects the flat
           fast path (single global merge-and-fix)
om_alg     O(m)Alg baseline (Tian et al. [5]): one-at-a-time jobs in
           Algorithm 5 order, each coflow optimally via BNA (Algorithm 1)
gdm_bf     G-DM + backfilling (§VII)
gdm_rt_bf  G-DM-RT + backfilling (§VII)
om_alg_bf  O(m)Alg + backfilling (§VII)
========== ==============================================================

The ``*_bf`` variants accept ``exec="packet"`` (default: matching-granular
re-execution of the plan's timed-matching decomposition, pointwise never
worse than the plan) or ``exec="ledger"`` (the historical uniform-rate
ledger sweep) — see ``backfill.py`` for the two-executor model.

Adding a scheduler is one decorator::

    @register_scheduler("my_sched", "one-line description")
    def _my_sched(instance, *, seed=0, **opts):
        return ...  # CompositeSchedule or BackfillResult

Incremental online path
-----------------------
:func:`plan_online` wraps the §VII-C.2 rescheduling protocol around a
registered scheduler — a thin driver over the event-driven
:class:`~repro.core.session.SchedulerSession` (``driver="batch"`` selects
the historical closed loop; ``session.py``'s frontier-append plan repair
rides on top) — and makes the repeated replanning incremental via the two
engine caches (see ``backend.py``):

* BNA decompositions are keyed on demand **bytes**, so coflows the previous
  window did not touch hit the cache even though ``_sub_instance`` builds
  fresh ``Coflow`` objects on every arrival (the old object-attribute memo
  missed every time).
* The primal-dual job order is keyed on the exact scheduling state, so
  replanning an unchanged state (simultaneous arrivals resolved in one
  batch, A/B pairs, or an active set that only shrank without any surviving
  demand being touched) reuses the previous order.  Keying on the full
  state is what keeps the incremental path *results-identical* to a
  from-scratch recomputation.

Both cache hit rates are reported in ``OnlineResult.stats``.

The alpha computation inside every ``merge_and_fix`` call is routed through
the backend dispatch layer (numpy oracle or the ``coflow_merge`` Pallas
kernel — see ``backend.py``; switch with ``REPRO_ALPHA_BACKEND=pallas`` or
``backend.set_alpha_backend("pallas")``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from . import backend
from .backfill import BackfillResult, backfill
from .baseline import om_alg
from .gdm import gdm
from .result import CompositeSchedule, Transcript
from .types import Instance

__all__ = [
    "Scheduler",
    "PlanResult",
    "register_scheduler",
    "make_scheduler",
    "available_schedulers",
    "scheduler_options",
    "plan",
    "plan_online",
]


@runtime_checkable
class Scheduler(Protocol):
    """Anything that turns an Instance into executed transmissions."""

    name: str

    def plan(self, instance: Instance) -> Transcript:
        ...


@dataclass
class PlanResult:
    """A planned schedule plus uniform metric access.

    `schedule` is the scheduler's native result — a CompositeSchedule for
    the plain algorithms, a BackfillResult for the backfilled variants —
    with the metric/transcript accessors normalized here.
    """

    name: str
    schedule: CompositeSchedule | BackfillResult

    def transcript(self) -> Transcript:
        s = self.schedule
        return s.transcript() if callable(s.transcript) else s.transcript

    def job_completions(self) -> dict[int, float]:
        s = self.schedule
        return dict(s.job_completions) if isinstance(s, BackfillResult) \
            else s.job_completions()

    def twct(self, from_release: bool = False) -> float:
        return self.schedule.twct(from_release)

    @property
    def makespan(self) -> float:
        return float(self.schedule.makespan)

    def backfilled(self, exec: str = "packet") -> "PlanResult":
        """Backfill this plan (§VII) without re-planning.

        exec="packet" (default) re-executes the timed-matching decomposition
        (pointwise never worse than the plan); exec="ledger" re-executes the
        uniform-rate ledger (the historical executor)."""
        if isinstance(self.schedule, BackfillResult):
            if self.schedule.executor != exec:
                raise ValueError(
                    f"already backfilled with exec={self.schedule.executor!r}; "
                    f"a BackfillResult cannot be re-executed as {exec!r} — "
                    f"plan the base scheduler and call backfill(..., exec=...)")
            return self
        return PlanResult(f"{self.name}_bf", backfill(self.schedule, exec=exec))


_Factory = Callable[..., "CompositeSchedule | BackfillResult"]


@dataclass
class _Entry:
    factory: _Factory
    doc: str
    options: tuple[str, ...]


_REGISTRY: dict[str, _Entry] = {}


def register_scheduler(name: str, doc: str = "",
                       options: tuple[str, ...] = ()):
    """Register `factory(instance, **opts)` under `name` (decorator).

    ``options`` declares the option names the factory accepts;
    :func:`make_scheduler` rejects anything else with an error listing the
    valid options, so a typo (``execc="ledger"``) fails loudly at
    construction time instead of being silently swallowed.  The declared
    tuple is checked against the factory's signature at registration, so
    it cannot drift: every keyword-only parameter must be declared, and —
    unless the factory forwards ``**opts`` — every declared option must be
    a real parameter."""
    import inspect

    def deco(factory: _Factory) -> _Factory:
        if name in _REGISTRY:
            raise ValueError(f"scheduler {name!r} already registered")
        params = inspect.signature(factory).parameters.values()
        kw = {p.name for p in params if p.kind == p.KEYWORD_ONLY}
        has_var = any(p.kind == p.VAR_KEYWORD for p in params)
        declared = set(options)
        if kw - declared:
            raise ValueError(f"scheduler {name!r}: keyword option(s) "
                             f"{sorted(kw - declared)} missing from the "
                             f"declared options")
        if not has_var and declared - kw:
            raise ValueError(f"scheduler {name!r}: declared option(s) "
                             f"{sorted(declared - kw)} not accepted by the "
                             f"factory")
        _REGISTRY[name] = _Entry(
            factory, doc or (factory.__doc__ or "").strip(), tuple(options))
        return factory

    return deco


def available_schedulers() -> dict[str, str]:
    """name -> one-line description, for CLIs and reports."""
    return {name: e.doc for name, e in sorted(_REGISTRY.items())}


def scheduler_options(name: str) -> tuple[str, ...]:
    """The option names scheduler `name` accepts (for CLIs and errors)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name].options


@dataclass
class _Registered:
    """A registry entry bound to its options; satisfies Scheduler."""

    name: str
    opts: dict = field(default_factory=dict)

    def plan_full(self, instance: Instance, **overrides) -> PlanResult:
        # instance-level plan prefetch: one batched decomposition call
        # (jit pipeline or bna_pieces_many, per REPRO_PLAN_BACKEND) warms
        # the caches for every coflow BEFORE the factory's
        # isolated_job_unit / dma_srt walk jobs one at a time (no-op when
        # batching or the cache is off; results-identical either way).
        # `overrides` are per-plan option overrides validated against the
        # registry exactly like make_scheduler's — the session threads its
        # pinned gamma through here, one value per planning event.
        opts = self.opts
        if overrides:
            unknown = sorted(set(overrides)
                             - set(_REGISTRY[self.name].options))
            if unknown:
                raise TypeError(
                    f"unknown plan override(s) {unknown} for scheduler "
                    f"{self.name!r}; valid options: "
                    f"{sorted(_REGISTRY[self.name].options)}")
            opts = {**self.opts, **overrides}
        backend.prefetch_plan(c.demand for j in instance.jobs
                              for c in j.coflows)
        return PlanResult(self.name,
                          _REGISTRY[self.name].factory(instance, **opts))

    def plan(self, instance: Instance) -> Transcript:
        return self.plan_full(instance).transcript()


def make_scheduler(name: str, **opts) -> _Registered:
    """Instantiate a registered scheduler with bound options.

    Options are scheduler-specific (beta, seed, nested, decompose, ...) and
    validated against the registry's declared option names — an unknown
    option raises immediately with the valid set, so typos cannot be
    silently swallowed.  Prefer `seed` over passing an `rng`: a seed
    re-derives a fresh generator per plan() call, which is what the online
    driver's repeated replanning expects (and what the legacy closures did).
    """
    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    unknown = sorted(set(opts) - set(_REGISTRY[name].options))
    if unknown:
        raise TypeError(
            f"unknown option(s) {unknown} for scheduler {name!r}; "
            f"valid options: {sorted(_REGISTRY[name].options)}")
    return _Registered(name, opts)


def plan(instance: Instance, name: str, **opts) -> PlanResult:
    """One-shot: plan `instance` with scheduler `name`."""
    return make_scheduler(name, **opts).plan_full(instance)


# --------------------------------------------------------------------------
# registered schedulers
# --------------------------------------------------------------------------

def _rng(opts_rng, seed):
    return np.random.default_rng(seed) if opts_rng is None else opts_rng


_GDM_OPTS = ("beta", "seed", "rng", "nested", "decompose", "delays", "gamma")
_GDM_RT_OPTS = _GDM_OPTS + ("require_tree",)
_OM_ALG_OPTS = ("decompose", "seed")


@register_scheduler("gdm", "G-DM (Algorithm 4): primal-dual order + "
                           "geometric groups + DMA per group; "
                           "delays=random|spread",
                    options=_GDM_OPTS)
def _gdm(instance: Instance, *, beta: float = 2.0, seed: int = 0, rng=None,
         nested: bool = True, decompose: bool = False,
         delays: str = "random", gamma=None) -> CompositeSchedule:
    return gdm(instance, beta=beta, rng=_rng(rng, seed), rooted=False,
               decompose=decompose, nested=nested, delays=delays,
               gamma=gamma)


@register_scheduler("gdm_rt", "G-DM-RT (Algorithm 4 over rooted trees, "
                              "DMA-RT groups; nested=False = flat fast "
                              "path; delays=random|spread)",
                    options=_GDM_RT_OPTS)
def _gdm_rt(instance: Instance, *, beta: float = 2.0, seed: int = 0, rng=None,
            nested: bool = True, decompose: bool = False,
            require_tree: bool = True,
            delays: str = "random", gamma=None) -> CompositeSchedule:
    return gdm(instance, beta=beta, rng=_rng(rng, seed), rooted=True,
               decompose=decompose, nested=nested, require_tree=require_tree,
               delays=delays, gamma=gamma)


@register_scheduler("om_alg", "O(m)Alg baseline: one-at-a-time jobs in "
                              "Algorithm 5 order, BNA per coflow",
                    options=_OM_ALG_OPTS)
def _om_alg(instance: Instance, *, decompose: bool = False,
            seed: int = 0) -> CompositeSchedule:
    # `seed` is accepted for registry uniformity (every scheduler can be
    # planned as plan(inst, name, seed=...)); the baseline is deterministic.
    del seed
    return om_alg(instance, decompose=decompose)


@register_scheduler("gdm_bf", "G-DM + backfilling (§VII); exec=packet|ledger",
                    options=_GDM_OPTS + ("exec",))
def _gdm_bf(instance: Instance, *, exec: str = "packet",
            **opts) -> BackfillResult:
    return backfill(_gdm(instance, **opts), exec=exec)


@register_scheduler("gdm_rt_bf", "G-DM-RT + backfilling (§VII); "
                                 "exec=packet|ledger",
                    options=_GDM_RT_OPTS + ("exec",))
def _gdm_rt_bf(instance: Instance, *, exec: str = "packet",
               **opts) -> BackfillResult:
    return backfill(_gdm_rt(instance, **opts), exec=exec)


@register_scheduler("om_alg_bf", "O(m)Alg + backfilling (§VII); "
                                 "exec=packet|ledger",
                    options=_OM_ALG_OPTS + ("exec",))
def _om_alg_bf(instance: Instance, *, exec: str = "packet",
               **opts) -> BackfillResult:
    return backfill(_om_alg(instance, **opts), exec=exec)


# --------------------------------------------------------------------------
# incremental online path
# --------------------------------------------------------------------------

def plan_online(instance: Instance, scheduler: "str | Scheduler",
                incremental: bool = True, driver: str = "session",
                repair: bool = True, gamma="residual", **opts):
    """Run the §VII-C.2 online protocol with a registered scheduler — a
    thin, results-identical driver over a :class:`SchedulerSession`
    (``driver="batch"`` selects the historical closed batch loop, the
    reference comparator).

    incremental=True (default) replans through the engine caches —
    results-identical to a cold run, measurably faster when reschedules
    share untouched coflows.  incremental=False disables and clears the
    caches for the duration (the from-scratch comparator).

    Returns the driver's OnlineResult with `stats` filled in: wall-clock
    seconds, reschedule count, per-cache hits/misses/hit-rate deltas
    attributable to this run, and (session driver) the session's
    repair/replan counters under ``stats["session"]``.
    """
    from .online import simulate_online

    if isinstance(scheduler, str):
        scheduler = make_scheduler(scheduler, **opts)
    elif opts:
        raise TypeError("scheduler options are only accepted with a "
                        "scheduler name, not a prebuilt Scheduler")

    def _run():
        before = backend.cache_stats()
        t0 = time.perf_counter()
        res = simulate_online(instance, scheduler, driver=driver,
                              repair=repair, gamma=gamma)
        wall = time.perf_counter() - t0
        after = backend.cache_stats()
        stats: dict = {"wall_s": wall, "reschedules": res.reschedules,
                       "incremental": incremental, "driver": driver}
        if "session" in res.stats:
            stats["session"] = res.stats["session"]
        for cache in ("bna", "order", "group"):
            hits = after[cache]["hits"] - before[cache]["hits"]
            misses = after[cache]["misses"] - before[cache]["misses"]
            total = hits + misses
            stats[cache] = {"hits": hits, "misses": misses,
                            "hit_rate": (hits / total) if total else 0.0}
        res.stats = stats
        return res

    if incremental:
        return _run()
    with backend.no_caches():
        return _run()
