"""BNA — Birkhoff–von-Neumann Algorithm (paper Algorithm 1).

Schedules a single coflow (m x m integer demand matrix) optimally: the
returned preemptive schedule finishes in exactly D slots, D = effective size
(Definition 1), which is a lower bound due to unit port capacities.

Implementation notes
--------------------
Algorithm 1 needs, each iteration, a matching "such that all tight nodes are
involved" (line 4). We realize this with the classical filled-matrix
argument (Lawler & Labetoulle 1978): consider the bipartite graph with an
edge (s, r) iff

    d[s, r] > 0                          (a *real* edge), or
    d_s < D and d_r < D                  (a *slack* edge)

A perfect matching always exists in this graph (pad D - d_s / D - d_r slack
to make the matrix doubly stochastic after dividing by D; Birkhoff gives a
perfect matching on its support). Tight nodes admit no slack edges, so any
perfect matching covers every tight node through a real edge. Slack-matched
pairs simply idle; only real matched edges transmit. The step length

    t = min( min_{(s,r) in M, d_sr>0} d_sr,  min_{i not real-matched} D - d_i )

is the faithful reading of line 5 under the filled-matrix construction: a
port matched through a slack edge does not transmit, so it constrains t the
same way an unmatched port does. Each step either zeroes a real matched edge
or makes a port tight, so there are at most nnz + 2m iterations.

The perfect matching is maintained incrementally across iterations (repair
via augmenting paths only for ports whose matched edge became invalid),
keeping the whole decomposition near O((nnz + m) * m) vector ops.

This module is the *scalar reference*: one coflow at a time, the code the
correctness argument above reads against.  The batched subsystem
(``core/matching.py``) decomposes many coflows at once — same pieces,
bit-identical (it shares :func:`support_restrict` / :func:`expand_pieces`
and the `_augment` repair below) — and is what the engine's prefetch path
actually runs; see ``core/backend.py`` (``bna_pieces_many``).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "bna",
    "schedule_total_time",
    "verify_bna_schedule",
    "support_restrict",
    "expand_pieces",
]

_NO_MATCH = -1


def _augment(start: int, adj_fn, match_sr: np.ndarray, match_rs: np.ndarray, m: int) -> bool:
    """One augmenting-path search (Kuhn) from unmatched sender `start`.

    adj_fn(s) -> boolean (m,) array of admissible receivers for sender s.
    Iterative DFS; numpy row ops keep the inner loop vectorized.
    """
    visited = np.zeros(m, dtype=bool)
    # stack of (sender, candidate receivers iterator state)
    parent_r: dict[int, int] = {}  # receiver -> sender that reached it
    stack = [start]
    frontier_of: dict[int, np.ndarray] = {}
    while stack:
        s = stack[-1]
        if s not in frontier_of:
            frontier_of[s] = np.flatnonzero(adj_fn(s) & ~visited)
        found = False
        while frontier_of[s].size:
            r = int(frontier_of[s][0])
            frontier_of[s] = frontier_of[s][1:]
            if visited[r]:
                continue
            visited[r] = True
            parent_r[r] = s
            nxt = int(match_rs[r])
            if nxt == _NO_MATCH:
                # augment along alternating path ending at r
                while True:
                    ps = parent_r[r]
                    prev_r = int(match_sr[ps])
                    match_sr[ps] = r
                    match_rs[r] = ps
                    if ps == start:
                        return True
                    r = prev_r
            else:
                stack.append(nxt)
                found = True
                break
        if not found:
            stack.pop()
            frontier_of.pop(s, None)
    return False


def support_restrict(
    demand: np.ndarray,
) -> tuple[np.ndarray | None, np.ndarray | None, np.ndarray | None]:
    """Validate `demand` and restrict it to its SUPPORT ports.

    Returns ``(sub, rows_p, cols_p)``: ``sub`` is the k x k int64 matrix over
    the loaded ports (k = max(#loaded rows, #loaded cols); loaded rows/cols
    first, padded with arbitrary idle ports up to square), ``rows_p`` /
    ``cols_p`` map its axes back to the full port ids — or ``None`` when no
    restriction applies (k == m).  ``sub is None`` means the demand is all
    zero.  Zero-load ports are never tight and never bind the step length,
    so they can idle throughout — this makes the decomposition cost scale
    with the coflow's width, not the switch size.
    """
    d_full = np.asarray(demand, dtype=np.int64)
    if d_full.ndim != 2 or d_full.shape[0] != d_full.shape[1]:
        raise ValueError("demand must be square")
    if (d_full < 0).any():
        raise ValueError("demand must be non-negative")
    m_full = d_full.shape[0]
    rows = np.flatnonzero(d_full.sum(axis=1) > 0)
    cols = np.flatnonzero(d_full.sum(axis=0) > 0)
    k = max(rows.size, cols.size)
    if k == 0:
        return None, None, None
    if k < m_full:
        rows_p = np.concatenate([rows, np.setdiff1d(np.arange(m_full), rows)[: k - rows.size]])
        cols_p = np.concatenate([cols, np.setdiff1d(np.arange(m_full), cols)[: k - cols.size]])
        return d_full[np.ix_(rows_p, cols_p)], rows_p, cols_p
    return d_full, None, None


def expand_pieces(
    pieces: list[tuple[int, np.ndarray]],
    rows_p: np.ndarray, cols_p: np.ndarray, m_full: int,
) -> list[tuple[int, np.ndarray]]:
    """Map support-restricted (duration, matching) pieces back to full
    port ids (inverse of :func:`support_restrict`'s axis remap)."""
    out: list[tuple[int, np.ndarray]] = []
    for t, match in pieces:
        full = np.full(m_full, _NO_MATCH, dtype=np.int64)
        ss = np.flatnonzero(match != _NO_MATCH)
        full[rows_p[ss]] = cols_p[match[ss]]
        out.append((t, full))
    return out


def bna(demand: np.ndarray, validate: bool = False) -> list[tuple[int, np.ndarray]]:
    """Decompose `demand` into a list of (duration, matching) pieces.

    matching: int array (m,), matching[s] = r when (s, r) transmits for the
    whole piece, -1 when sender s idles. Total time == effective size D.

    The matching problem is restricted to the demand's SUPPORT ports via
    :func:`support_restrict`.
    """
    d_full = np.asarray(demand, dtype=np.int64)
    sub, rows_p, cols_p = support_restrict(d_full)
    if sub is None:
        return []
    if rows_p is not None:
        out = expand_pieces(_bna_core(sub), rows_p, cols_p, d_full.shape[0])
        if validate:
            verify_bna_schedule(d_full, out)
        return out
    return _bna_core(sub, validate=validate)


def _bna_core(demand: np.ndarray, validate: bool = False) -> list[tuple[int, np.ndarray]]:
    d = np.array(demand, dtype=np.int64, copy=True)
    m = d.shape[0]
    row = d.sum(axis=1)
    col = d.sum(axis=0)
    D = int(max(row.max(initial=0), col.max(initial=0)))
    if D == 0:
        return []

    match_sr = np.full(m, _NO_MATCH, dtype=np.int64)
    match_rs = np.full(m, _NO_MATCH, dtype=np.int64)

    def adj_fn(s: int) -> np.ndarray:
        # real edges, plus slack edges if sender s is non-tight
        a = d[s] > 0
        if row[s] < D:
            a = a | (col < D)
        return a

    def repair() -> None:
        """Restore a perfect matching after d/row/col/D changed."""
        # invalidate matched edges that left the graph:
        # edge (s, r) is valid iff d[s,r] > 0 or (row[s] < D and col[r] < D)
        ms = np.flatnonzero(match_sr != _NO_MATCH)
        if ms.size:
            rr = match_sr[ms]
            bad = (d[ms, rr] == 0) & ((row[ms] >= D) | (col[rr] >= D))
            for s in ms[bad]:
                r = match_sr[s]
                match_sr[s] = _NO_MATCH
                match_rs[r] = _NO_MATCH
        for s in np.flatnonzero(match_sr == _NO_MATCH):
            if not _augment(int(s), adj_fn, match_sr, match_rs, m):
                raise AssertionError("BNA invariant violated: no perfect matching")

    pieces: list[tuple[int, np.ndarray]] = []
    # initial perfect matching
    repair()
    guard = int(np.count_nonzero(d)) + 2 * m + 4
    it = 0
    while D > 0:
        it += 1
        if it > guard + 4 * m:
            raise AssertionError("BNA failed to terminate (bug)")
        senders = np.arange(m)
        rcv = match_sr
        real = (rcv != _NO_MATCH) & (d[senders, np.maximum(rcv, 0)] > 0)
        # step length (line 5, filled-matrix form)
        t = np.iinfo(np.int64).max
        if real.any():
            t = int(d[senders[real], rcv[real]].min())
        # ports not transmitting constrain t by their slack D - load
        idle_s = ~real
        if idle_s.any():
            t = min(t, int((D - row[idle_s]).min()))
        recv_real = np.zeros(m, dtype=bool)
        recv_real[rcv[real]] = True
        if (~recv_real).any():
            t = min(t, int((D - col[~recv_real]).min()))
        assert t > 0, "zero-length BNA step (bug)"

        piece = np.full(m, _NO_MATCH, dtype=np.int64)
        piece[senders[real]] = rcv[real]
        pieces.append((t, piece))

        # transmit t units on every real matched edge
        sr = senders[real]
        rr = rcv[real]
        d[sr, rr] -= t
        row[sr] -= t
        col[rr] -= t
        D -= t
        if D == 0:
            break
        repair()

    if validate:
        verify_bna_schedule(np.asarray(demand, dtype=np.int64), pieces)
    return pieces


def schedule_total_time(pieces: list[tuple[int, np.ndarray]]) -> int:
    return int(sum(t for t, _ in pieces))


def verify_bna_schedule(demand: np.ndarray, pieces: list[tuple[int, np.ndarray]]) -> None:
    """Check: every piece is a matching; transmissions exactly cover demand;
    total time == effective size."""
    m = demand.shape[0]
    remaining = demand.astype(np.int64).copy()
    for t, piece in pieces:
        assert t > 0
        srcs = np.flatnonzero(piece != _NO_MATCH)
        dsts = piece[srcs]
        assert len(set(dsts.tolist())) == len(dsts), "receivers collide"
        remaining[srcs, dsts] -= t
        assert (remaining[srcs, dsts] >= 0).all(), "over-transmission"
    assert (remaining == 0).all(), "demand not fully served"
    row = demand.sum(axis=1)
    col = demand.sum(axis=0)
    D = int(max(row.max(initial=0), col.max(initial=0)))
    assert schedule_total_time(pieces) == D, "schedule not optimal (!= D)"
