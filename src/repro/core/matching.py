"""Batched matching — multi-coflow BNA (Algorithm 1 across a whole batch).

Every scheduler's cold start runs BNA once per coflow, and the per-coflow
implementation (``core/bna.py``, the scalar reference) pays its Python/numpy
dispatch overhead per *iteration per coflow*.  :func:`bna_many` decomposes
many demand matrices at once instead:

1. **Support-restrict** each demand exactly as the scalar path does
   (`bna.support_restrict`), then **bucket** the resulting k x k matrices by
   padded width w (next power of two) and pack each bucket into a padded
   ``(K, w, w)`` int64 stack.  Padding ports carry zero load, so they are
   never tight, never real-matched, and constrain the step length only by
   ``D - 0 = D`` — never binding, because the step is always <= the minimum
   matched demand <= D.  The padded stack therefore decomposes to exactly
   the same pieces as the unpadded matrices.
2. Run the **filled-matrix decomposition in lock-step** across the bucket:
   the step-length computation (line 5 of Algorithm 1 in its filled-matrix
   form), the demand/row/col/D updates, and the matched-edge invalidation
   are vectorized over the whole active batch (one ``bna_step``), while the
   augmenting-path repair stays per-matrix (`bna._augment`, byte-identical
   adjacency) but touches only matrices whose matching was actually
   invalidated.  Matrices whose D hits zero leave the active set; the batch
   is compacted whenever more than half of it has drained.
3. Map the collected pieces back through the support remap
   (`bna.expand_pieces`).

The matrices are independent, so interleaving their iterations cannot change
any matrix's own step sequence: **pieces are bit-identical to the scalar
path** (``tests/test_matching.py`` property-tests this across the
width/dtype/zero-demand grid, and the 9x6 scenario matrix pins plan
identity).  The win is wall-clock only: per iteration, one batched step
replaces len(batch) scalar steps' worth of small-array numpy dispatch.

The batched step dispatches through the ``REPRO_BNA_BACKEND`` knob
(``core/backend.py``): ``numpy`` runs the in-place vectorized step below;
``pallas`` routes the same arithmetic through the ``kernels/bna_step``
Pallas kernel (interpret mode on CPU, compiled on TPU); ``auto`` picks
pallas iff a TPU is attached.  The two are bit-identical (integer
arithmetic, same formulas); a kernel failure under ``auto`` falls back to
numpy with a one-time warning, an explicitly requested pallas backend
propagates the error.

The jit planning pipeline (``core/pipeline.py``, ``REPRO_PLAN_BACKEND``)
reuses this module's support-restrict/bucket/pack machinery and
``_bna_core_batch`` as its python fallback; its compiled decomposition is a
jnp mirror of :func:`bna_step_inplace` plus a vmapped repair, proven (and
tested) to produce the same per-lane step sequences.
"""
from __future__ import annotations

import warnings

import numpy as np

from .bna import (_NO_MATCH, expand_pieces, support_restrict,
                  verify_bna_schedule)

__all__ = ["bna_many", "bna_step_inplace", "bucket_width"]

_BIG = np.iinfo(np.int64).max


def bucket_width(k: int) -> int:
    """Padded batch width for a k x k support-restricted demand: the next
    power of two, so mixed-width instances land in O(log m) buckets."""
    return 1 << max(k - 1, 0).bit_length()


def bna_many(
    demands: list[np.ndarray],
    validate: bool = False,
    force: str | None = None,
) -> list[list[tuple[int, np.ndarray]]]:
    """Decompose every demand in `demands`; element i is bit-identical to
    ``bna(demands[i])``.  `force` overrides the BNA backend for this call
    (None follows ``backend.config.bna_backend``)."""
    out: list[list[tuple[int, np.ndarray]] | None] = [None] * len(demands)
    buckets: dict[int, list[tuple[int, np.ndarray, np.ndarray | None,
                                  np.ndarray | None, int]]] = {}
    for i, dem in enumerate(demands):
        d_full = np.asarray(dem, dtype=np.int64)
        sub, rows_p, cols_p = support_restrict(d_full)
        if sub is None:
            out[i] = []
            continue
        w = bucket_width(sub.shape[0])
        buckets.setdefault(w, []).append(
            (i, sub, rows_p, cols_p, d_full.shape[0]))
    for w in sorted(buckets):
        items = buckets[w]
        pieces_lists = _bna_core_batch([it[1] for it in items], w, force)
        for (i, _sub, rows_p, cols_p, m_full), pieces in zip(items, pieces_lists):
            out[i] = pieces if rows_p is None else \
                expand_pieces(pieces, rows_p, cols_p, m_full)
            if validate:
                verify_bna_schedule(np.asarray(demands[i], dtype=np.int64),
                                    out[i])
    return out  # type: ignore[return-value]


# --------------------------------------------------------------------------
# batched core
# --------------------------------------------------------------------------

def _augment_py(start: int, k: int, dlist: list, rowlist: list,
                collist: list, Dv: int, msr: list, mrs: list) -> bool:
    """`bna._augment` on Python-native state.

    At batch widths (k <= ~64) the augmenting DFS is dispatch-bound, not
    compute-bound: per-element numpy access costs more than the comparison
    it performs.  This mirror runs the identical search — frontiers built
    in increasing receiver order when a sender is first reached (filtering
    receivers already visited at that moment, exactly like the scalar
    `np.flatnonzero(adj & ~visited)`), consumed with visited-skipping,
    alternating-path augmentation on the first free receiver — over plain
    lists, so the matchings it produces are identical and the constant is
    several times smaller."""
    visited = [False] * k
    parent_r: dict[int, int] = {}
    stack = [start]
    frontier: dict[int, list[int]] = {}
    pos: dict[int, int] = {}
    while stack:
        s = stack[-1]
        f = frontier.get(s)
        if f is None:
            ds = dlist[s]
            if rowlist[s] < Dv:
                f = [r for r in range(k)
                     if not visited[r] and (ds[r] > 0 or collist[r] < Dv)]
            else:
                f = [r for r in range(k) if not visited[r] and ds[r] > 0]
            frontier[s] = f
            pos[s] = 0
        found = False
        p = pos[s]
        while p < len(f):
            r = f[p]
            p += 1
            if visited[r]:
                continue
            visited[r] = True
            parent_r[r] = s
            nxt = mrs[r]
            if nxt == _NO_MATCH:
                pos[s] = p
                while True:   # augment along the alternating path to start
                    ps = parent_r[r]
                    prev_r = msr[ps]
                    msr[ps] = r
                    mrs[r] = ps
                    if ps == start:
                        return True
                    r = prev_r
            else:
                pos[s] = p
                stack.append(nxt)
                found = True
                break
        if not found:
            pos[s] = p
            stack.pop()
            frontier.pop(s, None)
    return False


def _initial_matching(d2: np.ndarray, row1: np.ndarray, col1: np.ndarray,
                      Dv: int, msr: np.ndarray, mrs: np.ndarray,
                      k: int) -> None:
    """Initial perfect matching on the filled graph of one matrix — the
    scalar `repair()` from an all-unmatched state, i.e. `_repair_one`
    with nothing to clear (augments senders in increasing order)."""
    _repair_one(d2, row1, col1, Dv, msr, mrs, k,
                np.zeros(k, dtype=bool))


def _repair_one(d2: np.ndarray, row1: np.ndarray, col1: np.ndarray, Dv: int,
                msr: np.ndarray, mrs: np.ndarray, k: int,
                bad: np.ndarray) -> None:
    """Scalar repair() for one matrix of the batch: clear the invalidated
    matched edges (`bad`, ascending sender order, exactly the scalar bad
    mask), then re-augment unmatched senders in increasing order."""
    dlist = d2[:k, :k].tolist()
    rowlist = row1[:k].tolist()
    collist = col1[:k].tolist()
    msr_l = msr[:k].tolist()
    mrs_l = mrs[:k].tolist()
    for s in np.flatnonzero(bad):
        r = msr_l[s]
        msr_l[s] = _NO_MATCH
        mrs_l[r] = _NO_MATCH
    for s in range(k):
        if msr_l[s] == _NO_MATCH:
            if not _augment_py(s, k, dlist, rowlist, collist, Dv,
                               msr_l, mrs_l):
                raise AssertionError(
                    "BNA invariant violated: no perfect matching")
    msr[:k] = msr_l
    mrs[:k] = mrs_l


def bna_step_inplace(
    d: np.ndarray,      # (L, w, w) int64, mutated
    row: np.ndarray,    # (L, w) int64, mutated
    col: np.ndarray,    # (L, w) int64, mutated
    D: np.ndarray,      # (L,) int64 (not mutated)
    match: np.ndarray,  # (L, w) int64 match_sr (not mutated)
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One vectorized lock-step BNA iteration over the live batch,
    mutating d/row/col in place; returns ``(t, piece, D_new, invalid)``.

    This is the SINGLE numpy source of the step formulas: the numpy
    backend runs it directly, ``kernels/bna_step/ref.py`` wraps it on
    copies as the kernel oracle, and the Pallas kernel must stay
    bit-identical to it (all-integer arithmetic, so parity is equality).

    Formulas mirror the scalar ``_bna_core`` exactly: step length is the
    three-term min of line 5 in filled-matrix form (matched demands,
    idle-sender slack D - row, idle-receiver slack D - col); ``invalid``
    is the scalar repair()'s bad mask on the post-update state, masked to
    matrices still running (drained matrices get t == 0 and no repair)."""
    midx = np.maximum(match, 0)
    dm = np.take_along_axis(d, midx[:, :, None], axis=2)[:, :, 0]
    real = (match != _NO_MATCH) & (dm > 0)
    t = np.where(real, dm, _BIG).min(axis=1)
    t = np.minimum(t, np.where(~real, D[:, None] - row, _BIG).min(axis=1))
    recv = np.zeros(real.shape, dtype=bool)
    bi, si = np.nonzero(real)
    ri = midx[bi, si]
    recv[bi, ri] = True
    t = np.minimum(t, np.where(~recv, D[:, None] - col, _BIG).min(axis=1))
    piece = np.where(real, match, np.int64(_NO_MATCH))
    # transmit t units on every real matched edge
    d[bi, si, ri] -= t[bi]
    row -= t[:, None] * real
    col -= t[:, None] * recv
    D2 = D - t
    dm2 = np.take_along_axis(d, midx[:, :, None], axis=2)[:, :, 0]
    colm = np.take_along_axis(col, midx, axis=1)
    invalid = (match != _NO_MATCH) & (dm2 == 0) \
        & ((row >= D2[:, None]) | (colm >= D2[:, None])) \
        & (D2 > 0)[:, None]
    return t, piece, D2, invalid


_warned_bna_fallback = False


def _resolve_step(force: str | None):
    """(step_fn, backend_name): the batched-step implementation for this
    call per the REPRO_BNA_BACKEND dispatch (see backend.py)."""
    from .backend import config, resolve_bna_backend

    requested = force or config.bna_backend
    name = resolve_bna_backend(force)
    if name != "pallas":
        return None, "numpy"

    def step_pallas(d, row, col, D, match):
        global _warned_bna_fallback
        try:
            # repro: allow(backend-dispatch): this IS the REPRO_BNA_BACKEND resolved dispatch site
            from repro.kernels.bna_step.ops import bna_step_batch

            return bna_step_batch(d, row, col, D, match)
        except Exception as exc:  # pragma: no cover - env-dependent
            if requested == "pallas":
                raise
            if not _warned_bna_fallback:
                _warned_bna_fallback = True
                warnings.warn(
                    f"bna_step pallas backend failed ({exc!r}); "
                    "auto-dispatch falling back to the numpy step",
                    RuntimeWarning)
            return None

    return step_pallas, "pallas"


def _bna_core_batch(
    subs: list[np.ndarray], w: int, force: str | None = None,
) -> list[list[tuple[int, np.ndarray]]]:
    """Decompose a bucket of support-restricted matrices (each k x k with
    bucket_width(k) == w) in lock-step.  Returns per-matrix pieces, each
    bit-identical to ``_bna_core`` on that matrix alone."""
    B = len(subs)
    ks_full = np.array([s.shape[0] for s in subs], dtype=np.int64)
    ks = ks_full.copy()
    d = np.zeros((B, w, w), dtype=np.int64)
    for i, s in enumerate(subs):
        k = s.shape[0]
        d[i, :k, :k] = s
    row = d.sum(axis=2)
    col = d.sum(axis=1)
    D = np.maximum(row.max(axis=1), col.max(axis=1))
    match_sr = np.full((B, w), _NO_MATCH, dtype=np.int64)
    match_rs = np.full((B, w), _NO_MATCH, dtype=np.int64)
    for i in range(B):
        _initial_matching(d[i], row[i], col[i], int(D[i]),
                          match_sr[i], match_rs[i], int(ks[i]))

    pieces_out: list[list[tuple[int, np.ndarray]]] = [[] for _ in range(B)]
    ids = np.arange(B, dtype=np.int64)
    # scalar guard: nnz + 2m + 4 iterations, slack 4m — take the bucket max
    guard = int((d > 0).sum(axis=(1, 2)).max(initial=0)) + 6 * w + 8
    step_pallas, _backend = _resolve_step(force)
    steps: list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []
    it = 0
    while True:
        alive = D > 0
        if not alive.any():
            break
        it += 1
        if it > guard:
            raise AssertionError("batched BNA failed to terminate (bug)")

        if step_pallas is not None:
            res = step_pallas(d, row, col, D, match_sr)
            if res is None:        # auto-dispatch fallback, rest of bucket
                step_pallas = None
        if step_pallas is not None:
            t, piece, d, row, col, D, invalid = res
        else:
            t, piece, D, invalid = bna_step_inplace(d, row, col, D, match_sr)
        assert bool((t[alive] > 0).all()), "zero-length BNA step (bug)"
        steps.append((ids, t, piece, alive))

        finished = alive & (D == 0)
        if finished.any():
            match_sr[finished] = _NO_MATCH   # neutralize: no repair, t=0
            match_rs[finished] = _NO_MATCH
        for i in np.flatnonzero(invalid.any(axis=1)):
            _repair_one(d[i], row[i], col[i], int(D[i]),
                        match_sr[i], match_rs[i], int(ks[i]), invalid[i])

        live = D > 0
        n_live = int(live.sum())
        if n_live and n_live * 2 < d.shape[0]:
            # compact the batch (fresh arrays — recorded `ids` stay valid)
            d = d[live].copy()
            row = row[live].copy()
            col = col[live].copy()
            D = D[live].copy()
            match_sr = match_sr[live].copy()
            match_rs = match_rs[live].copy()
            ks = ks[live].copy()
            ids = ids[live].copy()

    for ids_a, t_a, piece_a, alive_a in steps:
        for j in np.flatnonzero(alive_a):
            i = int(ids_a[j])
            # slice the padded piece row back to the matrix's own width so
            # pieces are bit-identical to the scalar _bna_core output
            pieces_out[i].append(
                (int(t_a[j]), piece_a[j, : int(ks_full[i])].copy()))
    return pieces_out
