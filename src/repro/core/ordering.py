"""Combinatorial primal-dual job ordering (paper Algorithm 5, Appendix A).

Builds the permutation in reverse: at step k, if the unscheduled job with
the largest T_j + rho_j exceeds the current max server load d_phi, it goes
last (its dual eta_j is raised until constraint (21b) is tight); otherwise
the job minimizing residual-weight / load-on-phi goes last (raising
lambda_{phi, N'}). Runs in O(n(n + m)) here (paper: O(n(log n + m)) with
heaps; n is small in all our workloads).

Returns the permutation sigma (front-to-back) plus the dual variables so
tests can check dual feasibility (residual weights stay >= 0, Lemma 9).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .types import Instance, Job

__all__ = ["job_order", "cached_job_order", "OrderResult",
           "job_load_vectors", "instance_signature"]


@dataclass
class OrderResult:
    order: list[int]            # job ids, first-to-last
    eta: dict[int, float]       # eta_j duals
    lambdas: list[tuple[int, int, float]]  # (server index in 0..2m-1, k, lambda value)
    residual: dict[int, float]  # residual weights at removal time (>= 0 iff dual-feasible)


def job_load_vectors(jobs: list[Job], m: int) -> np.ndarray:
    """d_i^j for i in M_S + M_R: (n, 2m) aggregate-coflow loads per job.

    Each job's row is memoized on (m, per-coflow demand bytes) in the
    backend's bounded loads LRU — untouched jobs hit across online
    replans even though ``sub_instance`` rebuilds fresh Job objects every
    arrival (the BNA cache's key discipline).  Rows are assembled into a
    fresh array, so callers may mutate the result."""
    from . import backend

    backend.loads_cache.maxsize = backend.config.loads_cache_size
    n = len(jobs)
    d = np.zeros((n, 2 * m), dtype=np.float64)
    for k, j in enumerate(jobs):
        key = (m, tuple((c.demand.shape, c.demand.dtype.str,
                         c.demand.tobytes()) for c in j.coflows))
        found, row = backend.loads_cache.lookup(key)
        if not found:
            agg = j.aggregate_demand()
            row = np.concatenate([agg.sum(axis=1), agg.sum(axis=0)]) \
                .astype(np.float64)
            backend.loads_cache.store(key, row)
        d[k] = row
    return d


def job_order(instance: Instance, loads: np.ndarray | None = None) -> OrderResult:
    """loads: optional precomputed job_load_vectors (n, 2m) float64 — the
    jit pipeline supplies these from one batched segment-sum (exact integer
    arithmetic below 2^53, so identical to the python loop)."""
    jobs = instance.jobs
    n = len(jobs)
    m = instance.m
    if n == 0:
        return OrderResult([], {}, [], {})
    d = loads if loads is not None else job_load_vectors(jobs, m)  # (n, 2m)
    key = np.array([j.T + j.release for j in jobs], dtype=np.float64)
    wres = np.array([j.weight for j in jobs], dtype=np.float64)
    alive = np.ones(n, dtype=bool)
    loads = d.sum(axis=0)                    # current d_i over N'
    sigma: list[int] = [0] * n
    eta: dict[int, float] = {}
    lambdas: list[tuple[int, int, float]] = []
    residual: dict[int, float] = {}

    for k in range(n - 1, -1, -1):
        phi = int(np.argmax(loads))
        d_phi = loads[phi]
        cand = np.flatnonzero(alive)
        j = int(cand[np.argmax(key[cand])])
        if key[j] > d_phi:
            eta[jobs[j].jid] = float(wres[j])
            residual[jobs[j].jid] = float(wres[j])
            pick = j
        else:
            loads_phi = d[cand, phi]
            with np.errstate(divide="ignore", invalid="ignore"):
                ratio = np.where(loads_phi > 0, wres[cand] / loads_phi, np.inf)
            jp = int(cand[np.argmin(ratio)])
            lam = float(wres[jp] / d[jp, phi]) if d[jp, phi] > 0 else 0.0
            lambdas.append((phi, k, lam))
            wres[cand] = wres[cand] - lam * d[cand, phi]
            residual[jobs[jp].jid] = float(wres[jp])
            pick = jp
        sigma[k] = pick
        alive[pick] = False
        loads -= d[pick]

    return OrderResult([jobs[i].jid for i in sigma], eta, lambdas, residual)


def instance_signature(instance: Instance) -> tuple:
    """Hashable exact-state key: the full input Algorithm 5 reads.

    Two instances with equal signatures get identical orders, so caching on
    it is results-identical by construction.  Demands enter as raw bytes —
    the same key discipline as the BNA cache (backend.py)."""
    return (instance.m,) + tuple(
        (j.jid, float(j.weight), int(j.release), tuple(j.edges),
         tuple(c.demand.tobytes() for c in j.coflows))
        for j in instance.jobs)


def cached_job_order(instance: Instance) -> OrderResult:
    """job_order memoized on the exact scheduling state (bounded LRU).

    Hits whenever the same state is re-planned: the G-DM vs O(m)Alg A/B
    pairs in the benchmarks, beta sweeps over one instance, and online
    reschedules whose active set only shrank with every surviving job's
    remaining demand untouched.  Returns a fresh copy so callers may
    mutate the order list safely."""
    from . import backend

    backend.order_cache.maxsize = backend.config.order_cache_size
    key = instance_signature(instance)
    found, res = backend.order_cache.lookup(key)
    if not found:
        res = job_order(instance, loads=backend.plan_order_loads(instance))
        backend.order_cache.store(key, res)
    return OrderResult(list(res.order), dict(res.eta), list(res.lambdas),
                       dict(res.residual))
