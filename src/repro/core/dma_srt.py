"""DMA-SRT and DMA-RT — rooted-tree jobs (paper Algorithm 3 and §V-B).

DMA-SRT (single rooted tree):
  1. Enumerate path sub-jobs P_j (maximal source->sink directed paths; for a
     fan-in tree, one per leaf). Draw a random delay d_p in [0, Delta_j/beta]
     per path; the start of coflow c according to p is
     t_{c,p} = d_p + sum of effective sizes of c's predecessors on p.
  2. Sweep coflow sets S_0..S_{H-1}; each coflow starts at the smallest
     t_{c,p} that is >= every parent's finish time.
  3. Schedule each coflow by BNA at its start time.
  4-5. merge_and_fix (DMA Steps 3-4).

DMA-RT (multiple rooted trees): run DMA-SRT per job (with packet-level
decomposition so each job's schedule is a sequence of timed matchings, as
DMA Step 3 requires), then delay each whole job schedule uniformly in
[0, Delta/beta], merge, and fix.
"""
from __future__ import annotations

import numpy as np

from .dma import check_delays_mode, coflow_unit, draw_delays
from .timeline import FinalSchedule, UnitSchedule, merge_and_fix
from .types import (Job, aggregate_size, children_of, coflow_layers,
                    is_rooted_forest, parents_of)

__all__ = ["path_subjobs", "srt_start_times", "dma_srt", "dma_rt"]


def path_subjobs(job: Job, max_paths: int | None = None) -> list[list[int]]:
    """Maximal directed source->sink paths. For a rooted tree this is the
    paper's P_j (|P_j| <= mu). A cap guards accidental use on dense DAGs."""
    n = job.mu
    ch = children_of(n, job.edges)
    indeg = [0] * n
    for _, b in job.edges:
        indeg[b] += 1
    sources = [i for i in range(n) if indeg[i] == 0]
    paths: list[list[int]] = []
    cap = max_paths if max_paths is not None else 4 * max(n, 1)
    stack: list[list[int]] = [[s] for s in reversed(sources)]
    while stack:
        p = stack.pop()
        u = p[-1]
        if not ch[u]:
            paths.append(p)
            if len(paths) > cap:
                raise ValueError("too many paths; DMA-SRT expects a rooted tree")
            continue
        for v in ch[u]:
            stack.append(p + [v])
    return paths


def srt_start_times(
    job: Job, beta: float, rng: np.random.Generator | None,
    require_tree: bool = True,
) -> list[int]:
    """Steps 1-2 of Algorithm 3: per-coflow start times t_c.

    If no path candidate clears the precedence bound (possible only for
    fan-out orientations / non-tree inputs), falls back to starting right
    after the parents finish — precedence always holds; only the analysis
    constant is affected (documented in DESIGN.md).

    Accepted shapes are rooted *forests* (disjoint unions of fan-in or of
    fan-out trees) — strictly wider than the paper's Definition 5 trees,
    because online rescheduling hands DMA-SRT the residual of a tree after
    completed coflows are removed, and that residual loses connectivity but
    never the degree bound.  Path enumeration stays linear on forests.

    General DAGs with require_tree=False skip path enumeration entirely
    (a dense DAG can have exponentially many maximal paths) and use the
    start-after-parents fallback for every coflow — this is what lets the
    scenario x scheduler cross-product run G-DM-RT on general-DAG
    workloads."""
    n = job.mu
    sizes = [c.D for c in job.coflows]
    if not is_rooted_forest(job):
        if require_tree:
            raise ValueError(f"job {job.jid} is not a rooted tree or forest")
        par = parents_of(n, job.edges)
        t: list[int] = [0] * n
        for layer in coflow_layers(job):
            for c in layer:
                t[c] = max((t[q] + sizes[q] for q in par[c]), default=0)
        return t
    paths = path_subjobs(job)
    delta_j = job.delta
    hi = int(delta_j // beta)
    if rng is None:
        d_p = [(i * hi) // max(len(paths) - 1, 1) if len(paths) > 1 else 0
               for i in range(len(paths))]
    else:
        d_p = [int(rng.integers(0, hi + 1)) for _ in paths]

    cand: list[list[int]] = [[] for _ in range(n)]
    for p, dp in zip(paths, d_p):
        acc = dp
        for c in p:
            cand[c].append(acc)
            acc += sizes[c]

    par = parents_of(n, job.edges)
    t: list[int] = [0] * n
    for layer in coflow_layers(job):
        for c in layer:
            bound = max((t[q] + sizes[q] for q in par[c]), default=0)
            feas = [x for x in cand[c] if x >= bound]
            t[c] = min(feas) if feas else bound
    return t


def dma_srt(
    job: Job,
    m: int,
    beta: float = 2.0,
    rng: np.random.Generator | None = None,
    origin: int = 0,
    decompose: bool = True,
    require_tree: bool = True,
    use_kernel: bool | None = None,
    delays: str = "random",
) -> FinalSchedule:
    """Single rooted-tree job; makespan O(sqrt(mu) * h(m, mu)) x OPT whp
    (Theorem 3).  delays="spread" de-randomizes the per-path delays
    (srt_start_times with rng=None)."""
    check_delays_mode(delays)
    starts = srt_start_times(job, beta,
                             None if delays == "spread" else rng,
                             require_tree=require_tree)
    units: list[UnitSchedule] = []
    for cid, c in enumerate(job.coflows):
        units.append(coflow_unit(job.jid, cid, c.demand, starts[cid]))
        units[-1].uid = cid
    return merge_and_fix(units, m, origin=origin, decompose=decompose,
                         use_kernel=use_kernel)


def dma_rt(
    jobs: list[Job],
    m: int,
    beta: float = 2.0,
    rng: np.random.Generator | None = None,
    origin: int = 0,
    decompose: bool = False,
    require_tree: bool = True,
    use_kernel: bool | None = None,
    nested: bool = True,
    delays: str = "random",
) -> FinalSchedule:
    """Multiple rooted-tree jobs; makespan O(sqrt(mu) g(m) h(m, mu)) x OPT
    whp (Theorem 4).

    nested=True is the paper's exact construction: a full DMA-SRT (with its
    own packet-level fix-up) per job, then delay/merge/fix across jobs.
    nested=False is the flat fast path: per-path start times within jobs
    (DMA-SRT Steps 1-2) + per-job delays, ONE global merge-and-fix — the
    same randomized-delay/merge principle with a single expansion; used by
    the large benchmark sweeps (tests check both are feasible and close).

    delays="spread" de-randomizes both delay layers (per-path start times
    and per-job delays)."""
    check_delays_mode(delays)
    if rng is None:
        rng = np.random.default_rng(0)
    if nested:
        units = [
            dma_srt(j, m, beta, rng, decompose=True,
                    require_tree=require_tree, delays=delays).to_unit(j.jid)
            for j in jobs
        ]
    else:
        from .timeline import EdgeIntervals
        units = []
        for j in jobs:
            starts = srt_start_times(j, beta,
                                     None if delays == "spread" else rng,
                                     require_tree=require_tree)
            parts = [coflow_unit(j.jid, cid, c.demand, starts[cid])
                     for cid, c in enumerate(j.coflows)]
            edges = EdgeIntervals.concat([p.edges for p in parts]).with_owner(j.jid)
            units.append(UnitSchedule(
                uid=j.jid, edges=edges,
                ledger=[e for p in parts for e in p.ledger]))
    delta = aggregate_size(c.demand for j in jobs for c in j.coflows)
    delay_map = draw_delays([j.jid for j in jobs], delta, beta,
                            None if delays == "spread" else rng)
    return merge_and_fix(units, m, delay_map, origin=origin,
                         decompose=decompose, use_kernel=use_kernel)
