"""Online scenario driver (paper §VII-B.2 / §VII-C.2).

Jobs arrive over time (Poisson in the paper's experiments). On every
arrival, the scheduler suspends the active plan, updates remaining demands,
and reschedules everything currently in the system — exactly the paper's
protocol. Completion times are measured from each job's arrival.

The driver is scheduler-agnostic: it consumes a Transcript (executed
transmissions) and truncates it at the next arrival with pro-rata flooring
(integer packets — a partial window never over-counts).

`scheduler` may be a plain callable, an engine Scheduler object, or a
registered scheduler name (see core/engine.py); engine.plan_online is the
stats-reporting incremental wrapper around this driver.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .result import Transcript
from .types import Coflow, Instance, Job

__all__ = ["simulate_online", "OnlineResult"]

SchedulerFn = Callable[[Instance], Transcript]


@dataclass
class OnlineResult:
    job_completions: dict[int, float]     # absolute wall-clock completion
    instance: Instance
    reschedules: int
    stats: dict = field(default_factory=dict)  # cache/wall stats (engine)

    def twct(self) -> float:
        """Sum of weighted response times (measured from arrival)."""
        total = 0.0
        for j in self.instance.jobs:
            total += j.weight * (self.job_completions[j.jid] - j.release)
        return total

    @property
    def makespan(self) -> float:
        return max(self.job_completions.values(), default=0.0)


def _resolve_scheduler(scheduler, opts: dict | None = None) -> SchedulerFn:
    if isinstance(scheduler, str):
        from .engine import make_scheduler

        return make_scheduler(scheduler, **(opts or {})).plan
    if opts:
        raise TypeError("scheduler options are only accepted with a "
                        "scheduler name, not a prebuilt scheduler")
    plan = getattr(scheduler, "plan", None)
    if callable(plan) and not isinstance(scheduler, type):
        return plan
    return scheduler


def simulate_online(instance: Instance, scheduler, **opts) -> OnlineResult:
    """Run the rescheduling protocol.  `scheduler` may be a callable, an
    engine Scheduler, or a registered name; with a name, **opts are bound
    through the registry (e.g. ``simulate_online(inst, "gdm_bf",
    exec="ledger")`` selects the backfill executor for every replan)."""
    scheduler = _resolve_scheduler(scheduler, opts)
    jobs = sorted(instance.jobs, key=lambda j: (j.release, j.jid))
    remaining: dict[tuple[int, int], np.ndarray] = {
        (j.jid, c.cid): c.demand.astype(np.int64).copy()
        for j in jobs for c in j.coflows
    }
    done: dict[tuple[int, int], float] = {}
    for j in jobs:  # coflows that are empty from the start
        for c in j.coflows:
            if remaining[(j.jid, c.cid)].sum() == 0:
                done[(j.jid, c.cid)] = float(j.release)

    arrivals = [float(j.release) for j in jobs]
    i = 0
    t = arrivals[0] if arrivals else 0.0
    active: list[Job] = []
    reschedules = 0

    while i < len(jobs) or any(
        remaining[(j.jid, c.cid)].sum() > 0 for j in active for c in j.coflows
    ):
        while i < len(jobs) and arrivals[i] <= t + 1e-9:
            active.append(jobs[i])
            i += 1
        sub, cid_maps = _sub_instance(active, remaining, done, instance.m)
        if not sub.jobs:
            if i < len(jobs):
                t = arrivals[i]
                continue
            break
        transcript = scheduler(sub)
        reschedules += 1
        t_next = arrivals[i] if i < len(jobs) else math.inf
        horizon = t_next - t
        _execute(transcript, horizon, t, cid_maps, remaining, done)
        t = t_next if i < len(jobs) else t

    job_comp: dict[int, float] = {}
    for j in instance.jobs:
        cs = [done[(j.jid, c.cid)] for c in j.coflows]
        job_comp[j.jid] = max(cs, default=float(j.release))
    return OnlineResult(job_comp, instance, reschedules)


def _sub_instance(
    active: list[Job],
    remaining: dict[tuple[int, int], np.ndarray],
    done: dict[tuple[int, int], float],
    m: int,
) -> tuple[Instance, dict[int, list[int]]]:
    """Remaining-demand instance at a rescheduling point; all jobs present
    (release 0). cid_maps[jid] maps sub-instance cid -> original cid."""
    sub_jobs: list[Job] = []
    cid_maps: dict[int, list[int]] = {}
    for j in active:
        keep = [c.cid for c in j.coflows if (j.jid, c.cid) not in done]
        if not keep:
            continue
        idx = {orig: k for k, orig in enumerate(keep)}
        coflows = [Coflow(j.jid, idx[orig], remaining[(j.jid, orig)]) for orig in keep]
        edges = [(idx[a], idx[b]) for a, b in j.edges if a in idx and b in idx]
        sub_jobs.append(Job(j.jid, coflows, edges, weight=j.weight, release=0))
        cid_maps[j.jid] = keep
    return Instance(m, sub_jobs), cid_maps


def _execute(
    transcript: Transcript,
    horizon: float,
    t0_abs: float,
    cid_maps: dict[int, list[int]],
    remaining: dict[tuple[int, int], np.ndarray],
    done: dict[tuple[int, int], float],
) -> None:
    """Apply transcript (local time) up to `horizon`; floor partial windows.

    Flooring is *cumulative* per coflow edge, not per entry: backfilled
    transcripts split a flow's units fractionally across many windows, and
    flooring each window independently can yield zero progress forever
    (0.5 + 0.5 -> 0 + 0), livelocking the reschedule loop.  Accumulating
    the fractional units and banking integer packets whenever the running
    total crosses an integer keeps partial windows conservative while
    guaranteeing progress (the 1e-6 slack absorbs the backfill sweep's
    conservation tolerance)."""
    acc: dict[tuple[int, int], np.ndarray] = {}
    banked: dict[tuple[int, int], np.ndarray] = {}
    for e in sorted(transcript.entries, key=lambda e: e.t1):
        if e.units.size == 0:
            if e.t1 <= horizon + 1e-9:
                key = (e.jid, cid_maps[e.jid][e.cid])
                done.setdefault(key, t0_abs + e.t1)
            continue
        if e.t0 >= horizon:
            continue
        if e.t1 <= horizon + 1e-9:
            amount = e.units
            end = e.t1
        else:
            frac = (horizon - e.t0) / (e.t1 - e.t0)
            amount = np.floor(e.units * frac)
            end = horizon
        key = (e.jid, cid_maps[e.jid][e.cid])
        rem = remaining[key]
        a = acc.setdefault(key, np.zeros_like(rem, dtype=np.float64))
        t = banked.setdefault(key, np.zeros_like(rem))
        a[e.srcs, e.dsts] += amount
        avail = np.floor(a[e.srcs, e.dsts] + 1e-6).astype(np.int64) \
            - t[e.srcs, e.dsts]
        take = np.minimum(np.maximum(avail, 0), rem[e.srcs, e.dsts])
        t[e.srcs, e.dsts] += take
        rem[e.srcs, e.dsts] -= take
        if rem.sum() == 0 and key not in done:
            done[key] = t0_abs + end
