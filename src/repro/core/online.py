"""Online scenario driver (paper §VII-B.2 / §VII-C.2).

Jobs arrive over time (Poisson in the paper's experiments). On every
arrival, the scheduler suspends the active plan, updates remaining demands,
and reschedules everything currently in the system — exactly the paper's
protocol. Completion times are measured from each job's arrival.

``simulate_online`` is a thin convenience driver over the stateful
:class:`~repro.core.session.SchedulerSession` (which owns the residual-
demand ledger and the cumulative-flooring executor): submit every job, let
``advance()`` drain the event loop, return the session's result.  The
historical closed batch loop is retained behind ``driver="batch"`` as the
reference comparator — the two are results-identical on every scenario x
scheduler cell (tests/test_session.py pins the full matrix) and the
``session-equivalence`` CI job pins one online_poisson shape's goldens.

`scheduler` may be a plain callable, an engine Scheduler object, or a
registered scheduler name (see core/engine.py); engine.plan_online is the
stats-reporting incremental wrapper around this driver.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .result import Transcript
from .session import SchedulerSession, execute_transcript, sub_instance
from .types import Instance, Job

__all__ = ["simulate_online", "OnlineResult"]

SchedulerFn = Callable[[Instance], Transcript]


@dataclass
class OnlineResult:
    job_completions: dict[int, float]     # absolute wall-clock completion
    instance: Instance
    reschedules: int
    stats: dict = field(default_factory=dict)  # cache/session/wall stats

    def twct(self) -> float:
        """Sum of weighted response times (measured from arrival)."""
        total = 0.0
        for j in self.instance.jobs:
            total += j.weight * (self.job_completions[j.jid] - j.release)
        return total

    @property
    def makespan(self) -> float:
        return max(self.job_completions.values(), default=0.0)


def _resolve_scheduler(scheduler, opts: dict | None = None) -> SchedulerFn:
    if isinstance(scheduler, str):
        from .engine import make_scheduler

        return make_scheduler(scheduler, **(opts or {})).plan
    if opts:
        raise TypeError("scheduler options are only accepted with a "
                        "scheduler name, not a prebuilt scheduler")
    plan = getattr(scheduler, "plan", None)
    if callable(plan) and not isinstance(scheduler, type):
        return plan
    return scheduler


def simulate_online(instance: Instance, scheduler, driver: str = "session",
                    repair: bool = True, gamma="residual",
                    **opts) -> OnlineResult:
    """Run the rescheduling protocol.  `scheduler` may be a callable, an
    engine Scheduler, or a registered name; with a name, **opts are bound
    through the registry (e.g. ``simulate_online(inst, "gdm_bf",
    exec="ledger")`` selects the backfill executor for every replan).

    driver="session" (default) drives a SchedulerSession (frontier-append
    plan repair enabled unless ``repair=False``); driver="batch" runs the
    historical closed batch loop — the results-identical reference.

    ``gamma`` is the grouping-scale policy ('residual' | 'pinned' |
    positive number — see core/session.py); both drivers implement the
    identical pinned-gamma epoch, so the bit-identity contract holds
    under pinning too."""
    if driver not in ("session", "batch"):
        raise ValueError(f"unknown driver {driver!r}; "
                         f"choose from ('session', 'batch')")
    if driver == "batch":
        return _simulate_online_batch(instance, scheduler, gamma=gamma,
                                      **opts)
    session = SchedulerSession(instance.m, scheduler, repair=repair,
                               gamma=gamma, **opts)
    for j in sorted(instance.jobs, key=lambda j: (j.release, j.jid)):
        session.submit(j)
    session.advance()
    res = session.result()
    res.instance = instance
    return res


def _simulate_online_batch(instance: Instance, scheduler, gamma="residual",
                           **opts) -> OnlineResult:
    """The historical closed batch loop (reference comparator).

    Mirrors the session's pinned-gamma epoch exactly: the pin is a pure
    function of the residual-instance sequence (one ``observe`` per
    replan), so session and batch plan every residual with the same
    gamma — the bit-identity contract survives pinning."""
    from .gdm import GammaEpoch

    epoch = GammaEpoch.from_policy(gamma)
    if epoch is None:
        scheduler = _resolve_scheduler(scheduler, opts)
    else:
        from .engine import make_scheduler, scheduler_options

        name = scheduler if isinstance(scheduler, str) \
            else getattr(scheduler, "name", None)
        try:
            gamma_ok = isinstance(name, str) and \
                "gamma" in scheduler_options(name)
        except KeyError:
            gamma_ok = False
        if not gamma_ok:
            raise ValueError(
                f"gamma={gamma!r} needs an engine scheduler taking the "
                f"'gamma' plan option (the G-DM family); got {name!r}")
        if isinstance(scheduler, str):
            sched_obj = make_scheduler(scheduler, **opts)
        elif opts:
            raise TypeError("scheduler options are only accepted with a "
                            "scheduler name, not a prebuilt scheduler")
        else:
            sched_obj = scheduler

        def scheduler(sub):
            return sched_obj.plan_full(
                sub, gamma=epoch.observe(sub.gamma())).transcript()
    jobs = sorted(instance.jobs, key=lambda j: (j.release, j.jid))
    remaining: dict[tuple[int, int], np.ndarray] = {
        (j.jid, c.cid): c.demand.astype(np.int64).copy()
        for j in jobs for c in j.coflows
    }
    done: dict[tuple[int, int], float] = {}
    for j in jobs:  # coflows that are empty from the start
        for c in j.coflows:
            if remaining[(j.jid, c.cid)].sum() == 0:
                done[(j.jid, c.cid)] = float(j.release)

    arrivals = [float(j.release) for j in jobs]
    i = 0
    t = arrivals[0] if arrivals else 0.0
    active: list[Job] = []
    reschedules = 0

    while i < len(jobs) or any(
        remaining[(j.jid, c.cid)].sum() > 0 for j in active for c in j.coflows
    ):
        while i < len(jobs) and arrivals[i] <= t + 1e-9:
            active.append(jobs[i])
            i += 1
        sub, cid_maps = sub_instance(active, remaining, done, instance.m)
        if not sub.jobs:
            if i < len(jobs):
                t = arrivals[i]
                continue
            break
        transcript = scheduler(sub)
        reschedules += 1
        t_next = arrivals[i] if i < len(jobs) else math.inf
        horizon = t_next - t
        execute_transcript(transcript, horizon, t, cid_maps, remaining, done)
        t = t_next if i < len(jobs) else t

    job_comp: dict[int, float] = {}
    for j in instance.jobs:
        cs = [done[(j.jid, c.cid)] for c in j.coflows]
        job_comp[j.jid] = max(cs, default=float(j.release))
    return OnlineResult(job_comp, instance, reschedules)
