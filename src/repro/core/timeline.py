"""Merge-and-fix timeline machinery (paper DMA Steps 3-4, via Lemma 6).

Schedules are piecewise-constant port occupancies. We represent them as
*edge intervals* — an edge (s, r) transmitting at rate 1 over [t0, t1) — the
run-length-encoded form of a sequence of timed matchings (BNA output edges
persist across consecutive pieces, so this is compact: O(nnz + m) intervals
per coflow instead of O(pieces * m)).

merge_and_fix implements exactly Lemma 6: partition time by the set of all
scheduling event times; within each interval the merged demand is constant;
expand interval I of length l_I by alpha_I (the max number of packets any
port must send/receive there) and, when a packet-level schedule is required,
run BNA on (l_I x merged counts). Precedence constraints are preserved
because expansion is order-preserving, and the expanded schedule is feasible
(BNA serves the merged demand within l_I * alpha_I exactly).

Accounting uses a *ledger*: one entry per coflow attributing its flow units
uniformly over its scheduled window; completions and online truncation read
the ledger. The ledger is exact for completion times (a coflow's BNA
finishes exactly at its window end) and a documented uniform-rate
approximation for mid-window truncation.

For exact re-execution, `FinalSchedule.coflow_intervals()` exposes the
expanded schedule as a per-coflow timed-matching decomposition: rate-1 edge
intervals attributed to their (jid, cid), a refinement of the packet-level
matchings (built lazily from the retained merged edges when the schedule
was produced with decompose=False). The packet-level backfill executor
consumes this instead of the ledger approximation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "EdgeIntervals",
    "LedgerEntry",
    "UnitSchedule",
    "FinalSchedule",
    "bna_pieces_to_edge_intervals",
    "merge_and_fix",
    "unit_from_coflow_plan",
]


@dataclass
class EdgeIntervals:
    """Struct-of-arrays: edge (s[i], r[i]) active (rate 1) over [t0[i], t1[i]),
    attributed to scheduling unit owner[i] (exact-completion accounting) and
    to its originating coflow (jid[i], cid[i]).  The owner is relative to the
    current merge level (job id inside DMA, coflow id inside DMA-SRT, ...);
    the (jid, cid) channels are global and survive every re-packaging, which
    is what lets a FinalSchedule expose its timed-matching decomposition per
    coflow (the packet-level backfill executor consumes that)."""

    t0: np.ndarray
    t1: np.ndarray
    s: np.ndarray
    r: np.ndarray
    owner: np.ndarray = None
    jid: np.ndarray = None
    cid: np.ndarray = None

    def __post_init__(self):
        if self.owner is None:
            self.owner = np.zeros_like(self.t0)
        if self.jid is None:
            self.jid = np.full_like(self.t0, -1)
        if self.cid is None:
            self.cid = np.full_like(self.t0, -1)

    @staticmethod
    def empty() -> "EdgeIntervals":
        z = np.zeros(0, dtype=np.int64)
        return EdgeIntervals(z.copy(), z.copy(), z.copy(), z.copy(), z.copy(),
                             z.copy(), z.copy())

    @staticmethod
    def concat(parts: list["EdgeIntervals"]) -> "EdgeIntervals":
        parts = [p for p in parts if p.t0.size]
        if not parts:
            return EdgeIntervals.empty()
        return EdgeIntervals(
            np.concatenate([p.t0 for p in parts]),
            np.concatenate([p.t1 for p in parts]),
            np.concatenate([p.s for p in parts]),
            np.concatenate([p.r for p in parts]),
            np.concatenate([p.owner for p in parts]),
            np.concatenate([p.jid for p in parts]),
            np.concatenate([p.cid for p in parts]),
        )

    def shifted(self, dt: int) -> "EdgeIntervals":
        return EdgeIntervals(self.t0 + dt, self.t1 + dt, self.s, self.r,
                             self.owner, self.jid, self.cid)

    def with_owner(self, uid: int) -> "EdgeIntervals":
        return EdgeIntervals(self.t0, self.t1, self.s, self.r,
                             np.full_like(self.t0, uid), self.jid, self.cid)

    @property
    def size(self) -> int:
        return int(self.t0.size)


@dataclass
class LedgerEntry:
    """Attribution: coflow (jid, cid) transmits units[k] on (srcs[k], dsts[k])
    uniformly over [t0, t1). Zero-demand coflows carry an empty entry whose
    window marks their (instantaneous) completion point."""

    jid: int
    cid: int
    t0: int
    t1: int
    srcs: np.ndarray
    dsts: np.ndarray
    units: np.ndarray


@dataclass
class UnitSchedule:
    """One schedulable unit at the current nesting level (an isolated job
    schedule for DMA; a single coflow plan inside DMA-SRT; a whole DMA-SRT
    output inside DMA-RT)."""

    uid: int
    edges: EdgeIntervals
    ledger: list[LedgerEntry]

    def span(self) -> tuple[int, int]:
        lo = [int(self.edges.t0.min())] if self.edges.size else []
        hi = [int(self.edges.t1.max())] if self.edges.size else []
        lo += [e.t0 for e in self.ledger]
        hi += [e.t1 for e in self.ledger]
        return (min(lo, default=0), max(hi, default=0))


def bna_pieces_to_edge_intervals(
    pieces: list[tuple[int, np.ndarray]], start: int, owner: int = 0,
    jid: int = -1, cid: int = -1,
) -> EdgeIntervals:
    """RLE-compress BNA (duration, matching) pieces into edge intervals."""
    t0s: list[int] = []
    t1s: list[int] = []
    ss: list[int] = []
    rs: list[int] = []
    open_edges: dict[tuple[int, int], int] = {}
    t = start
    for dur, match in pieces:
        cur = {(int(s), int(match[s])) for s in np.flatnonzero(match >= 0)}
        for e in list(open_edges):
            if e not in cur:
                t0s.append(open_edges.pop(e))
                t1s.append(t)
                ss.append(e[0])
                rs.append(e[1])
        for e in cur:
            if e not in open_edges:
                open_edges[e] = t
        t += int(dur)
    for e, et0 in open_edges.items():
        t0s.append(et0)
        t1s.append(t)
        ss.append(e[0])
        rs.append(e[1])
    n = len(t0s)
    return EdgeIntervals(
        np.asarray(t0s, dtype=np.int64),
        np.asarray(t1s, dtype=np.int64),
        np.asarray(ss, dtype=np.int64),
        np.asarray(rs, dtype=np.int64),
        np.full(n, owner, dtype=np.int64),
        np.full(n, jid, dtype=np.int64),
        np.full(n, cid, dtype=np.int64),
    )


def _coflow_entry(jid: int, cid: int, demand: np.ndarray,
                  start: int) -> LedgerEntry:
    """Ledger entry for one coflow occupying [start, start + D)."""
    from .types import effective_size

    D = effective_size(demand)
    s_idx, r_idx = np.nonzero(demand)
    return LedgerEntry(
        jid=jid, cid=cid, t0=start, t1=start + D,
        srcs=s_idx.astype(np.int64), dsts=r_idx.astype(np.int64),
        units=demand[s_idx, r_idx].astype(np.float64),
    )


def unit_from_coflow_plan(
    jid: int, cid: int, demand: np.ndarray,
    pieces: list[tuple[int, np.ndarray]], start: int,
) -> UnitSchedule:
    """UnitSchedule for one coflow scheduled by BNA starting at `start`."""
    edges = bna_pieces_to_edge_intervals(pieces, start, owner=cid,
                                         jid=jid, cid=cid)
    return UnitSchedule(uid=jid, edges=edges,
                        ledger=[_coflow_entry(jid, cid, demand, start)])


def unit_from_coflow_edges(
    jid: int, cid: int, demand: np.ndarray,
    rel: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray], start: int,
) -> UnitSchedule:
    """unit_from_coflow_plan from precomputed start-relative edge intervals
    ``(t0, t1, s, r)`` — the jit planning pipeline's cached representation
    (core/pipeline.py).  Equivalent to RLE-compressing the BNA pieces."""
    t0, t1, s, r = rel
    n = t0.size
    edges = EdgeIntervals(
        t0.astype(np.int64) + int(start),
        t1.astype(np.int64) + int(start),
        s.astype(np.int64),
        r.astype(np.int64),
        np.full(n, cid, dtype=np.int64),
        np.full(n, jid, dtype=np.int64),
        np.full(n, cid, dtype=np.int64),
    )
    return UnitSchedule(uid=jid, edges=edges,
                        ledger=[_coflow_entry(jid, cid, demand, start)])


@dataclass
class MappedEntry:
    jid: int
    cid: int
    e0: float
    e1: float
    srcs: np.ndarray
    dsts: np.ndarray
    units: np.ndarray


@dataclass
class DecompPiece:
    """Packet-level piece in expanded time: matching edges active [t0, t0+dur)."""

    t0: int
    dur: int
    srcs: np.ndarray
    dsts: np.ndarray
    mult: np.ndarray  # per-edge multiplicity of the merged count served here (==1)


@dataclass
class FinalSchedule:
    """Result of merge_and_fix: expanded (feasible) timeline + accounting."""

    m: int
    origin: int
    events: np.ndarray      # (K+1,) original event times (pre-expansion, shifted)
    alphas: np.ndarray      # (K,) max per-port packet count in each interval
    exp: np.ndarray         # (K+1,) expanded times; exp[0] == origin
    ledger: list[MappedEntry]
    decomposition: list[DecompPiece] | None = None
    exact_completion: dict[int, float] | None = None  # per unit uid (packet-exact)
    merged: EdgeIntervals | None = None  # pre-expansion merged edge intervals
    coflow_edges: EdgeIntervals | None = None  # expanded, (jid, cid)-attributed
    _coflow_completion: dict[tuple[int, int], float] | None = None

    # --- time mapping -----------------------------------------------------
    def expand_time(self, t: np.ndarray | float) -> np.ndarray | float:
        """Map original time(s) to expanded time(s); rate-1 outside events."""
        t = np.asarray(t, dtype=np.float64)
        if self.events.size == 0:
            return t + self.origin
        lo, hi = self.events[0], self.events[-1]
        out = np.interp(np.clip(t, lo, hi), self.events, self.exp)
        out = np.where(t < lo, self.exp[0] - (lo - t), out)
        out = np.where(t > hi, self.exp[-1] + (t - hi), out)
        return out if out.ndim else float(out)

    # --- accounting ---------------------------------------------------------
    def coflow_completions(self) -> dict[tuple[int, int], float]:
        if self._coflow_completion is None:
            comp: dict[tuple[int, int], float] = {}
            for e in self.ledger:
                key = (e.jid, e.cid)
                comp[key] = max(comp.get(key, 0.0), float(e.e1))
            self._coflow_completion = comp
        return self._coflow_completion

    def job_completions(self) -> dict[int, float]:
        """Per-job completions. When a packet-level decomposition was built,
        the PACKET-EXACT time of each job's last transmitted unit is used
        (the conservative ledger window-end otherwise); zero-demand jobs
        fall back to their ledger markers either way."""
        comp: dict[int, float] = {}
        for (jid, _), t in self.coflow_completions().items():
            comp[jid] = max(comp.get(jid, 0.0), t)
        if self.exact_completion:
            # zero-demand coflows have no packets; their ledger markers
            # still gate job completion (e.g. an empty sink coflow)
            zero_mark: dict[int, float] = {}
            for e in self.ledger:
                if e.units.size == 0 or e.units.sum() == 0:
                    zero_mark[e.jid] = max(zero_mark.get(e.jid, 0.0), e.e1)
            for jid, t in self.exact_completion.items():
                if jid in comp:
                    comp[jid] = max(float(t), zero_mark.get(jid, 0.0))
        return comp

    @property
    def makespan(self) -> float:
        """End of the last transmission (trailing idle excluded); packet-
        exact when a decomposition exists, ledger window-end otherwise."""
        if self.exact_completion:
            return float(max(self.exact_completion.values()))
        busy = [e.e1 for e in self.ledger if e.units.size and e.units.sum() > 0]
        if busy:
            return float(max(busy))
        return float(max((e.e1 for e in self.ledger), default=self.origin))

    @property
    def end(self) -> float:
        return float(self.exp[-1]) if self.exp.size else float(self.origin)

    # --- per-coflow timed-matching decomposition ----------------------------
    def coflow_intervals(self) -> EdgeIntervals:
        """The expanded-time edge-interval decomposition attributed per
        coflow: each row is an edge (s, r) transmitting at rate 1 over
        [t0, t1) on behalf of coflow (jid[i], cid[i]).  Rows are a refinement
        of the packet-level matching decomposition, so their union is
        capacity-feasible by construction — this is what the packet-level
        backfill executor re-executes.

        Built lazily from the retained merged edges when the schedule was
        produced with decompose=False; public `decomposition` /
        `exact_completion` accounting is left untouched in that case so plan
        metrics stay order-independent."""
        if self.coflow_edges is None:
            if self.merged is None:
                raise ValueError("coflow_intervals requires the merged edge "
                                 "intervals (schedule predates merge_and_fix)")
            _, _, self.coflow_edges = _decompose(
                self.events, self.merged, self.alphas, self.exp, self.m)
        return self.coflow_edges

    # --- expansion splicing (session plan repair) ---------------------------
    def shifted_expanded(self, dt: int) -> "FinalSchedule":
        """This schedule translated by ``dt`` on the expanded (absolute)
        clock — the whole-block reuse half of the session's group-aware plan
        repair.  Spread-mode DMA/DMA-SRT layouts are translation invariant
        (``dma(jobs, origin=o)`` equals ``dma(jobs, origin=0)`` shifted by
        ``o``), so a retained G-DM group part whose inputs are untouched can
        be slid to its new chain position instead of being recomputed.

        Pre-expansion state (``events``, ``alphas``, ``merged``) is local to
        the part and unaffected; only the absolute anchors move: ``origin``,
        ``exp``, ledger windows, and — when a packet-level decomposition was
        built — the pieces, exact completions, and per-coflow intervals."""
        dt = int(dt)
        if dt == 0:
            return self
        return FinalSchedule(
            m=self.m,
            origin=self.origin + dt,
            events=self.events,
            alphas=self.alphas,
            exp=self.exp + dt if self.exp.size else self.exp,
            ledger=[MappedEntry(e.jid, e.cid, e.e0 + dt, e.e1 + dt,
                                e.srcs, e.dsts, e.units)
                    for e in self.ledger],
            decomposition=None if self.decomposition is None else
                [DecompPiece(p.t0 + dt, p.dur, p.srcs, p.dsts, p.mult)
                 for p in self.decomposition],
            exact_completion=None if self.exact_completion is None else
                {uid: t + dt for uid, t in self.exact_completion.items()},
            merged=self.merged,
            coflow_edges=None if self.coflow_edges is None else
                self.coflow_edges.shifted(dt),
        )

    def spliced(self, tau: float, keep: set, cid_remap: dict) -> "FinalSchedule":
        """The suffix of this expansion from expanded time ``tau`` on,
        restricted to the coflows in ``keep`` (a set of ``(jid, cid)``) and
        re-labelled via ``cid_remap`` (``(jid, cid) -> new cid``) — the
        retained half of the session's frontier-append plan repair.

        Only expansion-free suffixes can be spliced: every kept coflow must
        lie entirely at or after ``tau`` and every surviving interval must
        have alpha <= 1 (the suffix is its own packet-level schedule, so the
        spliced ledger windows stay exact).  The repair path guarantees both
        by construction; a violation raises ValueError and the caller falls
        back to a full replan."""
        led: list[MappedEntry] = []
        for e in self.ledger:
            if (e.jid, e.cid) not in keep:
                continue
            if e.e0 < tau - 1e-6:
                raise ValueError("kept coflow starts before the splice point")
            led.append(MappedEntry(e.jid, cid_remap[(e.jid, e.cid)],
                                   e.e0 - tau, e.e1 - tau,
                                   e.srcs, e.dsts, e.units))
        merged = None
        events = np.zeros(0, dtype=np.float64)
        alphas = np.zeros(0, dtype=np.int64)
        exp = np.zeros(0, dtype=np.float64)
        if self.merged is not None and self.merged.size:
            mk = np.array([(int(j), int(c)) in keep
                           for j, c in zip(self.merged.jid, self.merged.cid)])
            if mk.any():
                m_ = self.merged
                # merged edges live in pre-expansion local time; map them
                # through the expansion (exact at event boundaries) so the
                # splice point — which is expanded/absolute — compares
                # correctly for parts with a non-zero origin too (G-DM
                # group parts; om_alg's single part has the identity map)
                et0 = np.round(np.asarray(self.expand_time(m_.t0[mk]),
                                          dtype=np.float64)).astype(np.int64)
                et1 = np.round(np.asarray(self.expand_time(m_.t1[mk]),
                                          dtype=np.float64)).astype(np.int64)
                if int(et0.min()) < tau - 1e-6:
                    raise ValueError("kept merged edge precedes splice point")
                itau = int(round(tau))
                cid_new = np.array(
                    [cid_remap[(int(j), int(c))]
                     for j, c in zip(m_.jid[mk], m_.cid[mk])], dtype=np.int64)
                merged = EdgeIntervals(et0 - itau, et1 - itau,
                                       m_.s[mk], m_.r[mk], m_.owner[mk],
                                       m_.jid[mk], cid_new)
                ev = np.unique(np.concatenate([merged.t0, merged.t1]))
                # numpy oracle directly: a suffix of an expansion-free
                # schedule stays expansion-free (removing edges cannot raise
                # an alpha), so this is a cheap self-check, not a dispatch-
                # worthy kernel call
                alphas = _alphas_vectorized(ev, merged, self.m)
                if (alphas > 1).any():
                    raise ValueError("spliced suffix is not expansion-free")
                events = ev.astype(np.float64)
                exp = events.copy()
        out = FinalSchedule(m=self.m, origin=0, events=events, alphas=alphas,
                            exp=exp, ledger=led, merged=merged)
        return out

    @staticmethod
    def concat_expansion_free(parts: list["FinalSchedule"],
                              m: int) -> "FinalSchedule":
        """Merge already-expanded, expansion-free schedules on a shared
        clock into one (the session's repair path compacts its retained
        suffix with this, so consecutive frontier appends stay O(parts)=2
        instead of accumulating one part per repair).  Raises ValueError if
        the union is not expansion-free — the parts were not actually
        time-disjoint per port."""
        ledger = [e for p in parts for e in p.ledger]
        ms = [p.merged for p in parts if p.merged is not None and p.merged.size]
        merged = EdgeIntervals.concat(ms) if ms else None
        events = np.zeros(0, dtype=np.float64)
        alphas = np.zeros(0, dtype=np.int64)
        exp = np.zeros(0, dtype=np.float64)
        if merged is not None:
            ev = np.unique(np.concatenate([merged.t0, merged.t1]))
            alphas = _alphas_vectorized(ev, merged, m)
            if (alphas > 1).any():
                raise ValueError("concatenated parts are not expansion-free")
            events = ev.astype(np.float64)
            exp = events.copy()
        return FinalSchedule(m=m, origin=0, events=events, alphas=alphas,
                             exp=exp, ledger=ledger, merged=merged)

    # --- nesting ------------------------------------------------------------
    def to_unit(self, uid: int) -> UnitSchedule:
        """Re-package as a UnitSchedule for use at an outer merge level
        (DMA-RT merges whole DMA-SRT schedules).  Edges are the per-coflow
        timed-matching rows, so the (jid, cid) attribution survives the
        outer merge_and_fix."""
        if self.decomposition is None:
            raise ValueError("to_unit requires decompose=True")
        edges = self.coflow_intervals().with_owner(uid)
        ledger = [LedgerEntry(e.jid, e.cid, int(round(e.e0)), int(round(e.e1)),
                              e.srcs, e.dsts, e.units) for e in self.ledger]
        return UnitSchedule(uid=uid, edges=edges, ledger=ledger)


def _alphas_vectorized(
    events: np.ndarray, edges: EdgeIntervals, m: int, chunk: int = 8192
) -> np.ndarray:
    """Per-interval alpha via chunked prefix-sum over port-count deltas.

    This is the pure-numpy oracle for the `coflow_merge` Pallas kernel: build
    (interval, port) count deltas, running-sum down the time axis, take the
    per-interval max over ports.
    """
    K = events.size - 1
    if K <= 0:
        return np.zeros(0, dtype=np.int64)
    alphas = np.zeros(K, dtype=np.int64)
    if edges.size == 0:
        return alphas
    si = np.searchsorted(events, edges.t0)
    ei = np.searchsorted(events, edges.t1)
    carry_s = np.zeros(m, dtype=np.int64)
    carry_r = np.zeros(m, dtype=np.int64)
    order_start = np.argsort(si, kind="stable")
    order_end = np.argsort(ei, kind="stable")
    ps = pe = 0
    si_sorted, ei_sorted = si[order_start], ei[order_end]
    for lo in range(0, K, chunk):
        hi = min(lo + chunk, K)
        rows = hi - lo
        ds = np.zeros((rows, m), dtype=np.int64)
        dr = np.zeros((rows, m), dtype=np.int64)
        a = ps + np.searchsorted(si_sorted[ps:], lo)
        b = ps + np.searchsorted(si_sorted[ps:], hi)
        idx = order_start[a:b]
        np.add.at(ds, (si[idx] - lo, edges.s[idx]), 1)
        np.add.at(dr, (si[idx] - lo, edges.r[idx]), 1)
        ps = b
        a = pe + np.searchsorted(ei_sorted[pe:], lo)
        b = pe + np.searchsorted(ei_sorted[pe:], hi)
        idx = order_end[a:b]
        np.add.at(ds, (ei[idx] - lo, edges.s[idx]), -1)
        np.add.at(dr, (ei[idx] - lo, edges.r[idx]), -1)
        pe = b
        cs = carry_s + np.cumsum(ds, axis=0)
        cr = carry_r + np.cumsum(dr, axis=0)
        alphas[lo:hi] = np.maximum(cs.max(axis=1), cr.max(axis=1))
        carry_s, carry_r = cs[-1], cr[-1]
    return alphas


def merge_and_fix(
    units: list[UnitSchedule],
    m: int,
    delays: dict[int, int] | None = None,
    origin: int = 0,
    decompose: bool = False,
    use_kernel: bool | None = None,
) -> FinalSchedule:
    """DMA Steps 3-4 (Lemma 6): delay, merge, and expand to feasibility.

    delays: per-uid integer delay (Step 2); default 0.
    decompose: also produce the packet-level schedule (BNA per merged
      interval) — needed for verification and for nesting into DMA-RT.
    use_kernel: alpha-computation backend override. None (default) follows
      the global backend config (REPRO_ALPHA_BACKEND / backend.config);
      True forces the coflow_merge Pallas kernel (interpret mode on CPU);
      False forces the numpy oracle.
    """
    from .backend import compute_alphas, fused_merge_fix

    delays = delays or {}
    shifted: list[EdgeIntervals] = []
    for u in units:
        dt = int(delays.get(u.uid, 0))
        shifted.append(u.edges.shifted(dt) if dt else u.edges)
    edges = EdgeIntervals.concat(shifted)

    if edges.size:
        events = np.unique(np.concatenate([edges.t0, edges.t1]))
    else:
        events = np.zeros(0, dtype=np.int64)

    force = None if use_kernel is None else ("pallas" if use_kernel else "numpy")
    fused = fused_merge_fix(events, edges, m, force=force)
    if fused is not None:
        alphas, deltas = fused
        K = alphas.size
        exp = np.concatenate([[0], np.cumsum(deltas)]).astype(np.float64)
    else:
        alphas = compute_alphas(events, edges, m, force=force)
        K = alphas.size
        lens = (events[1:] - events[:-1]) if K else np.zeros(0, dtype=np.int64)
        rates = np.maximum(alphas, 1)
        exp = np.concatenate([[0], np.cumsum(lens * rates)]).astype(np.float64)
    # anchor: relative time 0 corresponds to `origin`; the idle lead-in up
    # to the first event passes at rate 1 (delays / release waits are real)
    exp += origin + (float(events[0]) if K else 0.0)

    sched = FinalSchedule(
        m=m,
        origin=origin,
        events=events.astype(np.float64) if K else np.zeros(0),
        alphas=alphas,
        exp=exp if K else np.zeros(0),
        ledger=[],
        merged=edges,
    )

    # map ledgers through the expansion
    for u in units:
        dt = int(delays.get(u.uid, 0))
        for e in u.ledger:
            e0 = float(sched.expand_time(e.t0 + dt))
            e1 = float(sched.expand_time(e.t1 + dt))
            sched.ledger.append(MappedEntry(e.jid, e.cid, e0, e1, e.srcs, e.dsts, e.units))

    if decompose:
        sched.decomposition, sched.exact_completion, sched.coflow_edges = \
            _decompose(events, edges, alphas, exp, m)
    return sched


def _decompose(
    events: np.ndarray, edges: EdgeIntervals, alphas: np.ndarray,
    exp: np.ndarray, m: int,
) -> tuple[list[DecompPiece], dict[int, float], EdgeIntervals]:
    """Packet-level fix-up: per interval, BNA(l_I x merged counts), plus
    PACKET-EXACT per-unit completion times: within each interval, an edge's
    merged units are attributed FIFO to the contributing units (activation
    order), and a unit's completion is the end of the piece that serves its
    last packet — the quantity the paper's simulator measures, much tighter
    than the expanded-window end.

    The same FIFO walk records each served stretch as an expanded-time edge
    interval attributed to its (jid, cid) — the per-coflow timed-matching
    decomposition (FinalSchedule.coflow_intervals).  The segments tile the
    packet-level pieces exactly, so per coflow and edge their total length
    equals the coflow's demand on that edge, and at any instant the active
    segments form a matching.

    Fast path: alpha_I == 1 means the merged active edges already form a
    matching — emit directly without BNA."""
    from .bna import bna

    pieces: list[DecompPiece] = []
    completion: dict[int, float] = {}
    seg_t0: list[int] = []
    seg_t1: list[int] = []
    seg_s: list[int] = []
    seg_r: list[int] = []
    seg_own: list[int] = []
    seg_jid: list[int] = []
    seg_cid: list[int] = []

    def emit_seg(t0: int, t1: int, s: int, r: int, key3) -> None:
        if t1 > t0:
            seg_t0.append(t0)
            seg_t1.append(t1)
            seg_s.append(s)
            seg_r.append(r)
            seg_own.append(key3[0])
            seg_jid.append(key3[1])
            seg_cid.append(key3[2])

    def pack() -> EdgeIntervals:
        return EdgeIntervals(
            np.asarray(seg_t0, dtype=np.int64),
            np.asarray(seg_t1, dtype=np.int64),
            np.asarray(seg_s, dtype=np.int64),
            np.asarray(seg_r, dtype=np.int64),
            np.asarray(seg_own, dtype=np.int64),
            np.asarray(seg_jid, dtype=np.int64),
            np.asarray(seg_cid, dtype=np.int64),
        )

    if edges.size == 0:
        return pieces, completion, pack()
    K = alphas.size
    si = np.searchsorted(events, edges.t0)
    ei = np.searchsorted(events, edges.t1)
    add_at: list[list[int]] = [[] for _ in range(K + 1)]
    rem_at: list[list[int]] = [[] for _ in range(K + 1)]
    for i in range(edges.size):
        add_at[si[i]].append(i)
        rem_at[ei[i]].append(i)
    # per edge: ordered list of (activation_seq, (owner, jid, cid), mult)
    active: dict[tuple[int, int], list] = {}
    seq = 0
    for k in range(K):
        for i in rem_at[k]:
            key = (int(edges.s[i]), int(edges.r[i]))
            k3 = (int(edges.owner[i]), int(edges.jid[i]), int(edges.cid[i]))
            lst = active[key]
            for j, ent in enumerate(lst):
                if ent[1] == k3:
                    if ent[2] == 1:
                        lst.pop(j)
                    else:
                        ent[2] -= 1
                    break
            if not lst:
                del active[key]
        for i in add_at[k]:
            key = (int(edges.s[i]), int(edges.r[i]))
            k3 = (int(edges.owner[i]), int(edges.jid[i]), int(edges.cid[i]))
            lst = active.setdefault(key, [])
            for ent in lst:
                if ent[1] == k3:
                    ent[2] += 1
                    break
            else:
                lst.append([seq, k3, 1])
                seq += 1
        if not active:
            continue
        l = int(events[k + 1] - events[k])
        if l == 0:
            continue
        t_exp = int(round(exp[k]))
        a = int(alphas[k])
        srcs = np.array([s for s, _ in active], dtype=np.int64)
        dsts = np.array([r for _, r in active], dtype=np.int64)
        cnts = np.array([sum(e[2] for e in lst) for lst in active.values()],
                        dtype=np.int64)
        # FIFO queues for this interval: per edge, units in activation order
        queues = {key: [[k3, mult * l] for _, k3, mult in sorted(lst)]
                  for key, lst in active.items()}
        if a <= 1:
            pieces.append(DecompPiece(t_exp, l, srcs, dsts, np.ones_like(cnts)))
            end = float(t_exp + l)
            for key, q in queues.items():
                cursor = t_exp
                for k3, amt in q:
                    emit_seg(cursor, cursor + amt, key[0], key[1], k3)
                    cursor += amt
                    completion[k3[0]] = max(completion.get(k3[0], 0.0), end)
            continue
        dm = np.zeros((m, m), dtype=np.int64)
        dm[srcs, dsts] = cnts * l
        off = 0
        for dur, match in bna(dm):
            ss = np.flatnonzero(match >= 0)
            pieces.append(DecompPiece(t_exp + off, int(dur), ss, match[ss],
                                      np.ones(ss.size, dtype=np.int64)))
            piece_end = float(t_exp + off + int(dur))
            for s_ in ss:
                key = (int(s_), int(match[s_]))
                q = queues.get(key)
                if not q:
                    continue
                served = int(dur)
                used = 0
                while served > 0 and q:
                    k3, rem = q[0]
                    take = min(rem, served)
                    rem -= take
                    served -= take
                    emit_seg(t_exp + off + used, t_exp + off + used + take,
                             key[0], key[1], k3)
                    used += take
                    if rem == 0:
                        q.pop(0)
                    else:
                        q[0][1] = rem
                    completion[k3[0]] = max(completion.get(k3[0], 0.0),
                                            piece_end)
            off += int(dur)
        assert off == l * a, "fix-up BNA length mismatch"
    return pieces, completion, pack()
