"""Core data types for coflow-DAG scheduling (Shafiee & Ghaderi 2020).

Model (paper §II): an m x m non-blocking switch; each coflow is an m x m
integer demand matrix; each multi-stage job is a DAG over its coflows with
Starts-After edges (a -> b means a must complete before b starts).

All demands/durations are integer (paper: "file sizes of flows are integers").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Coflow",
    "Job",
    "Instance",
    "loads",
    "effective_size",
    "aggregate_size",
    "topological_order",
    "parents_of",
    "children_of",
    "coflow_layers",
    "critical_path_size",
    "is_rooted_tree",
    "is_rooted_forest",
    "validate_dag",
]


def loads(demand: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-server loads (Definition 1): d_s row sums, d_r column sums."""
    return demand.sum(axis=1), demand.sum(axis=0)


def effective_size(demand: np.ndarray) -> int:
    """Effective size D (Definition 1): max load any port must send/receive."""
    if demand.size == 0:
        return 0
    ds, dr = loads(demand)
    return int(max(ds.max(initial=0), dr.max(initial=0)))


def aggregate_size(demands: Iterable[np.ndarray]) -> int:
    """Aggregate size of a set of coflows (Definition 2): effective size of the sum."""
    total = None
    for d in demands:
        total = d.astype(np.int64, copy=True) if total is None else total + d
    if total is None:
        return 0
    return effective_size(total)


@dataclass
class Coflow:
    """A coflow: an m x m integer demand matrix, identified within its job."""

    jid: int
    cid: int
    demand: np.ndarray  # (m, m) int64

    def __post_init__(self) -> None:
        self.demand = np.asarray(self.demand, dtype=np.int64)
        if self.demand.ndim != 2 or self.demand.shape[0] != self.demand.shape[1]:
            raise ValueError(f"demand must be square, got {self.demand.shape}")
        if (self.demand < 0).any():
            raise ValueError("demands must be non-negative")

    @property
    def m(self) -> int:
        return self.demand.shape[0]

    @property
    def D(self) -> int:
        return effective_size(self.demand)


@dataclass
class Job:
    """A multi-stage job: coflows + Starts-After DAG + weight + release time."""

    jid: int
    coflows: list[Coflow]
    edges: list[tuple[int, int]]  # (a, b): coflow a precedes coflow b
    weight: float = 1.0
    release: int = 0

    def __post_init__(self) -> None:
        validate_dag(len(self.coflows), self.edges)

    @property
    def mu(self) -> int:
        return len(self.coflows)

    @property
    def m(self) -> int:
        return self.coflows[0].m if self.coflows else 0

    def aggregate_demand(self) -> np.ndarray:
        agg = np.zeros((self.m, self.m), dtype=np.int64)
        for c in self.coflows:
            agg += c.demand
        return agg

    @property
    def delta(self) -> int:
        """Aggregate size Δ_j (Definition 2)."""
        return effective_size(self.aggregate_demand())

    @property
    def T(self) -> int:
        """Critical path size T_j (Definition 3)."""
        return critical_path_size(self)

    def remap(self, jid: int) -> "Job":
        job = dataclasses.replace(self, jid=jid)
        job.coflows = [dataclasses.replace(c, jid=jid) for c in self.coflows]
        return job


@dataclass
class Instance:
    """A scheduling instance: a set of jobs over an m x m switch."""

    m: int
    jobs: list[Job]

    def __post_init__(self) -> None:
        for j in self.jobs:
            for c in j.coflows:
                if c.m != self.m:
                    raise ValueError("coflow port count mismatch with instance m")

    @property
    def n(self) -> int:
        return len(self.jobs)

    @property
    def mu(self) -> int:
        return max((j.mu for j in self.jobs), default=0)

    def delta(self) -> int:
        """Δ: aggregate size over all jobs (Definition 2)."""
        return aggregate_size(c.demand for j in self.jobs for c in j.coflows)

    def total_demand(self) -> int:
        return int(sum(int(c.demand.sum()) for j in self.jobs for c in j.coflows))

    def gamma(self) -> int:
        """γ = min positive flow size (paper §VI-B)."""
        vals = [int(c.demand[c.demand > 0].min()) for j in self.jobs for c in j.coflows
                if (c.demand > 0).any()]
        return min(vals) if vals else 1


def validate_dag(n: int, edges: Sequence[tuple[int, int]]) -> None:
    for a, b in edges:
        if not (0 <= a < n and 0 <= b < n) or a == b:
            raise ValueError(f"bad edge ({a},{b}) for {n} coflows")
    topological_order(n, edges)  # raises on cycles


def topological_order(n: int, edges: Sequence[tuple[int, int]]) -> list[int]:
    """Kahn topological sort; deterministic (smallest index first)."""
    indeg = [0] * n
    out: list[list[int]] = [[] for _ in range(n)]
    for a, b in edges:
        indeg[b] += 1
        out[a].append(b)
    import heapq

    heap = [i for i in range(n) if indeg[i] == 0]
    heapq.heapify(heap)
    order: list[int] = []
    while heap:
        u = heapq.heappop(heap)
        order.append(u)
        for v in out[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                heapq.heappush(heap, v)
    if len(order) != n:
        raise ValueError("dependency graph has a cycle")
    return order


def parents_of(n: int, edges: Sequence[tuple[int, int]]) -> list[list[int]]:
    par: list[list[int]] = [[] for _ in range(n)]
    for a, b in edges:
        par[b].append(a)
    return par


def children_of(n: int, edges: Sequence[tuple[int, int]]) -> list[list[int]]:
    ch: list[list[int]] = [[] for _ in range(n)]
    for a, b in edges:
        ch[a].append(b)
    return ch


def coflow_layers(job: Job) -> list[list[int]]:
    """Coflow sets S_0..S_{H-1} (Definition 6): S_i = nodes whose longest path
    from a source has length i."""
    n = job.mu
    par = parents_of(n, job.edges)
    order = topological_order(n, job.edges)
    depth = [0] * n
    for u in order:
        for p in par[u]:
            depth[u] = max(depth[u], depth[p] + 1)
    h = max(depth, default=-1) + 1
    layers: list[list[int]] = [[] for _ in range(h)]
    for u in range(n):
        layers[depth[u]].append(u)
    return layers


def critical_path_size(job: Job) -> int:
    """T_j (Definition 3): max over directed paths of the sum of effective sizes."""
    n = job.mu
    if n == 0:
        return 0
    par = parents_of(n, job.edges)
    order = topological_order(n, job.edges)
    sizes = [c.D for c in job.coflows]
    best = [0] * n
    for u in order:
        best[u] = sizes[u] + max((best[p] for p in par[u]), default=0)
    return max(best)


def is_rooted_forest(job: Job) -> bool:
    """True iff the DAG is a disjoint union of fan-in trees (every out-degree
    <= 1) or of fan-out trees (every in-degree <= 1).

    Strictly wider than `is_rooted_tree` (connectivity and the single-root
    requirement are dropped).  This is the class DMA-SRT's path machinery is
    actually safe on: maximal paths are one-per-source (fan-in) or
    one-per-sink (fan-out), so enumeration cannot blow up.  It matters
    online: removing completed coflows from a rooted tree preserves the
    degree bound but not connectivity, so residual sub-jobs at a
    rescheduling point are forests."""
    n = job.mu
    if n == 0:
        return False
    outdeg = [0] * n
    indeg = [0] * n
    for a, b in job.edges:
        outdeg[a] += 1
        indeg[b] += 1
    return all(d <= 1 for d in outdeg) or all(d <= 1 for d in indeg)


def is_rooted_tree(job: Job) -> bool:
    """True iff the DAG is a fan-in or fan-out rooted tree (Definition 5)."""
    n = job.mu
    if n == 0:
        return False
    if len(job.edges) != n - 1:
        return False
    outdeg = [0] * n
    indeg = [0] * n
    for a, b in job.edges:
        outdeg[a] += 1
        indeg[b] += 1
    # connectivity (undirected)
    adj: list[list[int]] = [[] for _ in range(n)]
    for a, b in job.edges:
        adj[a].append(b)
        adj[b].append(a)
    seen = [False] * n
    stack = [0]
    seen[0] = True
    cnt = 1
    while stack:
        u = stack.pop()
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                cnt += 1
                stack.append(v)
    if cnt != n:
        return False
    fan_in = all(d <= 1 for d in outdeg) and sum(1 for d in outdeg if d == 0) == 1
    fan_out = all(d <= 1 for d in indeg) and sum(1 for d in indeg if d == 0) == 1
    return fan_in or fan_out
