"""Jitted planning pipeline — the whole-instance compiled planning path.

``REPRO_PLAN_BACKEND=jit`` (``core/backend.py``) replaces the hot half of a
cold-start plan — the per-coflow BNA decomposition loop and its Python
run-length encoding — with fixed-shape, width-bucketed XLA programs:

1. **Padded instance representation.**  Every demand is support-restricted
   exactly like the python path (``bna.support_restrict``), bucketed by
   padded width w (``matching.bucket_width``), and packed into a
   ``(B_pad, w, w)`` int32 stack (B padded to the next power of two, all-zero
   dummy lanes).  Per (m, width-bucket) signature ``(B_pad, w, T_cap)`` one
   XLA program is compiled and kept in a bounded LRU (`compile_cache`,
   keyed like the BNA cache), so repeated plans — scenario sweeps, seed
   batches, online reschedules — reuse the compiled step.

2. **One compiled decomposition per bucket.**  The filled-matrix BNA runs as
   a ``lax.while_loop`` whose body is the batched step (a jnp mirror of
   ``matching.bna_step_inplace`` — same integer formulas, bit-identical) and
   a vmapped augmenting-path repair (a jittable pointer-scan reformulation
   of ``matching._augment_py``: frontiers are consumed in increasing
   receiver order with visited-skipping, so it visits the *same* receivers
   in the *same* order and produces the same matchings).  Step buffers are
   bounded by ``T_cap = pow2(max nnz + 6w + 8)`` — the python path's own
   termination guard — so shapes are static.

3. **Vectorized RLE.**  The per-step matchings come back as one
   ``(B, T_cap, w)`` stack; the edge intervals every scheduler consumes
   (``timeline.unit_from_coflow_plan``'s run-length encoding) are extracted
   with a single vectorized boundary scan over the whole bucket and cached
   per demand (`edge_cache`, same key discipline as the BNA cache).  Within
   a coflow the row order is canonical (sender, then start time) instead of
   the python path's set-iteration order; every consumer is order-
   independent within a coflow (events/alphas are counts, the FIFO
   attribution of ``timeline._decompose`` keys on (owner, jid, cid) with at
   most one row per coflow per (s, r, start), and packet backfill caps
   never bind inside a matching), so plans are bit-identical — the 9x6
   equivalence grid in ``tests/test_pipeline.py`` pins this.

4. **Jitted ordering inputs.**  The Algorithm 5 load vectors (and the
   geometric-grouping prefix sizes derived from them) come from one
   segment-sum program over the stacked demands instead of a per-job numpy
   walk; the dual loop itself stays on the host (float control flow), fed
   with bit-identical integer loads.

Everything here is *exact*: all device arithmetic is integer (int32, with a
host-side range guard that falls back to the numpy decomposition per bucket
— still bit-identical — when loads would overflow), so jit-vs-python parity
is equality, not tolerance.  The pieces produced here are stored in the
shared BNA cache: python- and jit-planned processes interoperate freely.
"""
from __future__ import annotations

import time
import warnings

import numpy as np

from . import backend as _backend
from .bna import _NO_MATCH, expand_pieces, support_restrict
from .matching import _bna_core_batch, bucket_width

__all__ = [
    "prefetch_demands",
    "coflow_edges_rel",
    "instance_load_vectors",
    "edge_cache",
    "compile_cache",
    "pipeline_stats",
    "clear_pipeline_caches",
]

_INT32_MAX = int(np.iinfo(np.int32).max)

#: demand key -> (t0, t1, s, r) int64 *relative* edge intervals (start = 0);
#: the jit-path replacement for re-running the Python RLE per plan.
edge_cache = _backend.LRUCache(_backend.config.bna_cache_size, "plan_edges")

#: (kind, *shape signature) -> AOT-compiled XLA executable.
compile_cache = _backend.LRUCache(64, "plan_compile")

# counters surfaced via backend.cache_stats()["plan"]
_counters = {"compiles": 0, "compile_s": 0.0, "batches": 0,
             "bucket_fallbacks": 0}

_warned_overflow = False


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def pipeline_stats() -> dict:
    return {"edges": edge_cache.stats(),
            "compile": {**compile_cache.stats(), **_counters}}


def clear_pipeline_caches(compiled: bool = False) -> None:
    """Drop cached edge intervals (and, optionally, compiled executables —
    kept by default: recompiling is the expensive part and executables are
    data-independent)."""
    edge_cache.clear()
    if compiled:
        compile_cache.clear()
        _counters["compiles"] = 0
        _counters["compile_s"] = 0.0
    _counters["batches"] = 0
    _counters["bucket_fallbacks"] = 0


# --------------------------------------------------------------------------
# compiled decomposition (one program per (B_pad, w, T_cap) signature)
# --------------------------------------------------------------------------

def _build_decompose(w: int, T_cap: int):
    """The jitted bucket decomposition: (d (B, w, w) int32, ks (B,) int32)
    -> (ts (B, T_cap), pieces (B, T_cap, w), D_final (B,)).

    Mirrors ``matching._bna_core_batch`` without compaction: drained lanes
    keep running as no-ops (t == 0, piece all -1, no repair), which cannot
    change any lane's own step sequence — exactly the lock-step argument
    the batched numpy path already relies on."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    I32 = jnp.int32
    BIG = I32(_INT32_MAX)

    def step(d, row, col, D, match):
        # jnp mirror of matching.bna_step_inplace (same integer formulas)
        midx = jnp.maximum(match, 0)
        dm = jnp.take_along_axis(d, midx[:, :, None], axis=2)[:, :, 0]
        real = (match != _NO_MATCH) & (dm > 0)
        t = jnp.where(real, dm, BIG).min(axis=1)
        t = jnp.minimum(t, jnp.where(~real, D[:, None] - row, BIG).min(axis=1))
        onehot = (midx[:, :, None] == jnp.arange(w, dtype=I32)[None, None, :]) \
            & real[:, :, None]
        recv = onehot.any(axis=1)
        t = jnp.minimum(t, jnp.where(~recv, D[:, None] - col, BIG).min(axis=1))
        piece = jnp.where(real, match, I32(_NO_MATCH))
        d = d - jnp.where(onehot, t[:, None, None], 0)
        row = row - t[:, None] * real
        col = col - t[:, None] * recv
        D2 = D - t
        dm2 = jnp.take_along_axis(d, midx[:, :, None], axis=2)[:, :, 0]
        colm = jnp.take_along_axis(col, midx, axis=1)
        invalid = (match != _NO_MATCH) & (dm2 == 0) \
            & ((row >= D2[:, None]) | (colm >= D2[:, None])) \
            & (D2 > 0)[:, None]
        return t, piece, d, row, col, D2, invalid

    def augment_one(do, start, d, row, col, Dv, msr, mrs, k):
        # Pointer-scan Kuhn DFS == matching._augment_py: a sender's frontier
        # is consumed in increasing receiver order skipping visited ones;
        # any admissible receiver below the pointer was already consumed
        # (hence visited), so re-scanning from the pointer sees exactly the
        # frozen frontier's unvisited remainder.  Senders are pushed at most
        # once per search (each non-start sender is reached only through its
        # unique matched receiver), so the pointer never needs resetting.
        #
        # The search loop only RECORDS the free receiver; the augmenting
        # walk runs once after it, in its own loop.  A nested walk inside
        # the search body would never terminate under vmap: batched
        # while_loops keep re-executing the body for lanes that already
        # finished (masking discards the result), and re-walking a matching
        # the augmentation already rewired follows a parent/match cycle.
        idx = jnp.arange(w, dtype=I32)

        def cond(c):
            return (c[1] > 0) & jnp.logical_not(c[6])

        def body(c):
            stack, depth, ptr, visited, parent_r, end_r, done = c
            s = stack[depth - 1]
            adm = (d[s] > 0) | ((row[s] < Dv) & (col < Dv))
            ok = (idx >= ptr[s]) & (idx < k) & jnp.logical_not(visited) & adm
            has = ok.any()
            r = jnp.argmax(ok).astype(I32)
            nxt = mrs[r]
            free = nxt == _NO_MATCH
            visited = jnp.where(has, visited.at[r].set(True), visited)
            parent_r = jnp.where(has, parent_r.at[r].set(s), parent_r)
            ptr = jnp.where(has, ptr.at[s].set(r + 1), ptr)
            push = has & jnp.logical_not(free)
            stack = jnp.where(push, stack.at[depth].set(nxt), stack)
            depth = jnp.where(has,
                              jnp.where(push, depth + 1, depth), depth - 1)
            end_r = jnp.where(has & free, r, end_r)
            done = done | (has & free)
            return stack, depth, ptr, visited, parent_r, end_r, done

        init = (jnp.zeros(w, I32).at[0].set(start),
                jnp.where(do, I32(1), I32(0)),
                jnp.zeros(w, I32),
                jnp.zeros(w, jnp.bool_),
                jnp.full(w, _NO_MATCH, I32),
                I32(_NO_MATCH), jnp.asarray(False))
        c = lax.while_loop(cond, body, init)
        parent_r, end_r, done = c[4], c[5], c[6]

        def wbody(wc):
            r_, msr_, mrs_, _ = wc
            ps = parent_r[r_]
            prev_r = msr_[ps]
            msr_ = msr_.at[ps].set(r_)
            mrs_ = mrs_.at[r_].set(ps)
            return prev_r, msr_, mrs_, ps != start

        _, msr, mrs, _ = lax.while_loop(
            lambda wc: wc[3], wbody, (end_r, msr, mrs, done))
        return msr, mrs

    augment_vm = jax.vmap(augment_one,
                          in_axes=(0, None, 0, 0, 0, 0, 0, 0, 0))

    def repair(d, row, col, D, msr, mrs, ks, need, bad):
        # matching._repair_one across lanes flagged by `need`: clear the
        # invalidated edges, then re-augment unmatched senders in order.
        badn = bad & need[:, None]
        clear_r = ((msr[:, :, None] == jnp.arange(w, dtype=I32)[None, None, :])
                   & badn[:, :, None]).any(axis=1)
        msr = jnp.where(badn, I32(_NO_MATCH), msr)
        mrs = jnp.where(clear_r, I32(_NO_MATCH), mrs)

        def aug_s(s, carry):
            msr, mrs = carry
            do = need & (s < ks) & (msr[:, s] == _NO_MATCH)
            return augment_vm(do, s.astype(I32), d, row, col, D, msr, mrs, ks)

        return lax.fori_loop(0, w, aug_s, (msr, mrs))

    def decompose(d, ks):
        B = d.shape[0]
        row = d.sum(axis=2)
        col = d.sum(axis=1)
        D = jnp.maximum(row.max(axis=1), col.max(axis=1))
        msr = jnp.full((B, w), _NO_MATCH, I32)
        mrs = jnp.full((B, w), _NO_MATCH, I32)
        msr, mrs = repair(d, row, col, D, msr, mrs, ks, D > 0,
                          jnp.zeros((B, w), jnp.bool_))
        ts0 = jnp.zeros((B, T_cap), I32)
        ps0 = jnp.full((B, T_cap, w), _NO_MATCH, I32)

        def cond(c):
            return (c[3] > 0).any() & (c[8] < T_cap)

        def body(c):
            d, row, col, D, msr, mrs, ts, pieces, i = c
            t, piece, d, row, col, D, invalid = step(d, row, col, D, msr)
            ts = ts.at[:, i].set(t)
            pieces = pieces.at[:, i, :].set(piece)
            msr, mrs = repair(d, row, col, D, msr, mrs, ks,
                              invalid.any(axis=1), invalid)
            return d, row, col, D, msr, mrs, ts, pieces, i + 1

        c = lax.while_loop(cond, body,
                           (d, row, col, D, msr, mrs, ts0, ps0, I32(0)))
        return c[6], c[7], c[3]

    return decompose


def _get_compiled(key: tuple, builder, avals) -> object:
    """AOT-compile `builder()` for the given input avals, LRU-cached on
    `key` (the compile cache is what makes repeated plans pay tracing and
    XLA compilation once per shape signature, like the BNA value cache)."""
    found, fn = compile_cache.lookup(key)
    if found:
        return fn
    import jax

    t0 = time.perf_counter()
    fn = jax.jit(builder()).lower(*avals).compile()
    _counters["compiles"] += 1
    _counters["compile_s"] += time.perf_counter() - t0
    # repro: allow(cache-key): both call sites build `key` from the exact shape parameters that determine builder and avals, so the unkeyed params cannot vary under a fixed key
    compile_cache.store(key, fn)
    return fn


# --------------------------------------------------------------------------
# vectorized RLE over the step stacks
# --------------------------------------------------------------------------

def _rle_batch(ts: np.ndarray, pieces: np.ndarray):
    """Run-length encode a whole bucket's (B, T, w) piece stack at once.

    An edge (s, piece[b, t, s]) is active during step t; boundaries where
    the receiver changes open/close intervals.  Opens and closes alternate
    per (b, s), so pairing the i-th open with the i-th close (both emitted
    in (b, s, boundary) order by np.nonzero) reconstructs the intervals.
    Returns (s, r, t0, t1, offsets) with rows of lane b in
    ``[offsets[b], offsets[b+1])``, ordered by (sender, start time)."""
    B, T, w = pieces.shape
    times = np.zeros((B, T + 1), np.int64)
    np.cumsum(ts, axis=1, dtype=np.int64, out=times[:, 1:])
    Pt = np.full((B, w, T + 2), -1, np.int32)
    Pt[:, :, 1:T + 1] = pieces.transpose(0, 2, 1)
    change = Pt[:, :, 1:] != Pt[:, :, :-1]
    bo, so, to = np.nonzero(change & (Pt[:, :, 1:] != -1))
    bc, sc, tc = np.nonzero(change & (Pt[:, :, :-1] != -1))
    r = Pt[bo, so, to + 1].astype(np.int64)
    t0 = times[bo, to]
    t1 = times[bc, tc]
    offs = np.zeros(B + 1, np.int64)
    np.cumsum(np.bincount(bo, minlength=B), out=offs[1:])
    return so.astype(np.int64), r, t0, t1, offs


def _steps_to_lists(ts: np.ndarray, pieces: np.ndarray, ks: list[int]):
    """Per-lane python (duration, match) lists from the step stacks —
    bit-identical to the numpy batch's recorded pieces (an alive lane's
    steps are exactly its prefix of positive durations)."""
    out = []
    for i, k in enumerate(ks):
        n = int(np.count_nonzero(ts[i]))
        assert bool((ts[i, :n] > 0).all()), "jit step stack not a prefix"
        out.append([(int(ts[i, j]), pieces[i, j, :k].astype(np.int64))
                    for j in range(n)])
    return out


class _BucketOverflow(Exception):
    """Bucket loads exceed int32 — decompose it on the numpy path."""


def _decompose_bucket_jit(subs: list[np.ndarray], w: int):
    """Decompose one width bucket through the compiled path; returns per
    matrix ``(pieces_restricted, (t0, t1, s, r) restricted rel-edges)``."""
    B = len(subs)
    B_pad = _pow2(B)
    nnz = max(int((s > 0).sum()) for s in subs)
    T_cap = _pow2(nnz + 6 * w + 8)
    d = np.zeros((B_pad, w, w), np.int32)
    ks = np.zeros(B_pad, np.int32)
    for i, s in enumerate(subs):
        if max(int(s.sum(axis=1).max()), int(s.sum(axis=0).max())) \
                >= _INT32_MAX:
            raise _BucketOverflow
        k = s.shape[0]
        d[i, :k, :k] = s
        ks[i] = k

    import jax

    avals = (jax.ShapeDtypeStruct((B_pad, w, w), np.int32),
             jax.ShapeDtypeStruct((B_pad,), np.int32))
    fn = _get_compiled(("bna", B_pad, w, T_cap),
                       lambda: _build_decompose(w, T_cap), avals)
    ts, pieces, D_end = (np.asarray(x) for x in fn(d, ks))
    if D_end.any():
        raise AssertionError("jitted BNA failed to terminate (bug)")
    klist = [s.shape[0] for s in subs]
    plists = _steps_to_lists(ts[:B], pieces[:B], klist)
    so, r, t0, t1, offs = _rle_batch(ts[:B], pieces[:B])
    rels = [(t0[offs[i]:offs[i + 1]], t1[offs[i]:offs[i + 1]],
             so[offs[i]:offs[i + 1]], r[offs[i]:offs[i + 1]])
            for i in range(B)]
    return list(zip(plists, rels))


def _decompose_bucket_py(subs: list[np.ndarray], w: int):
    """int32-overflow fallback: the numpy batched decomposition (the very
    code the jit path mirrors, so still bit-identical) + python RLE."""
    from .timeline import bna_pieces_to_edge_intervals

    global _warned_overflow
    if not _warned_overflow:
        _warned_overflow = True
        warnings.warn(
            "jit planning pipeline: bucket loads exceed int32; decomposing "
            "on the numpy path (results are identical)", RuntimeWarning)
    _counters["bucket_fallbacks"] += 1
    out = []
    for plist in _bna_core_batch(subs, w):
        ei = bna_pieces_to_edge_intervals(plist, 0)
        out.append((plist, (ei.t0, ei.t1, ei.s, ei.r)))
    return out


def _plan_decompositions(demands: list[np.ndarray]):
    """(pieces, rel_edges) per demand: pieces are full-m (duration, match)
    lists bit-identical to ``bna.bna``; rel_edges are (t0, t1, s, r) int64
    edge intervals of the coflow's isolated schedule anchored at 0."""
    _counters["batches"] += 1
    out_p: list = [None] * len(demands)
    out_e: list = [None] * len(demands)
    buckets: dict[int, list] = {}
    for i, dem in enumerate(demands):
        d_full = np.asarray(dem, dtype=np.int64)
        sub, rows_p, cols_p = support_restrict(d_full)
        if sub is None:
            z = np.zeros(0, np.int64)
            out_p[i] = []
            out_e[i] = (z, z.copy(), z.copy(), z.copy())
            continue
        w = bucket_width(sub.shape[0])
        buckets.setdefault(w, []).append(
            (i, sub, rows_p, cols_p, d_full.shape[0]))
    for w in sorted(buckets):
        items = buckets[w]
        subs = [it[1] for it in items]
        try:
            res = _decompose_bucket_jit(subs, w)
        except _BucketOverflow:
            res = _decompose_bucket_py(subs, w)
        for (i, _sub, rows_p, cols_p, m_full), (plist, rel) in zip(items, res):
            if rows_p is None:
                out_p[i] = plist
                out_e[i] = rel
            else:
                out_p[i] = expand_pieces(plist, rows_p, cols_p, m_full)
                t0, t1, ss, rr = rel
                out_e[i] = (t0, t1, rows_p[ss], cols_p[rr])
    return out_p, out_e


# --------------------------------------------------------------------------
# cache-facing entry points
# --------------------------------------------------------------------------

def prefetch_demands(demands) -> None:
    """Warm BOTH the shared BNA cache and the edge cache for every demand in
    one width-bucketed compiled sweep — the jit analogue of
    ``backend.prefetch_bna``, with the same batching/thrash guards."""
    cfg = _backend.config
    if not cfg.bna_batch or cfg.bna_cache_size <= 0:
        return
    ds = [np.asarray(d) for d in demands]
    if not ds:
        return
    edge_cache.maxsize = cfg.bna_cache_size
    _backend.bna_cache.maxsize = cfg.bna_cache_size
    keys = [_backend._bna_key(d) for d in ds]
    if len(set(keys)) > cfg.bna_cache_size:
        return
    miss_keys: list = []
    miss_demands: list = []
    seen: set = set()
    for key, dem in zip(keys, ds):
        if key in seen:
            continue
        seen.add(key)
        e_hit, _ = edge_cache.lookup(key)
        p_hit, _ = _backend.bna_cache.lookup(key)
        if e_hit and p_hit:
            continue
        miss_keys.append(key)
        miss_demands.append(dem)
    if not miss_demands:
        return
    pieces_list, edges_list = _plan_decompositions(miss_demands)
    for key, p, e in zip(miss_keys, pieces_list, edges_list):
        _backend.bna_cache.store(key, p)
        edge_cache.store(key, e)


def coflow_edges_rel(demand: np.ndarray):
    """(t0, t1, s, r) relative edge intervals of `demand`'s BNA schedule
    (start = 0), memoized on the BNA key.  The arrays are shared across
    callers and must be treated as read-only (like cached pieces)."""
    dem = np.asarray(demand)
    key = _backend._bna_key(dem)
    edge_cache.maxsize = _backend.config.bna_cache_size
    found, rel = edge_cache.lookup(key)
    if found:
        return rel
    pieces_list, edges_list = _plan_decompositions(
        [np.asarray(dem, np.int64)])
    rel = edges_list[0]
    edge_cache.store(key, rel)
    if not _backend.bna_cache.lookup(key)[0]:
        _backend.bna_cache.store(key, pieces_list[0])
    return rel


# --------------------------------------------------------------------------
# jitted ordering inputs (Algorithm 5 load vectors / grouping prefix sizes)
# --------------------------------------------------------------------------

def _build_loads(m: int, n_pad: int):
    import jax.numpy as jnp

    def loads(dstack, seg):
        rows = dstack.sum(axis=2)
        cols = dstack.sum(axis=1)
        out = jnp.zeros((n_pad + 1, 2 * m), jnp.int32)
        out = out.at[seg, :m].add(rows).at[seg, m:].add(cols)
        return out[:n_pad]

    return loads


def instance_load_vectors(instance) -> np.ndarray | None:
    """(n, 2m) float64 per-job aggregate load vectors — the jitted
    segment-sum mirror of ``ordering.job_load_vectors`` (integer sums, so
    values are bit-identical).  None when the instance's total demand would
    overflow int32 (callers fall back to the host path)."""
    jobs = instance.jobs
    m = instance.m
    n = len(jobs)
    if n == 0 or m == 0:
        return np.zeros((n, 2 * m), dtype=np.float64)
    if instance.total_demand() >= _INT32_MAX:
        return None
    dems = [c.demand for j in jobs for c in j.coflows]
    C = len(dems)
    if C == 0:
        return np.zeros((n, 2 * m), dtype=np.float64)
    C_pad = _pow2(C)
    n_pad = _pow2(n)
    dstack = np.zeros((C_pad, m, m), np.int32)
    seg = np.full(C_pad, n_pad, np.int32)
    i = 0
    for k, j in enumerate(jobs):
        for c in j.coflows:
            dstack[i] = c.demand
            seg[i] = k
            i += 1

    import jax

    avals = (jax.ShapeDtypeStruct((C_pad, m, m), np.int32),
             jax.ShapeDtypeStruct((C_pad,), np.int32))
    fn = _get_compiled(("loads", C_pad, m, n_pad),
                       lambda: _build_loads(m, n_pad), avals)
    return np.asarray(fn(dstack, seg))[:n].astype(np.float64)
