"""DMA — Delay-and-Merge Algorithm for general DAG jobs (paper Algorithm 2).

Step 1: per job, topologically sort its coflows and schedule them
        back-to-back, each optimally via BNA (the *isolated* schedule).
Step 2: delay each isolated schedule by an integer chosen uniformly at
        random in [0, Delta/beta], beta > 1/e.
Steps 3-4: merge the delayed schedules and expand to feasibility
        (merge_and_fix, Lemma 6).
"""
from __future__ import annotations

import numpy as np

from .backend import bna_pieces, plan_edges
from .timeline import (EdgeIntervals, FinalSchedule, UnitSchedule,
                       merge_and_fix, unit_from_coflow_edges,
                       unit_from_coflow_plan)
from .types import Coflow, Job, aggregate_size, topological_order

__all__ = ["isolated_job_unit", "draw_delays", "dma", "cached_bna",
           "coflow_unit", "check_delays_mode"]

_DELAY_MODES = ("random", "spread")


def check_delays_mode(delays: str) -> None:
    """Validate a Step 2 delay mode: "random" is the paper's randomized
    draw; "spread" is the deterministic evenly-spaced mode
    (draw_delays(rng=None), the §IV-C de-randomization stand-in) that the
    registry exposes as ``make_scheduler("gdm", delays="spread")``."""
    if delays not in _DELAY_MODES:
        raise ValueError(f"unknown delays mode {delays!r}; "
                         f"expected one of {_DELAY_MODES}")


def cached_bna(c: Coflow) -> list:
    """BNA decomposition memoized on the demand's (shape, dtype, bytes)
    (bounded LRU in backend.py): G-DM, DMA-RT, O(m)Alg, every beta point of
    a sweep, AND every online reschedule share the same isolated schedules.
    The old per-object memo missed across online reschedules because
    _sub_instance builds fresh Coflow objects each arrival; the content key
    hits whenever the remaining demand is unchanged.  The engine's
    instance-level prefetch (backend.prefetch_bna, issued by engine.plan
    and the session before the per-job walk below) warms this same cache
    through the batched bna_many, so these lookups are typically hits."""
    return bna_pieces(c.demand)


def coflow_unit(jid: int, cid: int, demand: np.ndarray,
                start: int) -> UnitSchedule:
    """UnitSchedule for one coflow, via whichever plan backend is active:
    the jit pipeline serves cached start-relative edge intervals
    (backend.plan_edges → core/pipeline.py, bit-identical to the python
    RLE); otherwise BNA pieces are fetched through cached_bna and
    RLE-compressed per call."""
    rel = plan_edges(demand)
    if rel is not None:
        return unit_from_coflow_edges(jid, cid, demand, rel, start)
    return unit_from_coflow_plan(jid, cid, demand, bna_pieces(demand), start)


def isolated_job_unit(job: Job, start: int = 0) -> UnitSchedule:
    """Step 1: feasible isolated schedule — coflows back-to-back in
    topological order, each scheduled optimally by BNA (Lemma 1)."""
    order = topological_order(job.mu, job.edges)
    t = start
    parts: list[UnitSchedule] = []
    for cid in order:
        c = job.coflows[cid]
        u = coflow_unit(job.jid, cid, c.demand, t)
        parts.append(u)
        t += c.D
    edges = EdgeIntervals.concat([p.edges for p in parts]).with_owner(job.jid)
    ledger = [e for p in parts for e in p.ledger]
    return UnitSchedule(uid=job.jid, edges=edges, ledger=ledger)


def draw_delays(
    uids: list[int], delta: int, beta: float, rng: np.random.Generator | None,
) -> dict[int, int]:
    """Step 2 delays: uniform integers in [0, Delta/beta]. rng=None selects
    the deterministic 'spread' mode (evenly spaced — a practical stand-in for
    the de-randomization of §IV-C; documented, off by default)."""
    hi = int(delta // beta)
    if rng is None:
        k = max(len(uids), 1)
        return {uid: (i * hi) // max(k - 1, 1) if k > 1 else 0
                for i, uid in enumerate(uids)}
    return {uid: int(rng.integers(0, hi + 1)) for uid in uids}


def dma(
    jobs: list[Job],
    m: int,
    beta: float = 2.0,
    rng: np.random.Generator | None = None,
    origin: int = 0,
    decompose: bool = False,
    use_kernel: bool | None = None,
    delays: str = "random",
) -> FinalSchedule:
    """Schedule a set of general-DAG jobs; makespan O(mu * g(m)) x OPT whp
    (Theorem 2).  delays="spread" selects the deterministic evenly-spaced
    Step 2 delays (see check_delays_mode)."""
    check_delays_mode(delays)
    if rng is None:
        rng = np.random.default_rng(0)
    units = [isolated_job_unit(j) for j in jobs]
    delta = aggregate_size(c.demand for j in jobs for c in j.coflows)
    delay_map = draw_delays([j.jid for j in jobs], delta, beta,
                            None if delays == "spread" else rng)
    return merge_and_fix(units, m, delay_map, origin=origin,
                         decompose=decompose, use_kernel=use_kernel)
