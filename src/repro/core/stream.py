"""Sustained-arrivals streaming driver (serving-rate framing of §VII-C.2).

``simulate_online`` measures *what* schedule quality the rescheduling
protocol achieves; this module measures whether a live
:class:`~repro.core.session.SchedulerSession` can *keep up* when jobs
arrive continuously at a calibrated load.  The pieces:

- :func:`arrival_times` — seeded Poisson or bursty two-state MMPP
  (Markov-modulated Poisson) release times, floored to the integer
  wall-clock grid exactly like ``traces.poisson_releases``.
- :func:`stream_jobs` — a heavy-tail workload built from the trace
  primitives (``sample_coflows`` widths/sizes, ``dag_edges`` precedence),
  with the arrival rate calibrated so `load` is the fraction of the
  busiest port's sustainable service rate (load 1.0 = the port-bottleneck
  lower bound on the trace makespan equals the arrival horizon).
- :class:`StreamDriver` — feeds arrivals one by one into a live session,
  timing each arrival's submit+replan wall clock (the *scheduling
  latency* a serving system quotes at p50/p95/p99).  With an
  :class:`~repro.core.session.AdmissionPolicy` attached it applies
  backpressure: while the session's windowed replan debt exceeds the
  policy budget, new arrivals are *deferred* to the next planned
  completion boundary (a clean cut of the sequential plan, where
  frontier-append repair is likely), and once the deferral queue exceeds
  ``max_pending`` they are *rejected* outright.  Deferral/reject counts
  surface in ``SessionStats``.

Without a policy the driver is pure: every arrival is submitted at its
release time, so completions and TWCT are bit-identical to
``simulate_online(..., driver="batch")`` on the same trace — the extra
per-arrival replans execute zero time before the next event and the
repair path is certified results-identical (tests/test_stream.py pins
the matrix).  Backpressure deliberately trades schedule optimality for
replan-rate stability, so policy runs are *not* batch-identical.
"""
from __future__ import annotations

import time
from bisect import insort
from dataclasses import dataclass

import numpy as np

from .session import AdmissionPolicy, SchedulerSession
from .traces import dag_edges, sample_coflows
from .types import Coflow, Job

__all__ = [
    "arrival_times",
    "stream_jobs",
    "StreamDriver",
    "StreamResult",
    "run_stream",
]

_EPS = 1e-9


# --- arrival processes ------------------------------------------------------

def arrival_times(
    n: int,
    rate: float,
    seed: int = 0,
    *,
    process: str = "poisson",
    burst: float = 8.0,
    p_enter_burst: float = 0.05,
    p_exit_burst: float = 0.25,
) -> np.ndarray:
    """`n` integer release times with mean arrival rate `rate`.

    process="poisson": i.i.d. exponential gaps (the paper's §VII-B.2
    arrival model).  process="mmpp": a two-state Markov-modulated Poisson
    process — a background state and a burst state whose rate is `burst`x
    the background rate, switching per-gap with the given probabilities;
    the two rates are solved so the *stationary* mean rate is `rate`, so
    poisson and mmpp traces carry the same long-run load and differ only
    in burstiness.  Gaps are cumulative-summed and floored to int64,
    matching ``traces.poisson_releases``.
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    if process not in ("poisson", "mmpp"):
        raise ValueError(f"unknown arrival process {process!r}; "
                         f"choose from ('poisson', 'mmpp')")
    rng = np.random.default_rng(seed + 2)
    if process == "poisson":
        gaps = rng.exponential(1.0 / rate, size=n)
    else:
        if burst <= 1.0:
            raise ValueError(f"burst ratio must be > 1, got {burst}")
        # stationary state shares: pi_bg = p_exit / (p_enter + p_exit)
        pi_bg = p_exit_burst / (p_enter_burst + p_exit_burst)
        pi_bu = 1.0 - pi_bg
        # mean gap = pi_bg / r_bg + pi_bu / (burst * r_bg) == 1 / rate
        r_bg = rate * (pi_bg + pi_bu / burst)
        r_bu = burst * r_bg
        gaps = np.empty(n, dtype=np.float64)
        in_burst = rng.random() < pi_bu       # start at stationarity
        for i in range(n):
            gaps[i] = rng.exponential(1.0 / (r_bu if in_burst else r_bg))
            p_flip = p_exit_burst if in_burst else p_enter_burst
            if rng.random() < p_flip:
                in_burst = not in_burst
    cum = np.cumsum(gaps)
    if cum.size and cum[-1] >= 2.0**53:
        # float64 stops representing integers exactly at 2^53, so the
        # floor below would no longer be the true integer release time
        raise ValueError(
            f"cumulative arrival time {cum[-1]:.3g} exceeds the float64 "
            "integer-exact range (2^53); lower n or raise rate")
    return np.floor(cum).astype(np.int64)


# --- workload builder -------------------------------------------------------

def stream_jobs(
    m: int,
    n_jobs: int,
    seed: int = 0,
    *,
    process: str = "poisson",
    load: float = 0.7,
    mu: int = 3,
    dag: str = "tree",
    width_dist: tuple = ("loguniform", 2, 12),
    size_dist: tuple = ("pareto", 1.5, 8.0),
    size_clip: tuple[int, int] = (1, 4096),
    burst: float = 8.0,
) -> list[Job]:
    """A sustained-arrivals trace: `n_jobs` jobs of `mu` heavy-tail coflows
    each (Pareto sizes by default) with `dag`-family precedence, released
    by the chosen arrival process at a rate calibrated to `load`.

    Calibration: the busiest port must move ``max_port_work`` units over
    the whole trace, so the trace cannot drain faster than that; the
    arrival horizon is stretched to ``max_port_work / load``, i.e.
    ``rate = load * n_jobs / max_port_work``.  load < 1 is sustainable,
    load > 1 provably overloads the interconnect (the backpressure
    regime).  Returns jobs sorted by release.
    """
    if n_jobs < 1:
        raise ValueError(f"n_jobs must be >= 1, got {n_jobs}")
    if load <= 0:
        raise ValueError(f"load must be positive, got {load}")
    if mu < 1:
        raise ValueError(f"mu must be >= 1, got {mu}")
    demands = sample_coflows(m, n_jobs * mu, seed, width_dist=width_dist,
                             size_dist=size_dist, size_clip=size_clip)
    rng = np.random.default_rng(seed + 1)
    jobs: list[Job] = []
    for jid in range(n_jobs):
        group = demands[jid * mu:(jid + 1) * mu]
        coflows = [Coflow(jid, k, d) for k, d in enumerate(group)]
        edges = dag_edges(len(coflows), dag, rng)
        jobs.append(Job(jid, coflows, edges, weight=1.0, release=0))

    total = np.zeros((m, m), dtype=np.int64)
    for d in demands:
        total += d
    max_port_work = int(max(total.sum(axis=1).max(), total.sum(axis=0).max()))
    rate = load * n_jobs / max(max_port_work, 1)
    times = arrival_times(n_jobs, rate, seed, process=process, burst=burst)

    import dataclasses
    released = [dataclasses.replace(j, release=int(t))
                for j, t in zip(jobs, times)]
    released.sort(key=lambda j: (j.release, j.jid))
    return released


# --- streaming driver -------------------------------------------------------

@dataclass
class StreamResult:
    """Serving-rate view of a drained stream: the OnlineResult plus the
    per-arrival scheduling latencies and admission outcome counts."""
    online: object                      # OnlineResult (avoids import cycle)
    latencies_s: np.ndarray             # one entry per *submitted* arrival
    offered: int
    admitted: int
    deferred: int
    rejected: tuple[int, ...]           # jids turned away (never submitted)
    wall_s: float                       # feed + drain wall clock

    def latency_ms(self, q: float) -> float:
        if self.latencies_s.size == 0:
            return 0.0
        return float(np.percentile(self.latencies_s, q) * 1e3)

    @property
    def p50_ms(self) -> float:
        return self.latency_ms(50)

    @property
    def p95_ms(self) -> float:
        return self.latency_ms(95)

    @property
    def p99_ms(self) -> float:
        return self.latency_ms(99)

    @property
    def jobs_per_sec(self) -> float:
        """Sustained service rate: admitted jobs per wall-clock second of
        driving the stream (submit + replan + execute bookkeeping)."""
        return self.admitted / self.wall_s if self.wall_s > 0 else 0.0

    def as_dict(self) -> dict:
        d = {
            "p50_ms": self.p50_ms,
            "p95_ms": self.p95_ms,
            "p99_ms": self.p99_ms,
            "jobs_per_sec": self.jobs_per_sec,
            "offered": self.offered,
            "admitted": self.admitted,
            "deferred": self.deferred,
            "rejected": len(self.rejected),
            "twct": self.online.twct(),
            "wall_s": self.wall_s,
        }
        d.update({f"session_{k}": v
                  for k, v in self.online.stats["session"].items()})
        return d


class StreamDriver:
    """Feed a sustained arrival trace through a live SchedulerSession.

    ``feed(job)`` advances the session to the job's release and returns
    "submitted", "deferred", or "rejected"; ``drain()`` flushes the
    deferral queue and runs the session dry; ``result()`` wraps it all in
    a :class:`StreamResult`.  Jobs must be fed in release order.
    """

    def __init__(self, m: int, scheduler="gdm", *,
                 repair: "bool | str" = True,
                 admission: AdmissionPolicy | None = None,
                 gamma: "str | int | object" = "residual", **opts):
        self.session = SchedulerSession(m, scheduler, repair=repair,
                                        admission=admission, gamma=gamma,
                                        **opts)
        self.admission = admission
        self._deferred: list[tuple[float, int, Job]] = []   # (due, jid, job)
        self._latencies: list[float] = []
        self._offered = 0
        self._rejected: list[int] = []
        self._deferred_total = 0
        self._wall = 0.0
        self._drained = False

    # -- event API -----------------------------------------------------------

    def feed(self, job: Job) -> str:
        t0 = time.perf_counter()
        try:
            return self._feed(job)
        finally:
            self._wall += time.perf_counter() - t0

    def drain(self) -> None:
        t0 = time.perf_counter()
        try:
            while self._deferred:
                due, _, job = self._deferred.pop(0)
                if due > self.session.now + _EPS:
                    self.session.advance(until=due)
                self._submit_timed(job)
            self.session.advance()
            self._drained = True
        finally:
            self._wall += time.perf_counter() - t0

    def result(self) -> StreamResult:
        if not self._drained:
            self.drain()
        online = self.session.result()
        return StreamResult(
            online=online,
            latencies_s=np.asarray(self._latencies, dtype=np.float64),
            offered=self._offered,
            admitted=len(self._latencies),
            deferred=self._deferred_total,
            rejected=tuple(self._rejected),
            wall_s=self._wall,
        )

    # -- internals -----------------------------------------------------------

    def _feed(self, job: Job) -> str:
        self._offered += 1
        release = float(job.release)
        self._flush_deferred(release)
        if release > self.session.now + _EPS:
            self.session.advance(until=release)
        if self.admission is not None and self.session.backpressure():
            if len(self._deferred) >= self.admission.max_pending:
                self._rejected.append(job.jid)
                self.session.stats.admission_rejects += 1
                return "rejected"
            insort(self._deferred, (self._next_boundary(), job.jid, job))
            self._deferred_total += 1
            self.session.stats.admission_deferred += 1
            return "deferred"
        self._submit_timed(job)
        return "submitted"

    def _submit_timed(self, job: Job) -> None:
        """Submit and immediately replan — the arrival's scheduling latency
        as a serving system would quote it."""
        t0 = time.perf_counter()
        self.session.submit(job)
        self.session.frontier()
        self._latencies.append(time.perf_counter() - t0)

    def _flush_deferred(self, upto: float) -> None:
        while self._deferred and self._deferred[0][0] <= upto + _EPS:
            due, _, job = self._deferred.pop(0)
            if due > self.session.now + _EPS:
                self.session.advance(until=due)
            self._submit_timed(job)

    def _next_boundary(self) -> float:
        """The next planned completion after `now` — a clean cut of the
        sequential plan where a deferred arrival lands as a frontier
        append (repair-friendly).  Falls back to `now` when the plan has
        no future completions."""
        fr = self.session.frontier()
        future = [c for c in fr.completions.values()
                  if c > self.session.now + _EPS]
        return min(future) if future else self.session.now


def run_stream(jobs: list[Job], m: int, scheduler="gdm", *,
               repair: "bool | str" = True,
               admission: AdmissionPolicy | None = None,
               gamma: "str | int | object" = "residual",
               **opts) -> StreamResult:
    """Feed `jobs` (sorted by release) through a fresh StreamDriver and
    drain it.  Without `admission` the completions/twct are bit-identical
    to ``simulate_online(Instance(m, jobs), scheduler, driver="batch")``
    — including under a pinned grouping scale (``gamma="pinned"``, see
    core/session.py), which both drivers derive identically from the
    residual sequence."""
    drv = StreamDriver(m, scheduler, repair=repair, admission=admission,
                       gamma=gamma, **opts)
    for j in sorted(jobs, key=lambda j: (j.release, j.jid)):
        drv.feed(j)
    drv.drain()
    return drv.result()
