"""Training substrate: AdamW + schedules, microbatched train step with
planner-ordered gradient buckets, mixed precision."""

from .optim import OptConfig, adamw_init, adamw_update  # noqa: F401
from .step import TrainState, build_train_step, init_train_state  # noqa: F401
