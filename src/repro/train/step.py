"""Train step builder: family-dispatched loss, microbatch gradient
accumulation, planner-ordered gradient buckets (the paper's coflow schedule
realized as HLO dependency chains), AdamW update.

The bucket ordering hook: gradients are grouped into buckets (per period-
stack leaf by default); `bucket_order` (from repro.dist.planner, i.e. the
G-DM permutation over the step's collectives) chains bucket i+1 behind
bucket i's reduced value with jax.lax.optimization_barrier — in SPMD this
pins the launch order of the gradient all-reduces / reduce-scatters, which
is exactly the control the paper's schedule exercises over the fabric.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import (ArchConfig, encdec_loss, init_encdec, init_lm,
                          init_vlm, lm_loss, vlm_loss)
from repro.models.sharding import shard

from .optim import OptConfig, adamw_init, adamw_update

__all__ = ["TrainState", "init_train_state", "build_train_step", "loss_for"]


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten,
    lambda aux, children: TrainState(*children))


def init_params(cfg: ArchConfig, key: jax.Array):
    if cfg.family == "encdec":
        return init_encdec(cfg, key)
    if cfg.family == "vlm":
        return init_vlm(cfg, key)
    return init_lm(cfg, key)


def init_train_state(cfg: ArchConfig, key: jax.Array) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def loss_for(cfg: ArchConfig) -> Callable:
    """Batch-dict -> scalar loss, per family. Batch layouts (see
    launch/specs.py): lm {tokens, labels}; vlm {patches, tokens, labels};
    encdec {frames, tokens, labels}."""
    if cfg.family == "encdec":
        return lambda p, b: encdec_loss(cfg, p, b["frames"], b["tokens"], b["labels"])
    if cfg.family == "vlm":
        return lambda p, b: vlm_loss(cfg, p, b["patches"], b["tokens"], b["labels"])
    return lambda p, b: lm_loss(cfg, p, b["tokens"], b["labels"])


def _apply_bucket_order(grads: Any, order: list[list[str]] | None) -> Any:
    """Chain gradient buckets in the planner's order via optimization
    barriers. `order`: list of buckets, each a list of '/'-joined leaf
    paths; unlisted leaves keep natural order (no constraint)."""
    if not order:
        return grads
    from repro.dist.partition import _path_str

    flat = {}
    leaves_with_path = jax.tree_util.tree_flatten_with_path(grads)[0]
    for path, leaf in leaves_with_path:
        flat[_path_str(path)] = leaf
    token = None
    for bucket in order:
        vals = [flat[p] for p in bucket if p in flat]
        if not vals:
            continue
        if token is not None:
            # bucket depends on the previous bucket's reduced values
            chained = jax.lax.optimization_barrier(tuple(vals) + (token,))
            vals2 = chained[:-1]
        else:
            vals2 = jax.lax.optimization_barrier(tuple(vals))
        for p, v in zip([p for p in bucket if p in flat], vals2):
            flat[p] = v
        token = jnp.zeros((), jnp.float32) + sum(
            jnp.sum(v[(0,) * v.ndim]).astype(jnp.float32) * 0 for v in vals2)
    # rebuild tree
    paths = [_path_str(p) for p, _ in leaves_with_path]
    treedef = jax.tree_util.tree_structure(grads)
    return jax.tree_util.tree_unflatten(treedef, [flat[p] for p in paths])


def build_train_step(
    cfg: ArchConfig,
    opt_cfg: OptConfig,
    micro_steps: int = 1,
    bucket_order: list[list[str]] | None = None,
    grad_compression: bool = False,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). batch leaves
    have leading dim global_batch; microbatching splits it into micro_steps
    accumulation chunks via lax.scan (compute/comm overlap window)."""
    loss_fn = loss_for(cfg)

    def compute_grads(params, batch):
        if micro_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        def split(x):
            B = x.shape[0]
            assert B % micro_steps == 0
            return x.reshape(micro_steps, B // micro_steps, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros(()), g0), micro)
        inv = 1.0 / micro_steps
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch: dict):
        loss, grads = compute_grads(state.params, batch)
        if grad_compression:
            from repro.dist.compression import compress_decompress
            grads = compress_decompress(grads)
        grads = _apply_bucket_order(grads, bucket_order)
        params, opt, stats = adamw_update(state.params, grads, state.opt, opt_cfg)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        metrics = {"loss": loss, **stats, "step": state.step + 1}
        return new_state, metrics

    return train_step
