"""AdamW with cosine schedule + linear warmup + global-norm clipping —
pure JAX, f32 moments regardless of param dtype."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_at"]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_ratio
                    + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, cfg: OptConfig):
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)

    # global-norm clip in f32
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
