"""Test-support utilities (optional-dependency shims).

`repro.testing.hypothesis_compat` re-exports hypothesis when installed and
otherwise provides a tiny deterministic fallback so the property-test
modules still collect and run meaningfully without the dependency.
"""

from . import hypothesis_compat  # noqa: F401

__all__ = ["hypothesis_compat"]
