"""Optional-import shim for `hypothesis`.

When hypothesis is installed, this module is a transparent re-export —
property tests get the real shrinking/fuzzing engine.  When it is absent
(this container does not ship it), a minimal deterministic fallback runs
each property test on a fixed pseudo-random sample of examples: much weaker
than hypothesis, but the invariants still get exercised and `pytest -x -q`
collects and passes with no extra dependency.

Usage in tests (drop-in for the hypothesis import line)::

    from repro.testing.hypothesis_compat import given, settings, strategies as st

Fallback support is intentionally tiny: `st.integers`, `st.floats`,
`st.booleans`, `st.sampled_from`, keyword-style `@given`, and
`@settings(max_examples=..., deadline=...)` (deadline ignored).  Anything
else raises immediately so a test can't silently run with wrong semantics.
"""
from __future__ import annotations

HAVE_HYPOTHESIS = True
try:
    from hypothesis import given, settings  # type: ignore # noqa: F401
    from hypothesis import strategies  # type: ignore # noqa: F401
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random
    from types import SimpleNamespace

    _FALLBACK_EXAMPLES = 10    # per test, when no @settings is given
    _MAX_EXAMPLES_CAP = 25     # keep dependency-free CI runs bounded
    _SEED = 0xC0F70

    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, lo: float, hi: float):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.uniform(self.lo, self.hi)

    class _Booleans(_Strategy):
        def example(self, rng):
            return rng.random() < 0.5

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return rng.choice(self.options)

    strategies = SimpleNamespace(
        integers=lambda min_value, max_value: _Integers(min_value, max_value),
        floats=lambda min_value, max_value: _Floats(min_value, max_value),
        booleans=lambda: _Booleans(),
        sampled_from=lambda options: _SampledFrom(options),
    )

    def given(*args, **strats):
        if args or not strats:
            raise TypeError(
                "hypothesis fallback supports keyword strategies only; "
                "install hypothesis for the full API")
        for name, s in strats.items():
            if not isinstance(s, _Strategy):
                raise TypeError(f"unsupported strategy for {name!r}; "
                                "install hypothesis for the full API")

        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                rng = random.Random(_SEED)
                for _ in range(n):
                    fn(**{k: s.example(rng) for k, s in strats.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = _FALLBACK_EXAMPLES
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def settings(max_examples: int | None = None, **_ignored):
        def deco(fn):
            if max_examples is not None and hasattr(fn, "_max_examples"):
                fn._max_examples = min(int(max_examples), _MAX_EXAMPLES_CAP)
            return fn

        return deco
