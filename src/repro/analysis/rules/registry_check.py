"""registry-consistency: inspect the *live* registries instead of source
text.  Every scheduler's declared ``options=`` must match the keyword
parameters its factory chain actually accepts (following ``**opts``
forwarding, which ``register_scheduler``'s own registration-time check
cannot see through), and every scenario builder must accept the ``m`` /
``seed`` / ``scale`` convention and declare metadata within the
documented vocabulary (bounds keys, DAG family, arrival model)."""
from __future__ import annotations

import ast
import inspect
import textwrap
from pathlib import Path

from .. import Finding, register_rule
from ._util import dotted

#: bounds keys ScenarioMeta documents as instance-checkable
_BOUND_KEYS = {"flow_min", "entry_max", "width_max", "mu_max", "n_jobs_max"}
#: keywords every scenario builder must accept (registry.py docstring)
_BUILDER_KW = ("m", "seed", "scale")


def _anchor(fn) -> tuple[str, int]:
    """(repo-relative path, lineno) of a callable's definition."""
    code = getattr(fn, "__code__", None)
    if code is None:
        return "<builtin>", 1
    p = Path(code.co_filename)
    try:
        p = p.relative_to(Path.cwd())
    except ValueError:
        pass
    return p.as_posix(), code.co_firstlineno


def _accepted_keywords(fn, _seen=None) -> set[str]:
    """Keyword-only params of `fn`, unioned through ``**opts`` forwarding:
    if the factory forwards its VAR_KEYWORD dict to another function we
    can resolve in its globals, that callee's keywords count too."""
    _seen = _seen or set()
    if fn in _seen:
        return set()
    _seen.add(fn)
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):
        return set()
    kw = {p.name for p in params if p.kind == p.KEYWORD_ONLY}
    var = next((p.name for p in params if p.kind == p.VAR_KEYWORD), None)
    if var is None:
        return kw
    try:
        tree = ast.parse(textwrap.dedent(inspect.getsource(fn)))
    except (OSError, TypeError, SyntaxError):
        return kw
    globs = getattr(fn, "__globals__", {})
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if not any(k.arg is None and isinstance(k.value, ast.Name)
                   and k.value.id == var for k in node.keywords):
            continue
        parts = dotted(node.func)
        if parts and len(parts) == 1 and parts[0] in globs:
            kw |= _accepted_keywords(globs[parts[0]], _seen)
    return kw


def _check_schedulers():
    from repro.core import engine

    for name in sorted(engine._REGISTRY):
        entry = engine._REGISTRY[name]
        path, line = _anchor(entry.factory)
        declared = set(entry.options)
        accepted = _accepted_keywords(entry.factory)
        missing = sorted(accepted - declared)
        phantom = sorted(declared - accepted)
        if missing:
            yield Finding(
                "registry-consistency", path, line,
                f"scheduler {name!r}: factory chain accepts "
                f"{missing} but options= does not declare them",
                "add them to the options tuple so make_scheduler "
                "validation matches reality")
        if phantom:
            yield Finding(
                "registry-consistency", path, line,
                f"scheduler {name!r}: options= declares {phantom} "
                "not accepted anywhere in the factory chain",
                "drop the phantom options or add the parameters")


def _check_scenarios():
    from repro.scenarios import registry as sreg
    from repro.scenarios import zoo  # noqa: F401  (import registers)

    for name in sreg.names():
        scen = sreg.get(name)
        path, line = _anchor(scen.builder)
        try:
            params = inspect.signature(scen.builder).parameters
        except (TypeError, ValueError):
            continue
        has_var = any(p.kind == p.VAR_KEYWORD for p in params.values())
        for req in _BUILDER_KW:
            p = params.get(req)
            ok = has_var or (p is not None and p.kind in
                             (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD))
            if not ok:
                yield Finding(
                    "registry-consistency", path, line,
                    f"scenario {name!r}: builder does not accept the "
                    f"registry-convention keyword {req!r}",
                    "every scenario builder takes m=None, seed=0, "
                    "scale=1.0 (scenarios/registry.py docstring)")
        try:
            built = scen.build(seed=0, scale=0.05)
        except Exception as exc:  # build failure IS the inconsistency
            yield Finding(
                "registry-consistency", path, line,
                f"scenario {name!r}: build(seed=0, scale=0.05) raised "
                f"{type(exc).__name__}: {exc}",
                "registered scenarios must build at small scales for "
                "tests and fast benchmarks")
            continue
        meta = built.meta
        bad = sorted(set(meta.bounds) - _BOUND_KEYS)
        if bad:
            yield Finding(
                "registry-consistency", path, line,
                f"scenario {name!r}: metadata bounds keys {bad} are not "
                f"instance-checkable (known: {sorted(_BOUND_KEYS)})",
                "check_bounds silently ignores unknown keys — fix the "
                "key name or extend ScenarioMeta's documented set")
        if meta.dag_family not in sreg.DAG_FAMILIES:
            yield Finding(
                "registry-consistency", path, line,
                f"scenario {name!r}: dag_family {meta.dag_family!r} not "
                f"in {sreg.DAG_FAMILIES}", "fix the metadata")
        if meta.arrival not in sreg.ARRIVALS:
            yield Finding(
                "registry-consistency", path, line,
                f"scenario {name!r}: arrival {meta.arrival!r} not in "
                f"{sreg.ARRIVALS}", "fix the metadata")


@register_rule("registry-consistency",
               "declared scheduler options= match the factory chain's "
               "real keywords (through **opts); scenario builders honor "
               "the m/seed/scale convention with valid metadata",
               scope="project")
def _registry_consistency():
    yield from _check_schedulers()
    yield from _check_scenarios()
