"""frozen-core-types: ``Instance`` (core/types.py), ``Transcript``
(core/result.py), and ``FinalSchedule`` (core/timeline.py) are the
currency the equivalence matrix compares bit-for-bit — once constructed
they are read-only everywhere except their defining modules (which own
legitimate in-place construction like ``sched.ledger.append``)."""
from __future__ import annotations

import ast

from .. import FileContext, register_rule
from ._util import dotted, func_scopes, iter_scope, param_names

_FROZEN = {
    "Instance": "repro/core/types.py",
    "Transcript": "repro/core/result.py",
    "FinalSchedule": "repro/core/timeline.py",
}

_MUTATORS = {"append", "extend", "insert", "remove", "pop", "clear",
             "sort", "reverse", "update", "setdefault", "add", "discard"}

_HINT = ("treat core result types as immutable outside their defining "
         "module: build a new instance (dataclasses.replace) or do the "
         "mutation where the type is defined")


def _ann_type(node: ast.AST | None) -> str | None:
    """Frozen-type name mentioned in an annotation, if any."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        for t in _FROZEN:
            if t in node.value:
                return t
    for n in ast.walk(node):
        nm = None
        if isinstance(n, ast.Name):
            nm = n.id
        elif isinstance(n, ast.Attribute):
            nm = n.attr
        if nm in _FROZEN:
            return nm
    return None


def _tracked_in(scope: ast.AST, exempt: set[str]) -> dict[str, str]:
    """var name -> frozen type for this scope (constructor calls and
    annotations), skipping types whose defining module this file is."""
    tracked: dict[str, str] = {}

    def note(name: str, typ: str | None):
        if typ and typ not in exempt:
            tracked[name] = typ

    if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
        a = scope.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            note(p.arg, _ann_type(p.annotation))
    for node in [scope, *iter_scope(scope)]:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                isinstance(node.value, ast.Call):
            parts = dotted(node.value.func)
            if parts and parts[-1] in _FROZEN:
                note(node.targets[0].id, parts[-1])
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            note(node.target.id, _ann_type(node.annotation))
    return tracked


@register_rule("frozen-core-types",
               "no attribute assignment or in-place mutation on Instance/"
               "Transcript/FinalSchedule outside their defining modules")
def _frozen_core_types(ctx: FileContext):
    if ctx.in_testing():
        return
    exempt = {t for t, mod in _FROZEN.items() if ctx.rel.endswith(mod)}
    if len(exempt) == len(_FROZEN):
        return
    scopes: list[ast.AST] = [ctx.tree, *func_scopes(ctx.tree)]
    for scope in scopes:
        tracked = _tracked_in(scope, exempt)
        if not tracked:
            continue
        yield from _check_scope(ctx, scope, tracked)


def _check_scope(ctx, scope, tracked):
    for node in [scope, *iter_scope(scope)]:
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                root = t
                while isinstance(root, (ast.Attribute, ast.Subscript)):
                    root = root.value
                if isinstance(root, ast.Name) and root.id in tracked \
                        and root is not t:
                    yield ctx.finding(
                        "frozen-core-types", node,
                        f"assignment into frozen {tracked[root.id]} "
                        f"instance {root.id!r}", _HINT)
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS:
            parts = dotted(node.func)
            if parts and len(parts) >= 3 and parts[0] in tracked:
                yield ctx.finding(
                    "frozen-core-types", node,
                    f"in-place {parts[-1]}() on frozen "
                    f"{tracked[parts[0]]} instance {parts[0]!r}", _HINT)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                root = t
                while isinstance(root, (ast.Attribute, ast.Subscript)):
                    root = root.value
                if isinstance(root, ast.Name) and root.id in tracked \
                        and root is not t:
                    yield ctx.finding(
                        "frozen-core-types", node,
                        f"del on frozen {tracked[root.id]} instance "
                        f"{root.id!r}", _HINT)
