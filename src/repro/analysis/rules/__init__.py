"""Rule modules — importing this package registers every rule (the same
import-time registration the scheduler and scenario registries use).
Syntactic (file/project scope) rules first, then the program-scope
dataflow rules built on :mod:`repro.analysis.flow`."""
from . import dispatch     # noqa: F401  backend-dispatch
from . import frozen       # noqa: F401  frozen-core-types
from . import overflow     # noqa: F401  overflow-guard
from . import pragma_rule  # noqa: F401  pragma-discipline
from . import purity       # noqa: F401  jit-purity
from . import registry_check  # noqa: F401  registry-consistency
from . import rng          # noqa: F401  rng-discipline

from . import cache_key      # noqa: F401  cache-key (dataflow)
from . import overflow_range  # noqa: F401  overflow-range (dataflow)
from . import tracer_taint   # noqa: F401  tracer-taint (dataflow)
