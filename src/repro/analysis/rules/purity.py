"""jit-purity: a function handed to ``jax.jit`` / ``lax.while_loop`` /
``lax.scan`` / ``lax.fori_loop`` / ``jax.vmap`` runs as a traced program —
host-side numpy calls freeze trace-time values, prints fire once per
trace (not per step), closed-over mutation desynchronizes replays, and
``if tracer:`` raises ConcretizationTypeError only on the shapes that
reach it.  The jitted planning pipeline's bit-identity to the python path
(``core/pipeline.py``) depends on every staged body being pure."""
from __future__ import annotations

import ast

from .. import FileContext, register_rule
from ._util import import_aliases, iter_scope, local_names, param_names, \
    resolve

_JIT_ENTRY = {"jax.jit", "jax.vmap", "jax.pmap", "jax.checkpoint",
              "jax.lax.while_loop", "jax.lax.scan", "jax.lax.fori_loop",
              "jax.lax.map", "jax.lax.cond", "jax.lax.switch"}

# host-only numpy attributes that are pure trace-time constants — calling
# them inside a jitted body is deliberate staging, not a leak
_PURE_NP = {"iinfo", "finfo", "dtype"}


def _jitted_functions(tree, aliases):
    """(node, via) for every FunctionDef/Lambda staged into a jit entry."""
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)}
    out: dict[int, tuple[ast.AST, str]] = {}

    def add(node, via):
        out.setdefault(id(node), (node, via))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            full = resolve(node.func, aliases)
            if full in _JIT_ENTRY:
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Lambda):
                        add(arg, full)
                    elif isinstance(arg, ast.Name) and arg.id in defs:
                        add(defs[arg.id], full)
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                full = resolve(target, aliases)
                if full in _JIT_ENTRY:
                    add(node, full or "jax.jit")
                elif full in ("functools.partial", "partial") and \
                        isinstance(dec, ast.Call):
                    if any(resolve(a, aliases) in _JIT_ENTRY
                           for a in dec.args):
                        add(node, "jax.jit")
    return out.values()


@register_rule("jit-purity",
               "functions staged into jax.jit/lax.while_loop/lax.scan/"
               "jax.vmap must not call numpy, print, mutate closed-over "
               "state, or branch on tracer truthiness")
def _jit_purity(ctx: FileContext):
    if not ctx.in_core() or ctx.in_testing():
        return
    aliases = import_aliases(ctx.tree)
    for fn, via in _jitted_functions(ctx.tree, aliases):
        name = getattr(fn, "name", "<lambda>")
        locs = local_names(fn)
        params = param_names(fn)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in [stmt, *iter_scope(stmt)]:
                yield from _check_node(ctx, node, name, via, locs, params,
                                       aliases)


def _check_node(ctx, node, name, via, locs, params, aliases):
    if isinstance(node, ast.Call):
        full = resolve(node.func, aliases)
        if full and full.split(".")[0] == "numpy":
            attr = full.split(".")[-1]
            if attr not in _PURE_NP:
                yield ctx.finding(
                    "jit-purity", node,
                    f"{name}() is staged into {via} but calls host "
                    f"numpy ({full})",
                    "use jnp/lax inside jitted bodies; host numpy freezes "
                    "trace-time values")
        elif full == "print":
            yield ctx.finding(
                "jit-purity", node,
                f"{name}() is staged into {via} but calls print()",
                "use jax.debug.print, or log outside the jitted body")
    elif isinstance(node, (ast.Global, ast.Nonlocal)):
        yield ctx.finding(
            "jit-purity", node,
            f"{name}() is staged into {via} but declares "
            f"{'global' if isinstance(node, ast.Global) else 'nonlocal'} "
            f"{', '.join(node.names)}",
            "thread state through the carry instead of mutating closures")
    elif isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            root = t
            while isinstance(root, (ast.Attribute, ast.Subscript)):
                root = root.value
            if isinstance(root, ast.Name) and root.id not in locs \
                    and root is not t:
                yield ctx.finding(
                    "jit-purity", node,
                    f"{name}() is staged into {via} but mutates "
                    f"closed-over state ({root.id})",
                    "return updated values through the carry; jitted "
                    "bodies must be pure")
    elif isinstance(node, (ast.If, ast.While)):
        test = node.test
        bare = test.id if isinstance(test, ast.Name) else (
            test.operand.id if isinstance(test, ast.UnaryOp)
            and isinstance(test.op, ast.Not)
            and isinstance(test.operand, ast.Name) else None)
        if bare is not None and bare in params:
            yield ctx.finding(
                "jit-purity", node,
                f"{name}() is staged into {via} but branches on the "
                f"truthiness of traced argument {bare!r}",
                "use lax.cond/jnp.where, or mark the argument static")
