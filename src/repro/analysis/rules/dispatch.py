"""backend-dispatch: kernel implementations are reached only through the
``core/backend.py`` dispatch layer (``REPRO_ALPHA_BACKEND`` /
``REPRO_BNA_BACKEND`` / ``REPRO_PLAN_BACKEND``) so every call site gets
the guard + numpy-fallback + cache behaviour for free.  Direct
``repro.kernels`` imports are allowed only in the dispatch layer itself,
the jitted pipeline, the kernel packages, tests, and benchmarks."""
from __future__ import annotations

import ast

from .. import FileContext, register_rule

_ALLOWED_FILES = ("repro/core/backend.py", "repro/core/pipeline.py")

_HINT = ("call through repro.core.backend (or repro.core dispatch wrappers); "
         "if this site IS the resolved dispatch target, annotate it with "
         "`# repro: allow(backend-dispatch): <one-line why>`")


def _allowed(ctx: FileContext) -> bool:
    return (any(ctx.rel.endswith(f) for f in _ALLOWED_FILES)
            or ctx.in_kernels() or ctx.in_testing() or ctx.in_benchmarks())


@register_rule("backend-dispatch",
               "repro.kernels.* imported only via core/backend.py dispatch "
               "(plus pipeline, kernel packages, tests, benchmarks)")
def _backend_dispatch(ctx: FileContext):
    if _allowed(ctx):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.kernels" or \
                        a.name.startswith("repro.kernels."):
                    yield ctx.finding(
                        "backend-dispatch", node,
                        f"direct import of {a.name} bypasses backend "
                        "dispatch", _HINT)
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            if mod == "repro.kernels" or mod.startswith("repro.kernels."):
                names = ", ".join(a.name for a in node.names)
                yield ctx.finding(
                    "backend-dispatch", node,
                    f"direct import of {names} from {mod} bypasses backend "
                    "dispatch", _HINT)
