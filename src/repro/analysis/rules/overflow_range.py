"""overflow-range: *prove* each Pallas launch's int32 index space bounded.

The file-scope ``overflow-guard`` rule checks a guard exists; this
program-scope rule checks the guard is *sufficient*.  Every top-level
function in a kernel ``ops.py`` is run through the interval engine
(:class:`repro.analysis.flow.intervals.FlowInterp`): at each call that
resolves to a kernel implementation module (``repro.kernels.<k>.<impl>``
with ``<impl>`` neither ``ops`` nor ``ref`` — the ``*_padded`` Pallas
entries), every array operand's element count must be provably
``<= np.iinfo(np.int32).max`` on every path reaching the launch — by a
concrete interval bound, by a dominating guard on the same canonical
count expression, or by factor-cover of a guard-bounded product.
Anything unproven is reported with the symbolic count expression, which
is the engine saying "this is the operand a crafted input can overflow".
"""
from __future__ import annotations

import ast
import re

from .. import ProgramContext, register_rule
from ..flow.intervals import (AVal, FlowInterp, I32_MAX, count_expr_str,
                              prove_count)
from ._util import dotted

_OPS_RE = re.compile(r"repro/kernels/[^/]+/ops\.py$")
_HINT = ("bound the padded element count of every launch operand before "
         "launching — raise or fall back to the ref path past "
         "np.iinfo(np.int32).max, and validate input shapes "
         "(`if b.shape != (B, S, G, N): raise`) so one guard covers "
         "operands whose dims the guard expression never mentions")


def _is_launch(fqn: str | None, index) -> bool:
    """Does `fqn` name a function in a kernel implementation module?"""
    if not fqn or not fqn.startswith("repro.kernels."):
        return False
    owner, tail = index.split(fqn)
    if owner is None or not tail or "." in tail:
        return False
    parts = owner.name.split(".")
    return len(parts) == 4 and parts[-1] not in ("ops", "ref")


@register_rule("overflow-range",
               "interval engine must prove every Pallas launch operand's "
               "element count fits int32 on every path",
               scope="program")
def _overflow_range(ctx: ProgramContext):
    index = ctx.index
    for fc in ctx.files:
        if not _OPS_RE.search(fc.rel):
            continue
        mi = index.by_rel.get(fc.rel)
        if mi is None:
            continue
        findings: dict[tuple, tuple] = {}   # (line, argpos) -> finding args

        def on_call(node, env, args, kwargs, mi=mi, findings=findings):
            parts = dotted(node.func)
            if parts is None:
                return
            fqn = index.resolve(mi, ".".join(parts))
            if not _is_launch(fqn, index):
                return
            callee = parts[-1]
            for pos, val in enumerate(
                    list(args) + [kwargs[k] for k in sorted(kwargs)]):
                if not isinstance(val, AVal):
                    continue
                if prove_count(val, env, I32_MAX):
                    # proven on this path; an earlier path may have failed
                    # — keep that failure (must hold on EVERY path)
                    continue
                key = (node.lineno, pos)
                findings.setdefault(key, (
                    node,
                    f"cannot prove operand {pos} of {callee}() fits "
                    f"int32: element count {count_expr_str(val, env)} "
                    f"is unbounded on some path"))

        interp = FlowInterp(index, mi, on_call=on_call)
        for stmt in mi.ctx.tree.body:
            if isinstance(stmt, ast.FunctionDef):
                try:
                    interp.run_function(stmt)
                except Exception:
                    pass
        for (line, _pos), (node, msg) in sorted(findings.items()):
            yield fc.finding("overflow-range", node, msg, _HINT)
