"""Shared AST helpers for the rules: import-alias resolution and dotted
attribute-chain flattening, so checks can match ``np.random.seed`` no
matter how numpy was imported."""
from __future__ import annotations

import ast
from typing import Iterator


def import_aliases(tree: ast.AST) -> dict[str, str]:
    """Local binding -> dotted module/object path for every import in the
    module (``import numpy as np`` -> {"np": "numpy"}; ``from numpy import
    random as nr`` -> {"nr": "numpy.random"}; relative imports are prefixed
    with one dot per level)."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom):
            base = "." * node.level + (node.module or "")
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = (f"{base}.{a.name}" if base
                                               else a.name)
    return aliases


def dotted(node: ast.AST) -> list[str] | None:
    """["np", "random", "seed"] for the expression ``np.random.seed``;
    None when the chain is not rooted in a plain Name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def resolve(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Alias-expanded dotted name of an expression, e.g. ``np.random.seed``
    -> "numpy.random.seed" under ``import numpy as np``."""
    parts = dotted(node)
    if parts is None:
        return None
    head = aliases.get(parts[0], parts[0])
    return ".".join([head] + parts[1:])


_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)


def iter_scope(node: ast.AST) -> Iterator[ast.AST]:
    """Walk `node`'s subtree without descending into nested function/class
    scopes (the nested scopes are analyzed separately)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, _SCOPE_NODES):
            continue
        yield child
        yield from iter_scope(child)


def func_scopes(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    """Every (async) function definition in the module, at any nesting."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def param_names(fn: ast.FunctionDef | ast.Lambda) -> set[str]:
    a = fn.args
    names = {p.arg for p in
             (a.posonlyargs + a.args + a.kwonlyargs)}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


def local_names(fn: ast.FunctionDef | ast.Lambda) -> set[str]:
    """Params plus every plain-Name binding inside the function (at any
    nesting — good enough for "is this base object local" checks)."""
    names = param_names(fn)
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(
                    node.ctx, (ast.Store, ast.Del)):
                names.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                names |= param_names(node)
            elif isinstance(node, ast.comprehension):
                for t in ast.walk(node.target):
                    if isinstance(t, ast.Name):
                        names.add(t.id)
    return names
