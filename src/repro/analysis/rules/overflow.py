"""overflow-guard: the syntactic half of the int32 launch contract.

Every kernel ``ops.py`` lowers to Pallas programs with int32
index/accumulator arithmetic (TPU-native), so each must bound the
element/index space against ``np.iinfo(np.int32).max`` before launching
and either fall back to the numpy/jnp reference (the ``merge_fix``
pattern) or raise loudly (the ``bna_step`` pattern) — never wrap
silently.

This rule is deliberately shallow — "a sentinel-comparing guard with an
escape exists" — and is kept as the fast, fixture-friendly first line.
*Sufficiency* (does the guard dominate every launch, does it cover every
operand's element count on every path) is proven by the program-scope
``overflow-range`` rule in :mod:`repro.analysis.rules.overflow_range`,
which runs the interval engine over the same files; a file can pass this
rule and still fail ``overflow-range``, and that is the designed split.
"""
from __future__ import annotations

import ast
import re

from .. import FileContext, register_rule

_SENTINEL_NAME = re.compile(r"_?I(?:NT)?_?32_?MAX", re.IGNORECASE)
_I32_MAX = 2**31 - 1

_HINT = ("compare the padded element/index count against "
         "np.iinfo(np.int32).max and fall back to the ref implementation "
         "(kernels/merge_fix/ops.py) or raise (kernels/bna_step/ops.py); "
         "overflow-range then proves the bound covers every launch "
         "operand")


def _mentions_sentinel(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and _SENTINEL_NAME.search(n.id):
            return True
        if isinstance(n, ast.Attribute) and _SENTINEL_NAME.search(n.attr):
            return True
        if isinstance(n, ast.Constant) and n.value == _I32_MAX:
            return True
        if isinstance(n, ast.Call):
            tail = None
            if isinstance(n.func, ast.Attribute):
                tail = n.func.attr
            elif isinstance(n.func, ast.Name):
                tail = n.func.id
            if tail == "iinfo":
                return True
    return False


def _has_ref_import(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.split(".")[-1] == "ref" or \
                    any(a.name.split(".")[-1] == "ref" for a in node.names):
                return True
        elif isinstance(node, ast.Import):
            if any(a.name.split(".")[-1] == "ref" for a in node.names):
                return True
    return False


@register_rule("overflow-guard",
               "kernel ops.py must guard int32 index/accumulator space "
               "with a ref fallback or raise (sufficiency is proven "
               "separately by overflow-range)")
def _overflow_guard(ctx: FileContext):
    if not re.search(r"repro/kernels/[^/]+/ops\.py$", ctx.rel):
        return
    guards = [node for node in ast.walk(ctx.tree)
              if isinstance(node, (ast.If, ast.IfExp))
              and _mentions_sentinel(node.test)]
    if not guards:
        first_fn = next((n for n in ast.walk(ctx.tree)
                         if isinstance(n, ast.FunctionDef)), None)
        yield ctx.finding(
            "overflow-guard", first_fn or 1,
            "no int32 overflow guard: kernel launches without bounding "
            "the index/accumulator space", _HINT)
        return
    raises = any(isinstance(n, ast.Raise)
                 for g in guards for n in ast.walk(g))
    if not raises and not _has_ref_import(ctx.tree):
        yield ctx.finding(
            "overflow-guard", guards[0],
            "overflow guard present but no escape: neither a ref-module "
            "fallback import nor a raise in the guarded branch", _HINT)
