"""rng-discipline: randomness must flow through a seeded
``np.random.default_rng(seed)`` Generator parameter (the named-stream
convention of ``core/traces.py`` / ``core/stream.py`` — seed, seed+1,
seed+2).  Global seeding and module-level draws make results depend on
call order, which breaks the bit-identity contracts the equivalence
tests pin."""
from __future__ import annotations

import ast

from .. import FileContext, register_rule
from ._util import dotted, import_aliases, resolve

# numpy.random attributes that are seeded-construction, not draws
_CONSTRUCTORS = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "MT19937", "SFC64", "BitGenerator", "RandomState"}

_HINT = ("thread a seeded np.random.default_rng(seed) Generator through a "
         "parameter (named streams: seed, seed+1, ... as in core/traces.py)")


@register_rule("rng-discipline",
               "no np.random.seed / module-level np.random.* / stdlib "
               "random.* outside testing; randomness flows through a "
               "seeded Generator parameter")
def _rng_discipline(ctx: FileContext):
    if ctx.in_testing():
        return
    aliases = import_aliases(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        full = resolve(node.func, aliases)
        if full is None:
            continue
        if full == "numpy.random.seed":
            yield ctx.finding(
                "rng-discipline", node,
                "np.random.seed() sets hidden global state", _HINT)
        elif full.startswith("numpy.random."):
            attr = full.rsplit(".", 1)[-1]
            if attr not in _CONSTRUCTORS:
                yield ctx.finding(
                    "rng-discipline", node,
                    f"module-level draw np.random.{attr}() uses the "
                    "unseeded global stream", _HINT)
            elif attr == "default_rng" and not node.args and not node.keywords:
                yield ctx.finding(
                    "rng-discipline", node,
                    "default_rng() without a seed is entropy-seeded and "
                    "irreproducible", _HINT)
        elif full == "random" or full.startswith("random."):
            # only flag names actually bound by an import of the stdlib
            # module — never a local variable that happens to be `random`
            parts = dotted(node.func)
            bound = aliases.get(parts[0]) if parts else None
            if bound is not None and (bound == "random"
                                      or bound.startswith("random.")):
                yield ctx.finding(
                    "rng-discipline", node,
                    f"stdlib {full}() draws from unseeded global state",
                    _HINT)
