"""pragma-discipline: suppression pragmas are themselves checked — a
``# repro: allow(...)`` must name registered rules and carry a one-line
justification, or it suppresses nothing and is flagged.  This rule can
never be suppressed by a pragma (the engine refuses)."""
from __future__ import annotations

from .. import FileContext, register_rule
from ..pragmas import iter_pragmas

_MIN_JUSTIFICATION = 8  # characters — long enough to force an actual why


@register_rule("pragma-discipline",
               "every `# repro: allow(...)` pragma names registered rules "
               "and carries a one-line justification")
def _pragma_discipline(ctx: FileContext):
    from .. import _REGISTRY  # populated by the time checks run

    for p in iter_pragmas(ctx.source):
        if not p.rules:
            yield ctx.finding(
                "pragma-discipline", p.line,
                "pragma suppresses no rules (empty allow())",
                "write `# repro: allow(<rule-id>): <why>`")
            continue
        for r in p.rules:
            if r not in _REGISTRY:
                yield ctx.finding(
                    "pragma-discipline", p.line,
                    f"pragma names unknown rule {r!r}",
                    f"registered rules: {sorted(_REGISTRY)}")
        if len(p.justification) < _MIN_JUSTIFICATION:
            yield ctx.finding(
                "pragma-discipline", p.line,
                "pragma lacks a justification — unjustified pragmas "
                "suppress nothing",
                "append `: <one-line why this exception is intentional>`")
