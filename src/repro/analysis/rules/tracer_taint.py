"""tracer-taint: interprocedural jit purity through the taint engine.

``jit-purity`` pattern-matches the staged body itself (host numpy calls,
``print``, bare ``if param:``).  This rule runs
:class:`repro.analysis.flow.taint.TaintAnalyzer` over every function
staged into a jit entry in ``repro/core``: parameters and ``jax``/``jnp``/
``lax`` results are tracers, taint flows through locals *and into project
helpers called from the staged body*, and any Python ``if``/``while``/
``assert``/comprehension-filter on a tainted expression, numpy
materialization (``np.asarray``, ``float()``, ``.item()``), or host side
effect on a tainted value is reported at its source line — including
lines in a helper module the syntactic rule never looks at.

``jax.jit`` ``static_argnums``/``static_argnames`` parameters are seeded
untainted (they really are Python values at trace time).
"""
from __future__ import annotations

import ast

from .. import ProgramContext, register_rule
from ..flow.taint import TaintAnalyzer
from ._util import import_aliases, resolve
from .purity import _JIT_ENTRY, _jitted_functions

_KIND_HINTS = {
    "branch": "use lax.cond / lax.while_loop / jnp.where, or mark the "
              "driving argument static",
    "assert": "use checkify or validate before staging; `assert` on a "
              "tracer fails at trace time",
    "materialize": "stay in jnp inside jitted bodies; materializing a "
                   "tracer raises TracerArrayConversionError (or freezes "
                   "a trace-time constant)",
    "host": "use jax.debug.print / io_callback, or hoist the side effect "
            "out of the staged body",
}


def _static_params(tree: ast.AST, fn: ast.AST,
                   aliases: dict[str, str]) -> frozenset[str]:
    """Parameter names marked static at this function's jit sites."""
    names = _param_list(fn)
    static: set[str] = set()
    fname = getattr(fn, "name", None)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        full = resolve(node.func, aliases)
        if full not in _JIT_ENTRY:
            continue
        hits = fname is not None and any(
            isinstance(a, ast.Name) and a.id == fname
            for a in node.args)
        if not hits:
            continue
        for kw in node.keywords:
            if kw.arg == "static_argnames":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and \
                            isinstance(c.value, str):
                        static.add(c.value)
            elif kw.arg == "static_argnums":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and \
                            isinstance(c.value, int) and \
                            c.value < len(names):
                        static.add(names[c.value])
    # decorator form: @partial(jax.jit, static_argnums=...)
    for dec in getattr(fn, "decorator_list", []):
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    for c in ast.walk(kw.value):
                        if isinstance(c, ast.Constant) and \
                                isinstance(c.value, str):
                            static.add(c.value)
                elif kw.arg == "static_argnums":
                    for c in ast.walk(kw.value):
                        if isinstance(c, ast.Constant) and \
                                isinstance(c.value, int) and \
                                c.value < len(names):
                            static.add(names[c.value])
    return frozenset(static)


def _param_list(fn: ast.AST) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


@register_rule("tracer-taint",
               "taint tracking through jitted stages: no Python control "
               "flow, materialization, or host effects on traced values — "
               "interprocedurally",
               scope="program")
def _tracer_taint(ctx: ProgramContext):
    index = ctx.index
    by_module = {mi.name: fc for fc in ctx.files
                 for mi in [index.by_rel.get(fc.rel)] if mi is not None}
    seen: set[tuple] = set()
    for fc in ctx.files:
        if not fc.in_core() or fc.in_testing():
            continue
        mi = index.by_rel.get(fc.rel)
        if mi is None:
            continue
        aliases = import_aliases(fc.tree)
        for fn, via in _jitted_functions(fc.tree, aliases):
            analyzer = TaintAnalyzer(index)
            static = _static_params(fc.tree, fn, aliases)
            try:
                found = analyzer.analyze_staged(fn, mi, static)
            except RecursionError:
                continue
            name = getattr(fn, "name", "<lambda>")
            for f in found:
                line = getattr(f.node, "lineno", 1)
                sig = (f.module.name, line, f.kind)
                if sig in seen:
                    continue
                seen.add(sig)
                target = by_module.get(f.module.name, fc)
                where = "" if f.module is mi else \
                    f" (reached from {name}() staged into {via})"
                yield target.finding(
                    "tracer-taint", f.node,
                    f"{f.detail} inside a jitted stage{where}",
                    _KIND_HINTS.get(f.kind, ""))
