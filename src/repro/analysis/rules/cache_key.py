"""cache-key: everything that can reach a cached value must reach its key.

A memoized result that depends on an input the key omits silently serves
the wrong answer when that input changes — the exact bug class the BNA /
order LRU key-hardening fixed by hand.  This rule finds every *caching
function* (a body containing both ``<cache>.lookup(K)`` and
``<cache>.store(K, V)`` on the same cache-named object) and checks two
obligations:

1. **Parameter soundness** — every function parameter that can reach the
   stored value ``V`` (flow-insensitive def-use closure over the body,
   with ``zip``/``enumerate`` unpack precision) must also reach the
   stored key ``K``.
2. **Knob soundness** — the call graph is walked from the caching
   function (bounded BFS); any ``REPRO_*`` environment read or
   ``config.<attr>`` read reachable from the value computation is a
   hidden cache input and is reported — unless the function sits in the
   *neutral set*: backend dispatchers whose branches are certified
   bit-identical by the equivalence CI jobs (numpy/pallas/jit produce
   byte-equal results, so the knob cannot change the cached value), or
   the attr is cache plumbing (``*_cache_size`` bounds eviction, not
   results).
"""
from __future__ import annotations

import ast

from .. import ProgramContext, register_rule
from ..flow.callgraph import CallGraph, find_knob_reads
from ._util import dotted

# Dispatch helpers whose backend branches are certified bit-identical
# (plan-jit-equivalence, kernel-parity CI jobs): a knob read below these
# selects *how* a value is computed, never *what* it is.
_NEUTRAL_FQNS = {
    "repro.core.backend.resolve_alpha_backend",
    "repro.core.backend.resolve_bna_backend",
    "repro.core.backend.resolve_plan_backend",
    "repro.core.backend.compute_alphas",
    "repro.core.backend.fused_merge_fix",
    "repro.core.backend.plan_edges",
    "repro.core.backend.plan_order_loads",
    "repro.core.backend.prefetch_plan",
    "repro.core.backend.bna_pieces",
    "repro.core.backend.bna_pieces_many",
    "repro.core.backend.prefetch_bna",
    "repro.core.matching._resolve_step",
}

# config attributes that bound cache capacity, not cached results
_CACHE_PLUMBING_ATTRS = {"bna_cache_size", "order_cache_size",
                         "edge_cache_size", "compile_cache_size",
                         "group_cache_size", "loads_cache_size",
                         "gkey_cache_size"}

_HINT_PARAM = ("fold the parameter into the cache key (or derive both key "
               "and value from the same inputs); a value-only input makes "
               "the memo serve stale results when it changes")
_HINT_KNOB = ("include the knob in the cache key, clear the cache when it "
              "changes, or — if every setting is certified bit-identical — "
              "add the dispatcher to the rule's neutral set with that "
              "justification")


def _cache_calls(fn: ast.AST):
    """(lookups, stores) on cache-named objects inside `fn`."""
    lookups, stores = [], []
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        parts = dotted(node.func.value)
        if parts is None or not any("cache" in p.lower() for p in parts):
            continue
        if node.func.attr == "lookup" and node.args:
            lookups.append(node)
        elif node.func.attr == "store" and len(node.args) >= 2:
            stores.append(node)
    return lookups, stores


def _load_names(expr: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(expr)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _name_deps(fn: ast.AST) -> dict[str, set[str]]:
    """Flow-insensitive name -> names-it-was-computed-from map."""
    deps: dict[str, set[str]] = {}

    def add(name: str, srcs: set[str]) -> None:
        deps.setdefault(name, set()).update(srcs - {name})

    def unpack(target: ast.expr, value: ast.expr | None) -> None:
        srcs = _load_names(value) if value is not None else set()
        if isinstance(target, (ast.Tuple, ast.List)) and \
                isinstance(value, ast.Call) and \
                isinstance(value.func, ast.Name) and \
                value.func.id in ("zip", "enumerate"):
            # positional precision: zip elt i <- arg i; enumerate elt 0
            # is the index (no deps), elt 1 <- the iterable
            args = value.args
            if value.func.id == "enumerate":
                args = [None] + list(args)
            for i, el in enumerate(target.elts):
                el_srcs = _load_names(args[i]) if i < len(args) and \
                    args[i] is not None else set()
                for n in ast.walk(el):
                    if isinstance(n, ast.Name):
                        add(n.id, el_srcs)
            return
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                add(n.id, srcs)

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                unpack(t, node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            unpack(node.target, node.value)
        elif isinstance(node, ast.AugAssign):
            unpack(node.target, node.value)
        elif isinstance(node, ast.For):
            unpack(node.target, node.iter)
        elif isinstance(node, ast.comprehension):
            unpack(node.target, node.iter)
        elif isinstance(node, ast.withitem) and \
                node.optional_vars is not None:
            unpack(node.optional_vars, node.context_expr)
        elif isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            # mutation-style accumulation: xs.append(y) makes xs carry y
            call = node.value
            if isinstance(call.func, ast.Attribute) and \
                    isinstance(call.func.value, ast.Name) and \
                    call.func.attr in ("append", "extend", "add",
                                       "update", "insert", "setdefault"):
                srcs: set[str] = set()
                for a in list(call.args) + [k.value for k in call.keywords]:
                    srcs |= _load_names(a)
                add(call.func.value.id, srcs)
    return deps


def _reach(names: set[str], deps: dict[str, set[str]]) -> set[str]:
    out = set(names)
    frontier = list(names)
    while frontier:
        n = frontier.pop()
        for src in deps.get(n, ()):
            if src not in out:
                out.add(src)
                frontier.append(src)
    return out


def _params(fn: ast.AST) -> set[str]:
    a = fn.args
    names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


@register_rule("cache-key",
               "every parameter and global knob that can reach a cached "
               "value must also reach its cache key",
               scope="program")
def _cache_key(ctx: ProgramContext):
    index = ctx.index
    graph = CallGraph(index)
    seen_knobs: set[tuple] = set()
    for fc in ctx.files:
        if fc.in_testing() or fc.in_benchmarks():
            continue
        mi = index.by_rel.get(fc.rel)
        if mi is None or not mi.name.startswith("repro."):
            continue
        for fname, fn in mi.functions.items():
            lookups, stores = _cache_calls(fn)
            if not (lookups and stores):
                continue
            fqn = f"{mi.name}.{fname}"
            deps = _name_deps(fn)
            params = _params(fn)
            for store in stores:
                key_expr, val_expr = store.args[0], store.args[1]
                key_reach = _reach(_load_names(key_expr), deps)
                val_reach = _reach(_load_names(val_expr), deps)
                leaked = sorted((val_reach - key_reach) & params)
                if leaked:
                    yield fc.finding(
                        "cache-key", store,
                        f"{fname}() caches a value computed from "
                        f"parameter(s) {', '.join(repr(p) for p in leaked)}"
                        f" that never reach the cache key", _HINT_PARAM)
            # knob soundness: env/config reads reachable from the body
            if fqn in _NEUTRAL_FQNS:
                continue
            reached = graph.reachable([fqn], max_depth=6,
                                      stop=_NEUTRAL_FQNS)
            for rfqn in sorted(reached):
                if rfqn in _NEUTRAL_FQNS:
                    continue
                owner, rfn = index.lookup_function(rfqn)
                if owner is None or rfn is None:
                    continue
                for read in find_knob_reads(rfn, owner, index):
                    if read.kind == "config" and \
                            read.name in _CACHE_PLUMBING_ATTRS:
                        continue
                    sig = (owner.ctx.rel, read.line, read.name)
                    if sig in seen_knobs:
                        continue
                    seen_knobs.add(sig)
                    where = "" if rfqn == fqn else \
                        f" (via {rfqn.rsplit('.', 1)[-1]}())"
                    yield owner.ctx.finding(
                        "cache-key", read.line,
                        f"{fname}() populates a cache but reads "
                        f"result-affecting knob "
                        f"{read.name!r}{where} that is not part of the "
                        f"cache key", _HINT_KNOB)