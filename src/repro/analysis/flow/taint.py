"""Tracer-taint analysis for jitted stages.

Inside a ``jax.jit`` / ``lax.while_loop`` / ``lax.scan`` body, function
parameters and the results of ``jax.*`` / ``jnp.*`` / ``lax.*`` calls are
*tracers*.  Python-level control flow (``if``/``while``/``assert``),
numpy materialization (``np.asarray``, ``float()``, ``.item()``,
``.tolist()``) and host side effects (``print``/``open``) on a tracer
either crash at trace time or — worse — silently bake one traced value
into the compiled program.  :class:`TaintAnalyzer` propagates a taint bit
through a staged function's locals and follows calls into *project*
functions (helpers called from a jitted body are analyzed under the
tainted arguments too, depth-limited and memoized), reporting each
violation at its source line in the module that contains it.

Deliberate un-taints: ``x.shape`` / ``x.ndim`` / ``x.dtype`` / ``x.size``
and ``len(x)`` are Python values even on tracers, so arithmetic on shapes
never taints — the analysis only fires on *data*-dependent control flow.
"""
from __future__ import annotations

import ast
from typing import NamedTuple, Optional

from .modules import dotted
from .modules import ModuleInfo, ProjectIndex

__all__ = ["TaintFinding", "TaintAnalyzer"]

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_MATERIALIZE_METHODS = {"tolist", "item", "to_py", "block_until_ready"}
_MATERIALIZE_BUILTINS = {"float", "int", "bool", "complex"}
_HOST_CALLS = {"print", "open", "input", "breakpoint"}
_UNTAINT_BUILTINS = {"len", "range", "enumerate", "isinstance", "type",
                     "hasattr", "getattr"}


class TaintFinding(NamedTuple):
    module: ModuleInfo
    node: ast.AST
    kind: str          # "branch" | "assert" | "materialize" | "host"
    detail: str


class _Scope:
    __slots__ = ("tainted", "parent")

    def __init__(self, tainted: set[str],
                 parent: Optional["_Scope"] = None):
        self.tainted = tainted
        self.parent = parent

    def is_tainted(self, name: str) -> bool:
        s: Optional[_Scope] = self
        while s is not None:
            if name in s.tainted:
                return True
            s = s.parent
        return False


class TaintAnalyzer:
    """Interprocedural tracer-taint over one staged entry function."""

    def __init__(self, index: ProjectIndex, max_depth: int = 3):
        self.index = index
        self.max_depth = max_depth
        self.findings: list[TaintFinding] = []
        # (module, name, lineno, tainted-param mask) -> returns_tainted
        self._memo: dict[tuple, bool] = {}
        self._active: set[tuple] = set()

    # -- public entry -------------------------------------------------------

    def analyze_staged(self, fn: ast.AST, module: ModuleInfo,
                       static_params: frozenset[str] = frozenset()
                       ) -> list[TaintFinding]:
        params = _param_names(fn)
        tainted = {p for p in params if p not in static_params}
        self._run(fn, module, _Scope(tainted), depth=0)
        return self.findings

    # -- function body walk -------------------------------------------------

    def _run(self, fn: ast.AST, module: ModuleInfo, scope: _Scope,
             depth: int) -> bool:
        """Walk `fn`'s body under `scope`; returns `returns_tainted`."""
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        if not isinstance(fn.body, list):  # lambda
            return self._expr(fn.body, module, scope, depth)
        # propagate assignments to a fixpoint (loops feed back), then one
        # reporting pass
        for _ in range(4):
            before = set(scope.tainted)
            self._block(body, module, scope, depth, report=False)
            if scope.tainted == before:
                break
        return self._block(body, module, scope, depth, report=True)

    def _block(self, stmts: list, module: ModuleInfo, scope: _Scope,
               depth: int, report: bool) -> bool:
        returns_tainted = False
        for stmt in stmts:
            returns_tainted |= self._stmt(stmt, module, scope, depth,
                                          report)
        return returns_tainted

    def _stmt(self, stmt: ast.stmt, module: ModuleInfo, scope: _Scope,
              depth: int, report: bool) -> bool:
        rt = False
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            t = self._expr(value, module, scope, depth,
                           report=report) if value is not None else False
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            if isinstance(stmt, ast.AugAssign):
                t = t or self._expr(stmt.target, module, scope, depth,
                                    report=False)
            for tgt in targets:
                for name in _target_names(tgt):
                    if t:
                        scope.tainted.add(name)
                    elif name in scope.tainted and \
                            isinstance(stmt, ast.Assign):
                        scope.tainted.discard(name)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                rt = self._expr(stmt.value, module, scope, depth,
                                report=report)
        elif isinstance(stmt, ast.Expr):
            self._expr(stmt.value, module, scope, depth, report=report)
        elif isinstance(stmt, ast.If):
            if self._expr(stmt.test, module, scope, depth,
                          report=False) and report:
                self._flag(module, stmt, "branch",
                           "Python `if` on a traced value")
            rt |= self._block(stmt.body, module, scope, depth, report)
            rt |= self._block(stmt.orelse, module, scope, depth, report)
        elif isinstance(stmt, ast.While):
            if self._expr(stmt.test, module, scope, depth,
                          report=False) and report:
                self._flag(module, stmt, "branch",
                           "Python `while` on a traced value")
            rt |= self._block(stmt.body, module, scope, depth, report)
            rt |= self._block(stmt.orelse, module, scope, depth, report)
        elif isinstance(stmt, ast.Assert):
            if self._expr(stmt.test, module, scope, depth,
                          report=False) and report:
                self._flag(module, stmt, "assert",
                           "`assert` on a traced value")
        elif isinstance(stmt, ast.For):
            t = self._expr(stmt.iter, module, scope, depth, report=report)
            for name in _target_names(stmt.target):
                if t:
                    scope.tainted.add(name)
            rt |= self._block(stmt.body, module, scope, depth, report)
            rt |= self._block(stmt.orelse, module, scope, depth, report)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self._expr(item.context_expr, module, scope, depth,
                           report=report)
            rt |= self._block(stmt.body, module, scope, depth, report)
        elif isinstance(stmt, ast.Try):
            rt |= self._block(stmt.body, module, scope, depth, report)
            for h in stmt.handlers:
                rt |= self._block(h.body, module, scope, depth, report)
            rt |= self._block(stmt.finalbody, module, scope, depth, report)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef, ast.Import, ast.ImportFrom,
                               ast.Pass, ast.Break, ast.Continue,
                               ast.Global, ast.Nonlocal, ast.Raise,
                               ast.Delete)):
            pass
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._expr(child, module, scope, depth, report=report)
        return rt

    # -- expression taint ---------------------------------------------------

    def _expr(self, node: ast.expr, module: ModuleInfo, scope: _Scope,
              depth: int, report: bool = True) -> bool:
        if isinstance(node, ast.Name):
            return scope.is_tainted(node.id)
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            base = self._expr(node.value, module, scope, depth, report)
            if node.attr in _SHAPE_ATTRS:
                return False
            return base
        if isinstance(node, ast.Subscript):
            return self._expr(node.value, module, scope, depth, report) \
                or self._expr(node.slice, module, scope, depth, report)
        if isinstance(node, ast.Call):
            return self._call(node, module, scope, depth, report)
        if isinstance(node, ast.IfExp):
            if self._expr(node.test, module, scope, depth,
                          report=False) and report:
                self._flag(module, node, "branch",
                           "conditional expression on a traced value")
            return self._expr(node.body, module, scope, depth, report) or \
                self._expr(node.orelse, module, scope, depth, report)
        if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare,
                             ast.UnaryOp, ast.Tuple, ast.List, ast.Set,
                             ast.Slice, ast.Starred, ast.JoinedStr,
                             ast.FormattedValue, ast.Dict)):
            return any(self._expr(c, module, scope, depth, report)
                       for c in ast.iter_child_nodes(node)
                       if isinstance(c, ast.expr))
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            sub = _Scope(set(), parent=scope)
            for gen in node.generators:
                t = self._expr(gen.iter, module, sub, depth, report)
                for name in _target_names(gen.target):
                    if t:
                        sub.tainted.add(name)
                for cond in gen.ifs:
                    if self._expr(cond, module, sub, depth,
                                  report=False) and report:
                        self._flag(module, cond, "branch",
                                   "comprehension filter on a traced "
                                   "value")
            parts = [node.elt] if not isinstance(node, ast.DictComp) \
                else [node.key, node.value]
            return any(self._expr(p, module, sub, depth, report)
                       for p in parts)
        if isinstance(node, ast.Lambda):
            return False
        return False

    def _call(self, node: ast.Call, module: ModuleInfo, scope: _Scope,
              depth: int, report: bool) -> bool:
        arg_taints = [self._expr(a, module, scope, depth, report)
                      for a in node.args]
        kw_taints = {k.arg: self._expr(k.value, module, scope, depth,
                                       report)
                     for k in node.keywords if k.arg}
        any_tainted = any(arg_taints) or any(kw_taints.values())
        func = node.func

        # method calls -------------------------------------------------
        if isinstance(func, ast.Attribute):
            base_tainted = self._expr(func.value, module, scope, depth,
                                      report=False)
            parts = dotted(func)
            fqn = None
            if parts is not None:
                fqn = self.index.resolve(module, ".".join(parts)) or \
                    _alias_fqn(module, parts)
            if fqn:
                if _is_jax(fqn):
                    return True
                if _is_numpy(fqn) and any_tainted:
                    if report:
                        self._flag(module, node, "materialize",
                                   f"`{'.'.join(parts)}` materializes a "
                                   "traced value on the host")
                    return False
                owner, fndef = self.index.lookup_function(fqn)
                if fndef is not None and owner is not None:
                    return self._inter(node, fndef, owner, arg_taints,
                                       kw_taints, depth, report)
            if base_tainted and func.attr in _MATERIALIZE_METHODS:
                if report:
                    self._flag(module, node, "materialize",
                               f"`.{func.attr}()` materializes a traced "
                               "value on the host")
                return False
            return base_tainted or any_tainted

        # plain-name calls ---------------------------------------------
        if isinstance(func, ast.Name):
            name = func.id
            if name in _MATERIALIZE_BUILTINS and any_tainted:
                if report:
                    self._flag(module, node, "materialize",
                               f"`{name}()` forces a traced value to a "
                               "host scalar")
                return False
            if name in _HOST_CALLS and any_tainted:
                if report:
                    self._flag(module, node, "host",
                               f"`{name}()` is a host side effect on a "
                               "traced value")
                return False
            if name in _UNTAINT_BUILTINS:
                return False
            fqn = self.index.resolve(module, name)
            if fqn:
                if _is_jax(fqn):
                    return True
                owner, fndef = self.index.lookup_function(fqn)
                if fndef is not None and owner is not None:
                    return self._inter(node, fndef, owner, arg_taints,
                                       kw_taints, depth, report)
            return any_tainted
        # computed callee (lambda var, functools.partial result, ...)
        self._expr(func, module, scope, depth, report=False)
        return any_tainted

    # -- interprocedural ----------------------------------------------------

    def _inter(self, call: ast.Call, fn: ast.AST, owner: ModuleInfo,
               arg_taints: list[bool], kw_taints: dict, depth: int,
               report: bool) -> bool:
        if depth >= self.max_depth:
            return any(arg_taints) or any(kw_taints.values())
        params = _param_names(fn)
        tainted = set()
        for i, t in enumerate(arg_taints):
            if t and i < len(params):
                tainted.add(params[i])
        for k, t in kw_taints.items():
            if t and k in params:
                tainted.add(k)
        key = (owner.name, getattr(fn, "name", "<lambda>"),
               getattr(fn, "lineno", 0), frozenset(tainted), report)
        if key in self._memo:
            return self._memo[key]
        if key in self._active:       # recursion: assume propagation
            return bool(tainted)
        self._active.add(key)
        try:
            rt = self._run(fn, owner, _Scope(tainted), depth + 1)
        finally:
            self._active.discard(key)
        self._memo[key] = rt
        return rt

    # -- helpers ------------------------------------------------------------

    def _flag(self, module: ModuleInfo, node: ast.AST, kind: str,
              detail: str) -> None:
        f = TaintFinding(module, node, kind, detail)
        # dedupe on (module, line, kind)
        sig = (module.name, getattr(node, "lineno", 0), kind)
        if sig not in {(x.module.name, getattr(x.node, "lineno", 0),
                        x.kind) for x in self.findings}:
            self.findings.append(f)


def _is_jax(fqn: str) -> bool:
    return fqn == "jax" or fqn.startswith("jax.")


def _is_numpy(fqn: str) -> bool:
    return fqn == "numpy" or fqn.startswith("numpy.")


def _alias_fqn(module: ModuleInfo, parts: list[str]) -> Optional[str]:
    head = module.imports.get(parts[0])
    if head is None:
        return None
    return ".".join([head] + parts[1:])


def _param_names(fn: ast.AST) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _target_names(tgt: ast.expr) -> list[str]:
    out = []
    for n in ast.walk(tgt):
        if isinstance(n, ast.Name):
            out.append(n.id)
    return out
