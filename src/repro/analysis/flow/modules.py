"""Module and symbol resolution over a scanned file set.

The program-scope rules need to answer "what does this dotted name refer
to, project-wide?" — ``from ..coflow_merge.ref import build_delta`` inside
``repro/kernels/merge_fix/ops.py`` must resolve to the *function object's*
defining module so the interval engine can evaluate its body under that
module's own import aliases.  :class:`ProjectIndex` builds that map from
the scanned :class:`~repro.analysis.FileContext` list alone (no imports
are executed): path -> dotted module name, per-module symbol tables
(functions at any nesting, top-level constants, import bindings resolved
to absolute dotted targets), and a chased :meth:`resolve` /
:meth:`lookup_function`.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .. import FileContext

__all__ = ["dotted", "module_name_for", "ModuleInfo", "ProjectIndex"]


def dotted(node: ast.AST) -> list[str] | None:
    """["np", "random", "seed"] for the expression ``np.random.seed``;
    None when the chain is not rooted in a plain Name.  (Mirror of
    ``rules._util.dotted``, defined here so the flow package never
    imports the rules package — rules import flow, not the reverse.)"""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def module_name_for(rel: str) -> str:
    """Dotted module name for a scan-root-relative path.

    ``src/repro/core/backend.py`` -> ``repro.core.backend``;
    ``benchmarks/run.py`` -> ``benchmarks.run``; ``pkg/__init__.py`` ->
    ``pkg``.  The leading ``src/`` layout component is dropped so fixture
    trees and the real repo resolve identically.
    """
    parts = rel.split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][: -len(".py")]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(p for p in parts if p)


@dataclass
class ModuleInfo:
    """One scanned module: its context plus symbol tables."""

    name: str                       # dotted module name
    ctx: "FileContext"
    is_package: bool
    functions: dict[str, ast.FunctionDef] = field(default_factory=dict)
    classes: dict[str, ast.ClassDef] = field(default_factory=dict)
    imports: dict[str, str] = field(default_factory=dict)  # local -> absolute
    constants: dict[str, ast.expr] = field(default_factory=dict)

    @property
    def package(self) -> str:
        """The package this module's relative imports anchor on."""
        if self.is_package:
            return self.name
        return self.name.rsplit(".", 1)[0] if "." in self.name else ""


def _index_module(mi: ModuleInfo) -> None:
    tree = mi.ctx.tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # top-level name wins; nested defs index under their own name
            # only if unclaimed (good enough for helper resolution)
            mi.functions.setdefault(node.name, node)
        elif isinstance(node, ast.ClassDef):
            mi.classes.setdefault(node.name, node)
        elif isinstance(node, ast.Import):
            # function-level imports included: the repo lazily imports
            # inside functions to break cycles, and interprocedural
            # resolution must see those bindings (first binding wins)
            for a in node.names:
                if a.asname:
                    mi.imports.setdefault(a.asname, a.name)
                else:
                    root = a.name.split(".")[0]
                    mi.imports.setdefault(root, root)
        elif isinstance(node, ast.ImportFrom):
            base = _absolute_base(mi, node)
            if base is None:
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                target = f"{base}.{a.name}" if base else a.name
                mi.imports.setdefault(a.asname or a.name, target)
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            mi.constants[node.targets[0].id] = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None and \
                isinstance(node.target, ast.Name):
            mi.constants[node.target.id] = node.value


def _absolute_base(mi: ModuleInfo, node: ast.ImportFrom) -> str | None:
    """Absolute dotted base for an ImportFrom (relative levels resolved
    against the module's package)."""
    if node.level == 0:
        return node.module or ""
    anchor = mi.package.split(".") if mi.package else []
    drop = node.level - 1
    if drop > len(anchor):
        return None
    anchor = anchor[: len(anchor) - drop]
    if node.module:
        anchor += node.module.split(".")
    return ".".join(anchor)


class ProjectIndex:
    """Whole-program symbol table over the scanned files."""

    def __init__(self, files: "list[FileContext]"):
        self.modules: dict[str, ModuleInfo] = {}
        self.by_rel: dict[str, ModuleInfo] = {}
        for ctx in files:
            name = module_name_for(ctx.rel)
            if not name:
                continue
            mi = ModuleInfo(name, ctx,
                            is_package=ctx.rel.endswith("__init__.py"))
            _index_module(mi)
            self.modules[name] = mi
            self.by_rel[ctx.rel] = mi

    # --- resolution -------------------------------------------------------

    def resolve(self, mi: ModuleInfo, dotted: str,
                _depth: int = 0) -> str | None:
        """Absolute dotted target of `dotted` as seen from module `mi`:
        import aliases expanded and re-exports chased (bounded)."""
        if _depth > 6:
            return None
        parts = dotted.split(".")
        head = mi.imports.get(parts[0])
        if head is None:
            if parts[0] in mi.functions or parts[0] in mi.classes or \
                    parts[0] in mi.constants:
                return f"{mi.name}.{dotted}"
            return None
        fqn = ".".join([head] + parts[1:])
        # chase one re-export level: if fqn's module prefix is an indexed
        # module that merely imports the tail, follow it
        owner, tail = self.split(fqn)
        if owner is not None and tail and "." not in tail and \
                tail not in owner.functions and tail not in owner.classes \
                and tail not in owner.constants and tail in owner.imports:
            return self.resolve(owner, tail, _depth + 1)
        return fqn

    def split(self, fqn: str) -> tuple[Optional[ModuleInfo], str]:
        """(owning module, remainder qualname) for an absolute dotted name
        — the longest indexed module prefix wins."""
        parts = fqn.split(".")
        for i in range(len(parts), 0, -1):
            name = ".".join(parts[:i])
            if name in self.modules:
                return self.modules[name], ".".join(parts[i:])
        return None, fqn

    def lookup_function(
        self, fqn: str | None
    ) -> tuple[Optional[ModuleInfo], Optional[ast.FunctionDef]]:
        """(module, FunctionDef) for an absolute dotted name, or (None,
        None) when it is not a scanned function."""
        if not fqn:
            return None, None
        owner, tail = self.split(fqn)
        if owner is None or not tail:
            return None, None
        fn = owner.functions.get(tail)
        if fn is not None:
            return owner, fn
        # plain re-export (from .impl import f) — chase it
        if tail in owner.imports:
            return self.lookup_function(owner.imports[tail])
        return None, None

    def lookup_constant(
        self, fqn: str | None
    ) -> tuple[Optional[ModuleInfo], Optional[ast.expr]]:
        if not fqn:
            return None, None
        owner, tail = self.split(fqn)
        if owner is None or not tail:
            return None, None
        if tail in owner.constants:
            return owner, owner.constants[tail]
        if tail in owner.imports:
            return self.lookup_constant(owner.imports[tail])
        return None, None
