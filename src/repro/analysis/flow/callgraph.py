"""Project call graph + configuration-knob read scanning.

Built on :class:`~repro.analysis.flow.modules.ProjectIndex`: for every
scanned function we record the set of *resolved* callee FQNs (dotted
names resolved through import aliases and re-exports to their defining
module).  ``reachable`` runs a bounded BFS over that edge set — the
cache-key rule walks it from a cached value's producer to find
environment / config reads that can influence the value without being
part of the cache key.

A "knob read" is either:

* ``os.environ["REPRO_*"]`` / ``os.environ.get("REPRO_*")`` — raw
  environment access, or
* an attribute read off a name that resolves to a ``*config`` object
  (e.g. ``repro.core.backend.config.bna_backend``) — the structured
  form the repo actually uses.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from .modules import ModuleInfo, ProjectIndex, dotted

__all__ = ["CallGraph", "KnobRead", "find_knob_reads"]


class KnobRead:
    """One configuration read inside a function body."""

    __slots__ = ("kind", "name", "line")

    def __init__(self, kind: str, name: str, line: int):
        self.kind = kind    # "env" | "config"
        self.name = name    # REPRO_FOO or config attribute name
        self.line = line

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"KnobRead({self.kind}:{self.name}@{self.line})"


def find_knob_reads(fn: ast.AST, mi: ModuleInfo,
                    index: ProjectIndex) -> list[KnobRead]:
    """All env-var / config-attribute reads lexically inside `fn`."""
    out: list[KnobRead] = []
    for node in ast.walk(fn):
        # os.environ["REPRO_X"] and os.environ.get("REPRO_X", ...)
        key: Optional[ast.expr] = None
        target: Optional[ast.expr] = None
        if isinstance(node, ast.Subscript):
            target, key = node.value, node.slice
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "get" and node.args:
            target, key = node.func.value, node.args[0]
        if target is not None and _is_environ(target, mi, index) and \
                isinstance(key, ast.Constant) and \
                isinstance(key.value, str) and \
                key.value.startswith("REPRO_"):
            out.append(KnobRead("env", key.value, node.lineno))
            continue
        # config.<attr> where `config` resolves to a *config binding
        if isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, ast.Load) and \
                not node.attr.startswith("_"):
            parts = dotted(node.value)
            if parts is None:
                continue
            fqn = index.resolve(mi, ".".join(parts)) or ".".join(parts)
            if fqn.split(".")[-1] in ("config", "CONFIG"):
                out.append(KnobRead("config", node.attr, node.lineno))
    return out


def _is_environ(expr: ast.expr, mi: ModuleInfo,
                index: ProjectIndex) -> bool:
    parts = dotted(expr)
    if parts is None:
        return False
    fqn = index.resolve(mi, ".".join(parts)) or ".".join(parts)
    return fqn in ("os.environ", "environ")


class CallGraph:
    """Resolved call edges between scanned functions."""

    def __init__(self, index: ProjectIndex):
        self.index = index
        self._edges: dict[str, set[str]] = {}

    def _fqn(self, mi: ModuleInfo, name: str) -> str:
        return f"{mi.name}.{name}"

    def callees(self, fqn: str) -> set[str]:
        """Resolved FQNs called from `fqn`'s body (computed lazily)."""
        if fqn in self._edges:
            return self._edges[fqn]
        owner, fn = self.index.lookup_function(fqn)
        edges: set[str] = set()
        self._edges[fqn] = edges
        if owner is None or fn is None:
            return edges
        local_fns = {n.name for n in ast.walk(fn)
                     if isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))
                     and n is not fn}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            parts = dotted(node.func)
            if parts is None:
                continue
            if parts[0] in local_fns:
                # nested helper: analyze inline under the same module
                edges.add(self._fqn(owner, parts[0]))
                continue
            resolved = self.index.resolve(owner, ".".join(parts))
            if resolved is None:
                continue
            ro, rf = self.index.lookup_function(resolved)
            if ro is not None and rf is not None:
                edges.add(f"{ro.name}.{rf.name}")
        return edges

    def reachable(self, roots: Iterable[str], max_depth: int = 6,
                  stop: Optional[set[str]] = None) -> set[str]:
        """Functions reachable from `roots` (inclusive), bounded BFS.

        `stop` names are included when reached but not traversed — used
        for certified-neutral dispatch helpers whose internals are
        audited out-of-band (bit-identity CI jobs).
        """
        stop = stop or set()
        seen: set[str] = set()
        frontier = [(r, 0) for r in roots]
        while frontier:
            fqn, d = frontier.pop()
            if fqn in seen or d > max_depth:
                continue
            seen.add(fqn)
            if fqn in stop:
                continue
            for callee in self.callees(fqn):
                if callee not in seen:
                    frontier.append((callee, d + 1))
        return seen
