"""repro.analysis.flow — whole-program dataflow for the contract checker.

The PR-8 rules in :mod:`repro.analysis.rules` are syntactic: one file at
a time, pattern-matching for the *presence* of a guard or a pragma.
This package adds the semantic layer underneath them — module/symbol
resolution, a call graph, an integer-interval abstract interpreter with
symbolic shapes, and an interprocedural tracer-taint engine — so rules
can prove a guard *sufficient* rather than merely present.

Writing a dataflow rule
=======================

1. **Declare program scope.**  Register with ``scope="program"``; the
   check receives a :class:`repro.analysis.ProgramContext` holding every
   scanned :class:`~repro.analysis.FileContext` plus a lazily-built
   :class:`~repro.analysis.flow.modules.ProjectIndex`::

       from . import register_rule

       def check(program):
           index = program.index          # ProjectIndex
           for ctx in program.files:      # all FileContexts
               ...
               yield ctx.finding("my-rule", node, "message", hint="...")

       register_rule("my-rule", "one-line doc", check, scope="program")

2. **Resolve symbols through the index.**  ``index.resolve(mi, "jnp.pad")``
   expands import aliases and chases re-exports to an absolute dotted
   name; ``index.lookup_function(fqn)`` returns the defining
   ``(ModuleInfo, ast.FunctionDef)`` so callee bodies can be analyzed
   under *their own* module's imports — the core of interprocedural
   precision.

3. **Pick an engine.**

   * *Value ranges / shapes*: :class:`~repro.analysis.flow.intervals.FlowInterp`
     walks one function path-sensitively (forking at ``if``, no joins up
     to a path cap), tracking an :class:`~repro.analysis.flow.intervals.IV`
     interval **and** a canonical symbolic expression per local, and
     symbolic dimension tuples per array.  Pass ``on_call`` to hook every
     call site — that is where the overflow rule discharges its
     "element count <= 2**31-1" obligation via
     :func:`~repro.analysis.flow.intervals.prove_count` (pure interval
     bound, refined count expression, or factor-multiset cover of a
     guard-recorded product bound).
   * *Taint*: :class:`~repro.analysis.flow.taint.TaintAnalyzer` seeds a
     staged function's parameters as tracers, propagates through locals
     and into project callees (memoized, depth-limited), and reports
     Python control flow / materialization / host effects on tainted
     values at their source line.
   * *Reachability*: :class:`~repro.analysis.flow.callgraph.CallGraph`
     gives resolved callee FQNs and a bounded-BFS ``reachable`` with a
     ``stop`` set for certified-neutral helpers;
     :func:`~repro.analysis.flow.callgraph.find_knob_reads` scans a body
     for ``REPRO_*`` env reads and ``config.<attr>`` reads — the
     cache-key rule's "hidden input" detector.

4. **Fail toward reporting.**  Anything outside the abstract domain must
   evaluate to an *unknown* that blocks proofs, never to a value that
   completes one.  A dataflow rule that cannot prove safety emits a
   finding with the unproven expression in the message and a concrete
   fix in ``hint=``.

5. **Pragma policy.**  False positives are suppressed at the line (or the
   line above) with a ``repro: allow(rule-name): justification`` comment
   (leading hash) — the
   justification is mandatory and should say *why the proof obligation is
   met by other means* (e.g. "key is derived from the same params that
   select the builder").  Never pragma a true finding; fix it.

6. **Baseline workflow.**  ``python -m repro.analysis --strict`` fails on
   any unsuppressed finding not recorded in ``analysis_baseline.json``
   (matched on rule + path + message, line-insensitive) *and* on baseline
   entries that no longer reproduce, so the baseline only ever shrinks.
   After fixing findings, refresh with ``--update-baseline``; CI keeps
   the committed file honest.
"""
from .callgraph import CallGraph, KnobRead, find_knob_reads
from .intervals import (AVal, Env, FlowInterp, I32_MAX, IV, SVal,
                        count_expr_str, prove_count)
from .modules import ModuleInfo, ProjectIndex, module_name_for
from .taint import TaintAnalyzer, TaintFinding

__all__ = [
    "AVal", "CallGraph", "Env", "FlowInterp", "I32_MAX", "IV", "KnobRead",
    "ModuleInfo", "ProjectIndex", "SVal", "TaintAnalyzer", "TaintFinding",
    "count_expr_str", "find_knob_reads", "module_name_for", "prove_count",
]
