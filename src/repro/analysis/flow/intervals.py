"""Integer-interval abstract interpretation with symbolic shapes.

This is the engine behind the ``overflow-range`` rule: a path-sensitive
abstract interpreter over one function at a time that tracks, for every
local name, an :class:`IV` integer interval *and* a canonical symbolic
expression, and for every locally-constructed / padded array a tuple of
symbolic dimensions.  Guards (``if expr >= _I32_MAX: raise/return ref``)
refine the fall-through state — including **product bounds**: a bound on
``b_pad * w_pad * w_pad`` proves any launch operand whose element count is
a sub-product of those factors (remaining factors provably >= 1).  The
point is to prove, at each Pallas *launch site*, that every array
operand's element count is bounded by ``2**31 - 1`` — or to report the
unproven count expression.

Scope and honesty: the abstract domain covers the wrapper idioms the
repo's kernels actually use — full ``x.shape`` unpacking or raising
shape-equality validation, ``np.zeros/full/empty``-style constructors,
``jnp.pad``, shape-preserving elementwise/`.at[]`/`.astype` chains, and
straight-line helper summaries (``build_delta``, local ``pad`` closures)
— with commutative-sum/product canonicalization so ``Sp`` matches
``S + pad`` and ``s_to + (x.shape[2] - x.shape[2])`` collapses to
``s_to``.  Anything outside the domain evaluates to an *unknown*, and
unknowns make launches unprovable, never silently proven: the analysis
fails toward reporting.
"""
from __future__ import annotations

import ast
from typing import Callable, NamedTuple, Optional

from .modules import ModuleInfo, ProjectIndex

__all__ = ["IV", "SVal", "AVal", "Env", "FlowInterp", "I32_MAX",
           "prove_count", "count_expr_str"]

I32_MAX = 2**31 - 1
INF = float("inf")


# ---------------------------------------------------------------------------
# interval lattice
# ---------------------------------------------------------------------------

class IV(NamedTuple):
    """Closed integer interval; +-inf endpoints for unbounded sides."""

    lo: float
    hi: float

    def is_const(self) -> bool:
        return self.lo == self.hi and self.lo not in (INF, -INF)

    def join(self, o: "IV") -> "IV":
        return IV(min(self.lo, o.lo), max(self.hi, o.hi))

    def meet(self, o: "IV") -> "IV":
        lo, hi = max(self.lo, o.lo), min(self.hi, o.hi)
        return IV(lo, hi) if lo <= hi else IV(lo, lo)  # empty -> point

    def add(self, o: "IV") -> "IV":
        return IV(self.lo + o.lo, self.hi + o.hi)

    def sub(self, o: "IV") -> "IV":
        return IV(self.lo - o.hi, self.hi - o.lo)

    def neg(self) -> "IV":
        return IV(-self.hi, -self.lo)

    def mul(self, o: "IV") -> "IV":
        cands = [_m(a, b) for a in (self.lo, self.hi)
                 for b in (o.lo, o.hi)]
        return IV(min(cands), max(cands))

    def floordiv(self, o: "IV") -> "IV":
        if o.lo <= 0:
            return TOP
        cands = []
        for a in (self.lo, self.hi):
            for b in (o.lo, o.hi):
                if b in (0, INF, -INF):
                    cands.append(0.0 if a not in (INF, -INF) else a)
                elif a in (INF, -INF):
                    cands.append(a)
                else:
                    cands.append(a // b)
        return IV(min(cands), max(cands))

    def mod(self, o: "IV") -> "IV":
        if o.lo > 0 and o.hi != INF:
            return IV(0, o.hi - 1)
        if o.lo > 0:
            return IV(0, INF)
        return TOP

    def lshift(self, o: "IV") -> "IV":
        if self.lo < 0 or o.lo < 0:
            return TOP
        lo = self.lo * (2 ** min(o.lo, 63)) if self.lo not in (INF,) else INF
        hi = INF if (self.hi == INF or o.hi == INF or o.hi > 63) \
            else self.hi * (2 ** o.hi)
        return IV(lo, hi)


def _m(a: float, b: float) -> float:
    if a in (INF, -INF) or b in (INF, -INF):
        if a == 0 or b == 0:
            return 0.0
    return a * b


TOP = IV(-INF, INF)
NONNEG = IV(0, INF)


def const_iv(v: float) -> IV:
    return IV(v, v)


# ---------------------------------------------------------------------------
# canonical symbolic expressions (hashable nested tuples)
# ---------------------------------------------------------------------------
#   ("c", int)                       constant
#   ("a", key)                       opaque atom (param, shape dim, ...)
#   ("+", const, ((term, coeff), ...))  linear combination, terms sorted
#   ("*", coeff, (f1, f2, ...))      product, factors sorted, reps allowed
#   ("//" | "%" | "<<", a, b)        non-linear binary ops
#   ("min" | "max", (args...))       sorted args
#   ("call", name, (args...))        pure call / opaque method
#   ("?", a, b)                      joined alternatives (if-exp)

def s_const(v: int):
    return ("c", int(v))


def s_atom(key) -> tuple:
    return ("a", key)


def _as_sum(e) -> tuple[int, dict]:
    if e[0] == "c":
        return e[1], {}
    if e[0] == "+":
        return e[1], dict(e[2])
    return 0, {e: 1}


def s_sum(const: int, terms: dict) -> tuple:
    terms = {t: c for t, c in terms.items() if c != 0}
    if not terms:
        return s_const(const)
    if const == 0 and len(terms) == 1:
        (t, c), = terms.items()
        if c == 1:
            return t
        if t[0] == "*":
            return s_mul_make(c * t[1], list(t[2]))
    return ("+", const, tuple(sorted(terms.items(), key=repr)))


def s_add(a, b) -> tuple:
    ca, ta = _as_sum(a)
    cb, tb = _as_sum(b)
    for t, c in tb.items():
        ta[t] = ta.get(t, 0) + c
    return s_sum(ca + cb, ta)


def s_neg(a) -> tuple:
    c, t = _as_sum(a)
    return s_sum(-c, {k: -v for k, v in t.items()})


def s_sub(a, b) -> tuple:
    return s_add(a, s_neg(b))


def s_mul_make(coeff: int, factors: list) -> tuple:
    if coeff == 0:
        return s_const(0)
    flat: list = []
    for f in factors:
        if f[0] == "c":
            coeff *= f[1]
        elif f[0] == "*":
            coeff *= f[1]
            flat.extend(f[2])
        else:
            flat.append(f)
    if coeff == 0:
        return s_const(0)
    if not flat:
        return s_const(coeff)
    if len(flat) == 1:
        # c*x is canonically the one-term sum ("+", 0, ((x, c),)) — the
        # same form s_add produces — so x + x and 2*x meet and cancel
        return flat[0] if coeff == 1 else ("+", 0, ((flat[0], coeff),))
    return ("*", coeff, tuple(sorted(flat, key=repr)))


def s_mul(a, b) -> tuple:
    # fold constant * sum into the sum (keeps 2*m canonical either way)
    if a[0] == "c" and b[0] == "+":
        a, b = b, a
    if b[0] == "c" and a[0] == "+":
        k = b[1]
        return s_sum(a[1] * k, {t: c * k for t, c in a[2]})
    return s_mul_make(1, [a, b])


def s_factors(e) -> tuple[int, tuple]:
    """(coeff, factor multiset) of a canonical product-like expression."""
    if e[0] == "*":
        return e[1], e[2]
    if e[0] == "c":
        return e[1], ()
    return 1, (e,)


_FRESH = [0]


def fresh_atom(tag: str) -> tuple:
    _FRESH[0] += 1
    return s_atom(f"{tag}#{_FRESH[0]}")


# ---------------------------------------------------------------------------
# abstract values
# ---------------------------------------------------------------------------

class SVal(NamedTuple):
    """Scalar: interval + canonical symbolic expression (None = opaque)."""

    iv: IV
    sym: Optional[tuple]


class AVal(NamedTuple):
    """Array: per-dimension scalar abstractions + an identity symbol."""

    dims: tuple          # tuple[SVal, ...]
    sym: tuple           # identity atom (for .size / method canon)


class ShapeRef(NamedTuple):
    """Transient value of an ``x.shape`` expression."""

    base: object         # the array's env slot name or AVal
    name: Optional[str]  # env name holding the array, when known


class AtRef(NamedTuple):
    """Transient value of ``x.at`` — indexing it keeps x's shape."""

    aval: "AVal"


def unknown_sval(tag: str = "v") -> SVal:
    return SVal(TOP, fresh_atom(tag))


def unknown_aval(tag: str = "arr") -> AVal:
    return AVal((), fresh_atom(tag))   # () dims = rank unknown


class Env:
    """One path's abstract state."""

    def __init__(self):
        self.vars: dict[str, object] = {}
        self.refine: dict[tuple, IV] = {}     # canonical sym -> interval
        self.prods: list[tuple[tuple, float]] = []  # (factor multiset, hi)
        self.funcs: dict[str, tuple] = {}     # local def name -> (node,)

    def copy(self) -> "Env":
        e = Env()
        e.vars = dict(self.vars)
        e.refine = dict(self.refine)
        e.prods = list(self.prods)
        e.funcs = dict(self.funcs)
        return e

    def meet_sym(self, sym: tuple, iv: IV) -> None:
        cur = self.refine.get(sym, TOP)
        self.refine[sym] = cur.meet(iv)

    def iv_of(self, val: object) -> IV:
        if isinstance(val, SVal):
            iv = val.iv
            if val.sym is not None:
                iv = iv.meet(self.sym_iv(val.sym))
            return iv
        return TOP

    def sym_iv(self, sym: tuple) -> IV:
        """Best interval for a canonical expression: refinement table plus
        a structural recomputation over refined parts."""
        iv = self.refine.get(sym, TOP)
        g = self.ground(sym)
        if g != sym:
            iv = iv.meet(self.refine.get(g, TOP))
        iv = iv.meet(self._structural_iv(sym))
        return iv

    def _structural_iv(self, sym: tuple, depth: int = 0) -> IV:
        if depth > 8:
            return TOP
        tag = sym[0]
        if tag == "c":
            return const_iv(sym[1])
        if tag == "a":
            return self.refine.get(sym, TOP)
        sub = self.refine.get(sym)
        if sub is not None:
            return sub
        if tag == "+":
            iv = const_iv(sym[1])
            for t, c in sym[2]:
                ti = self._structural_iv(t, depth + 1).meet(
                    self.refine.get(t, TOP))
                iv = iv.add(ti.mul(const_iv(c)))
            return iv
        if tag == "*":
            iv = const_iv(sym[1])
            for f in sym[2]:
                fi = self._structural_iv(f, depth + 1).meet(
                    self.refine.get(f, TOP))
                iv = iv.mul(fi)
            return iv
        if tag in ("min", "max"):
            ivs = [self._structural_iv(a, depth + 1).meet(
                self.refine.get(a, TOP)) for a in sym[1]]
            if tag == "min":
                return IV(min(i.lo for i in ivs), min(i.hi for i in ivs))
            return IV(max(i.lo for i in ivs), max(i.hi for i in ivs))
        if tag == "?":
            return self._structural_iv(sym[1], depth + 1).join(
                self._structural_iv(sym[2], depth + 1))
        if tag == "<<":
            return self._structural_iv(sym[1], depth + 1).lshift(
                self._structural_iv(sym[2], depth + 1))
        if tag == "//":
            return self._structural_iv(sym[1], depth + 1).floordiv(
                self._structural_iv(sym[2], depth + 1))
        if tag == "%":
            return self._structural_iv(sym[1], depth + 1).mod(
                self._structural_iv(sym[2], depth + 1))
        return TOP

    def ground(self, sym: tuple, depth: int = 0) -> tuple:
        """Substitute singleton-interval subexpressions with their constant
        and re-canonicalize (so ``S + pad`` under ``pad == 0`` matches
        ``S``, including when ``pad`` is itself a ``%`` expression)."""
        if depth > 8 or not isinstance(sym, tuple):
            return sym
        tag = sym[0]
        if tag == "c":
            return sym
        known = self.refine.get(sym)
        if known is not None and known.is_const():
            return s_const(int(known.lo))
        if tag == "a":
            return sym
        if tag == "+":
            out = s_const(sym[1])
            for t, c in sym[2]:
                out = s_add(out, s_mul(self.ground(t, depth + 1), s_const(c)))
            return out
        if tag == "*":
            out = s_const(sym[1])
            for f in sym[2]:
                out = s_mul(out, self.ground(f, depth + 1))
            return out
        if tag in ("min", "max"):
            return (tag, tuple(sorted((self.ground(a, depth + 1)
                                       for a in sym[1]), key=repr)))
        if tag in ("//", "%", "<<", "?"):
            return (tag, self.ground(sym[1], depth + 1),
                    self.ground(sym[2], depth + 1))
        if tag == "call":
            return (tag, sym[1], tuple(self.ground(a, depth + 1)
                                       for a in sym[2]))
        return sym


# ---------------------------------------------------------------------------
# launch-proof helpers
# ---------------------------------------------------------------------------

def _covers(bound_fs: tuple, fs: tuple, env: Env) -> bool:
    """Does the recorded bound's factor multiset cover `fs`, with every
    uncovered extra factor provably >= 1 (a sub-product of a bounded
    product of >=1 factors is bounded)?"""
    remaining = list(bound_fs)
    for f in fs:
        if f in remaining:
            remaining.remove(f)
        else:
            return False
    return all(env.sym_iv(f).lo >= 1 for f in remaining)


def prove_count(aval: AVal, env: Env, bound: int = I32_MAX) -> bool:
    """Is this array's element count provably <= `bound` in `env`?"""
    if not isinstance(aval, AVal) or not aval.dims:
        return False
    iv = const_iv(1)
    syms = []
    for d in aval.dims:
        div = env.iv_of(d) if isinstance(d, SVal) else TOP
        iv = iv.mul(div.meet(NONNEG))
        syms.append(d.sym if isinstance(d, SVal) else None)
    if iv.hi <= bound:
        return True
    if any(s is None for s in syms):
        return False
    count = s_const(1)
    for s in syms:
        count = s_mul(count, s)
    if env.sym_iv(count).hi <= bound:
        return True
    coeff, fs = s_factors(env.ground(count))
    if coeff < 1:
        return False
    for bfs, bhi in env.prods:
        if bhi * 1 <= bound * 1 and _covers(
                tuple(env.ground(f) for f in bfs), fs, env) \
                and bhi * coeff <= bound:
            return True
    return False


def count_expr_str(aval: AVal, env: Env) -> str:
    """Human-readable element-count expression for a finding message."""
    if not isinstance(aval, AVal) or not aval.dims:
        return "<unknown shape>"
    return " * ".join(_render(d.sym) if isinstance(d, SVal) and d.sym
                      else "?" for d in aval.dims)


def _render(sym, depth: int = 0) -> str:
    if depth > 6 or not isinstance(sym, tuple):
        return "?"
    tag = sym[0]
    if tag == "c":
        return str(sym[1])
    if tag == "a":
        key = sym[1]
        if isinstance(key, tuple):
            if key and key[0] == "shape" and len(key) == 3:
                return f"{_render(key[1], depth + 1)}.shape[{key[2]}]"
            if key and key[0] == "attr" and len(key) == 3:
                return f"{_render(key[1], depth + 1)}.{key[2]}"
            if key and key[0] == "size" and len(key) == 2:
                return f"{_render(key[1], depth + 1)}.size"
            return "?"
        return str(key).split(":")[-1].split("#")[0] or str(key)
    if tag == "+":
        parts = [str(sym[1])] if sym[1] else []
        for t, c in sym[2]:
            parts.append(_render(t, depth + 1) if c == 1
                         else f"{c}*{_render(t, depth + 1)}")
        return "(" + " + ".join(parts) + ")"
    if tag == "*":
        parts = [str(sym[1])] if sym[1] != 1 else []
        parts += [_render(f, depth + 1) for f in sym[2]]
        return "*".join(parts)
    if tag in ("min", "max"):
        return f"{tag}({', '.join(_render(a, depth + 1) for a in sym[1])})"
    if tag in ("//", "%", "<<"):
        return f"({_render(sym[1], depth + 1)} {tag} " \
               f"{_render(sym[2], depth + 1)})"
    if tag == "call":
        return f"{sym[1]}(...)"
    return "?"


# ---------------------------------------------------------------------------
# the path-sensitive interpreter
# ---------------------------------------------------------------------------

_NP_HEADS = ("numpy", "jax.numpy")
_CTORS = {"zeros", "ones", "full", "empty"}
_ELEMWISE = {"log", "exp", "sqrt", "abs", "floor", "ceil", "maximum",
             "minimum", "where", "clip", "negative", "logical_not"}
_PASSTHRU = {"asarray", "ascontiguousarray", "array"}
_SHAPE_PRESERVING_METHODS = {"astype", "copy", "add", "set", "mul", "min",
                             "max", "multiply", "clip", "T"}


class _Return(Exception):
    pass


class FlowInterp:
    """Abstract interpreter for one function (plus straight-line helper
    summaries).  ``on_call(node, env, args, kwargs)`` fires at every Call
    evaluation in the *root* function — the rule's launch hook."""

    def __init__(self, index: ProjectIndex, module: ModuleInfo,
                 on_call: Optional[Callable] = None,
                 max_paths: int = 160, depth: int = 0):
        self.index = index
        self.module = module
        self.on_call = on_call
        self.max_paths = max_paths
        self.depth = depth
        self._paths = 0
        self._module_env: Optional[Env] = None

    # --- module environment ----------------------------------------------

    def module_env(self) -> Env:
        """Top-level constants evaluated once (sentinels like ``_I32_MAX =
        int(np.iinfo(np.int32).max)`` become concrete intervals)."""
        if self._module_env is None:
            env = Env()
            self._module_env = env
            for name, expr in self.module.constants.items():
                try:
                    v = self.eval(expr, env, hook=False)
                except Exception:
                    v = unknown_sval(f"const:{name}")
                if isinstance(v, (SVal, AVal)):
                    env.vars[name] = v
        return self._module_env

    # --- entry points ------------------------------------------------------

    def run_function(self, fn: ast.FunctionDef,
                     env: Optional[Env] = None) -> list:
        """Walk every path of `fn`; returns the list of returned abstract
        values (for summaries).  `env` pre-binds params/free names."""
        base = self.module_env().copy()
        if env is not None:
            base.vars.update(env.vars)
            base.refine.update(env.refine)
            base.prods.extend(env.prods)
            base.funcs.update(env.funcs)
        for p in _params(fn):
            base.vars.setdefault(p, SVal(TOP, s_atom(f"param:{p}")))
        returns: list = []
        self._paths = 0
        self.exec_block(list(fn.body), base, returns)
        return returns

    def summarize(self, fn: ast.FunctionDef, owner: ModuleInfo,
                  args: list, kwargs: dict, parent_env: Env):
        """Evaluate a callee under its own module context; join returns."""
        if self.depth >= 3:
            return unknown_sval("deep")
        sub = FlowInterp(self.index, owner, on_call=None,
                         max_paths=32, depth=self.depth + 1)
        env = Env()
        # closures see the caller's locals only for same-module nested defs
        if owner is self.module:
            env.vars = dict(parent_env.vars)
            env.refine = dict(parent_env.refine)
            env.prods = list(parent_env.prods)
            env.funcs = dict(parent_env.funcs)
        names = _param_list(fn)
        for i, a in enumerate(args):
            if i < len(names):
                env.vars[names[i]] = a
        for k, v in kwargs.items():
            if k in names:
                env.vars[k] = v
        defaults = fn.args.defaults
        dnames = names[len(names) - len(defaults):] if defaults else []
        for n, d in zip(dnames, defaults):
            if n not in env.vars:
                try:
                    env.vars[n] = sub.eval(d, env, hook=False)
                except Exception:
                    pass
        try:
            rets = sub.run_function(fn, env)
        except Exception:
            return unknown_sval("summary")
        return _join_values(rets)

    # --- statements ---------------------------------------------------------

    def exec_block(self, stmts: list, env: Env, returns: list) -> list[Env]:
        """Execute a statement list; returns fall-through path envs."""
        envs = [env]
        for i, stmt in enumerate(stmts):
            nxt: list[Env] = []
            for e in envs:
                nxt.extend(self.exec_stmt(stmt, e, returns))
            if len(nxt) > self.max_paths:
                nxt = [_join_envs(nxt)]
            envs = nxt
            if not envs:
                break
        return envs

    def exec_stmt(self, stmt: ast.stmt, env: Env,
                  returns: list) -> list[Env]:
        try:
            return self._exec_stmt(stmt, env, returns)
        except _Return:
            raise
        except Exception:
            return [env]

    def _exec_stmt(self, stmt, env: Env, returns: list) -> list[Env]:
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            self._assign(stmt, env)
            return [env]
        if isinstance(stmt, ast.Expr):
            self.eval(stmt.value, env)
            return [env]
        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                returns.append(self.eval(stmt.value, env))
            return []
        if isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self.eval(stmt.exc, env)
            return []
        if isinstance(stmt, (ast.Break, ast.Continue)):
            return []
        if isinstance(stmt, ast.If):
            self.eval(stmt.test, env)
            out: list[Env] = []
            te = env.copy()
            self.refine_cond(stmt.test, te, True)
            out.extend(self.exec_block(list(stmt.body), te, returns))
            fe = env
            self.refine_cond(stmt.test, fe, False)
            out.extend(self.exec_block(list(stmt.orelse), fe, returns))
            return out
        if isinstance(stmt, ast.Assert):
            self.eval(stmt.test, env)
            self.refine_cond(stmt.test, env, True)
            return [env]
        if isinstance(stmt, (ast.While, ast.For)):
            self._havoc_assigned(stmt, env)
            be = env.copy()
            if isinstance(stmt, ast.While):
                self.eval(stmt.test, be)
                self.refine_cond(stmt.test, be, True)
            else:
                self.eval(stmt.iter, be)
            self.exec_block(list(stmt.body), be, returns)  # visit launches
            return self.exec_block(list(stmt.orelse), env, returns) \
                if stmt.orelse else [env]
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self.eval(item.context_expr, env)
            return self.exec_block(list(stmt.body), env, returns)
        if isinstance(stmt, ast.Try):
            out = self.exec_block(list(stmt.body), env.copy(), returns)
            for h in stmt.handlers:
                out.extend(self.exec_block(list(h.body), env.copy(),
                                           returns))
            final: list[Env] = []
            for e in out or [env]:
                final.extend(self.exec_block(list(stmt.finalbody), e,
                                             returns))
            return final
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            env.funcs[stmt.name] = (stmt,)
            return [env]
        if isinstance(stmt, (ast.Import, ast.ImportFrom, ast.Pass,
                             ast.Global, ast.Nonlocal, ast.Delete,
                             ast.ClassDef)):
            return [env]
        # anything else: evaluate child expressions for hook coverage
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self.eval(child, env)
        return [env]

    def _assign(self, stmt, env: Env) -> None:
        if isinstance(stmt, ast.AugAssign):
            val = self.eval(ast.BinOp(left=stmt.target, op=stmt.op,
                                      right=stmt.value), env)
            if isinstance(stmt.target, ast.Name):
                env.vars[stmt.target.id] = val
            return
        value = stmt.value
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        if value is None:
            return
        # tuple-unpack of x.shape binds symbolic dims (and materializes x)
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                self._unpack(t, value, env)
            elif isinstance(t, ast.Name):
                env.vars[t.id] = self.eval(value, env)
            else:
                self.eval(value, env)   # subscript/attr store: shape-safe

    def _unpack(self, target, value, env: Env) -> None:
        elts = target.elts
        if isinstance(value, ast.Attribute) and value.attr == "shape":
            aval = self._materialize(value.value, env, rank=len(elts))
            if aval is not None:
                for i, el in enumerate(elts):
                    if isinstance(el, ast.Name) and i < len(aval.dims):
                        env.vars[el.id] = aval.dims[i]
                return
        if isinstance(value, (ast.Tuple, ast.List)) and \
                len(value.elts) == len(elts):
            for el, vexpr in zip(elts, value.elts):
                if isinstance(el, ast.Name):
                    env.vars[el.id] = self.eval(vexpr, env)
                else:
                    self.eval(vexpr, env)
            return
        self.eval(value, env)
        for el in elts:
            if isinstance(el, ast.Name):
                env.vars[el.id] = unknown_sval(f"unpack:{el.id}")

    def _materialize(self, expr, env: Env, rank: int) -> Optional[AVal]:
        """AVal for `expr` with at least `rank` dims, creating symbolic
        shape atoms on first access (stored back when expr is a Name)."""
        val = self.eval(expr, env, hook=False)
        if isinstance(val, AVal) and len(val.dims) >= rank:
            return val
        base_sym = val.sym if isinstance(val, (AVal, SVal)) and val.sym \
            else fresh_atom("arr")
        dims = tuple(SVal(NONNEG, s_atom(("shape", base_sym, i)))
                     for i in range(rank))
        for d in dims:
            env.meet_sym(d.sym, NONNEG)
        aval = AVal(dims, base_sym)
        if isinstance(expr, ast.Name):
            env.vars[expr.id] = aval
        return aval

    def _havoc_assigned(self, stmt, env: Env) -> None:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, (ast.Store,)):
                env.vars[node.id] = unknown_sval(f"loop:{node.id}")
            elif isinstance(node, ast.comprehension):
                for t in ast.walk(node.target):
                    if isinstance(t, ast.Name):
                        env.vars[t.id] = unknown_sval(f"loop:{t.id}")

    # --- expressions --------------------------------------------------------

    def eval(self, node, env: Env, hook: bool = True):
        try:
            return self._eval(node, env, hook)
        except _Return:
            raise
        except Exception:
            return unknown_sval("err")

    def _eval(self, node, env: Env, hook: bool):
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool):
                return SVal(IV(int(node.value), int(node.value)),
                            s_const(int(node.value)))
            if isinstance(node.value, int):
                return SVal(const_iv(node.value), s_const(node.value))
            return SVal(TOP, fresh_atom("const"))
        if isinstance(node, ast.Name):
            if node.id in env.vars:
                return env.vars[node.id]
            sym = s_atom(f"free:{node.id}")
            return SVal(TOP, sym)
        if isinstance(node, ast.BinOp):
            return self._binop(node, env, hook)
        if isinstance(node, ast.UnaryOp):
            v = self.eval(node.operand, env, hook)
            if isinstance(node.op, ast.USub) and isinstance(v, SVal):
                return SVal(env.iv_of(v).neg(),
                            s_neg(v.sym) if v.sym else None)
            return unknown_sval("unary")
        if isinstance(node, ast.Call):
            return self._call(node, env, hook)
        if isinstance(node, ast.Attribute):
            return self._attribute(node, env, hook)
        if isinstance(node, ast.Subscript):
            return self._subscript(node, env, hook)
        if isinstance(node, ast.IfExp):
            self.eval(node.test, env, hook)
            a = self.eval(node.body, env, hook)
            b = self.eval(node.orelse, env, hook)
            if isinstance(a, SVal) and isinstance(b, SVal):
                sym = a.sym if a.sym == b.sym else (
                    ("?", a.sym, b.sym) if a.sym and b.sym else None)
                return SVal(env.iv_of(a).join(env.iv_of(b)), sym)
            return unknown_sval("ifexp")
        if isinstance(node, ast.BoolOp):
            vals = [self.eval(v, env, hook) for v in node.values]
            svals = [v for v in vals if isinstance(v, SVal)]
            if svals:
                iv = svals[0].iv
                for v in svals[1:]:
                    iv = iv.join(env.iv_of(v))
                return SVal(iv, None)
            return unknown_sval("bool")
        if isinstance(node, ast.Compare):
            for e in [node.left, *node.comparators]:
                self.eval(e, env, hook)
            return SVal(IV(0, 1), None)
        if isinstance(node, (ast.Tuple, ast.List)):
            return tuple(self.eval(e, env, hook) for e in node.elts)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp,
                             ast.DictComp)):
            return unknown_sval("comp")
        if isinstance(node, ast.Starred):
            return self.eval(node.value, env, hook)
        if isinstance(node, ast.JoinedStr):
            return unknown_sval("fstr")
        if isinstance(node, ast.Lambda):
            return unknown_sval("lambda")
        return unknown_sval("expr")

    def _binop(self, node: ast.BinOp, env: Env, hook: bool):
        a = self.eval(node.left, env, hook)
        b = self.eval(node.right, env, hook)
        if not (isinstance(a, SVal) and isinstance(b, SVal)):
            return unknown_sval("binop")
        ia, ib = env.iv_of(a), env.iv_of(b)
        sa, sb = a.sym, b.sym
        op = node.op
        if isinstance(op, ast.Add):
            return SVal(ia.add(ib),
                        s_add(sa, sb) if sa and sb else None)
        if isinstance(op, ast.Sub):
            return SVal(ia.sub(ib),
                        s_sub(sa, sb) if sa and sb else None)
        if isinstance(op, ast.Mult):
            return SVal(ia.mul(ib),
                        s_mul(sa, sb) if sa and sb else None)
        if isinstance(op, ast.FloorDiv):
            return SVal(ia.floordiv(ib),
                        ("//", sa, sb) if sa and sb else None)
        if isinstance(op, ast.Mod):
            return SVal(ia.mod(ib), ("%", sa, sb) if sa and sb else None)
        if isinstance(op, ast.LShift):
            return SVal(ia.lshift(ib),
                        ("<<", sa, sb) if sa and sb else None)
        if isinstance(op, ast.Pow):
            # constant integer powers only (2**31 - 1 sentinels)
            if ia.lo == ia.hi and ib.lo == ib.hi and ib.lo >= 0 and \
                    ia.lo == int(ia.lo) and ib.lo == int(ib.lo) and \
                    ib.lo <= 64:
                c = int(ia.lo) ** int(ib.lo)
                return SVal(IV(c, c), s_const(c))
            return unknown_sval("binop")
        if isinstance(op, ast.Div):
            return SVal(TOP, None)
        return unknown_sval("binop")

    def _resolved(self, func, env: Env) -> Optional[str]:
        from .modules import dotted
        parts = dotted(func)
        if parts is None:
            return None
        if parts[0] in env.vars or parts[0] in env.funcs:
            return None
        return self.index.resolve(self.module, ".".join(parts)) or \
            ".".join([self.module.imports.get(parts[0], parts[0])]
                     + parts[1:])

    def _call(self, node: ast.Call, env: Env, hook: bool):
        args = [self.eval(a, env, hook) for a in node.args]
        kwargs = {k.arg: self.eval(k.value, env, hook)
                  for k in node.keywords if k.arg}
        func = node.func
        if hook and self.on_call is not None:
            self.on_call(node, env, args, kwargs)
        # module-qualified / project calls dispatch on the resolved FQN
        # (tried first so np.zeros is a constructor, not a method on np)
        fqn = self._resolved(func, env)
        if fqn:
            out = self._fqn_call(fqn, node, args, kwargs, env)
            if out is not None:
                return out
        # method calls on values -------------------------------------------
        if isinstance(func, ast.Attribute):
            base = self.eval(func.value, env, hook=False)
            name = func.attr
            if isinstance(base, AtRef):
                base = base.aval
            if isinstance(base, AVal):
                if name in _SHAPE_PRESERVING_METHODS:
                    return base
                if name in ("max", "min", "sum", "prod") and node.keywords:
                    return SVal(TOP, ("call", f".{name}",
                                      (base.sym,) + _syms(args)))
                return SVal(TOP, ("call", f".{name}",
                                  (base.sym,) + _syms(args)))
            if isinstance(base, SVal):
                if name == "bit_length":
                    return SVal(IV(0, 66),
                                ("call", ".bit_length", (base.sym,))
                                if base.sym else None)
                return SVal(TOP, ("call", f".{name}",
                                  (base.sym,) + _syms(args))
                            if base.sym else None)
            return unknown_sval("method")
        # builtins and local defs ------------------------------------------
        if isinstance(func, ast.Name):
            if func.id in env.funcs:
                return self.summarize(env.funcs[func.id][0], self.module,
                                      args, kwargs, env)
            if func.id == "len":
                if args and isinstance(args[0], AVal) and args[0].dims:
                    return args[0].dims[0]
                return SVal(NONNEG, ("call", "len", _syms(args))
                            if all(s is not None for s in _syms(args))
                            else None)
            if func.id in ("min", "max") and len(args) >= 2 and \
                    all(isinstance(a, SVal) for a in args):
                ivs = [env.iv_of(a) for a in args]
                syms = _syms(args)
                if func.id == "min":
                    iv = IV(min(i.lo for i in ivs), min(i.hi for i in ivs))
                else:
                    iv = IV(max(i.lo for i in ivs), max(i.hi for i in ivs))
                sym = (func.id, tuple(sorted(syms, key=repr))) \
                    if all(s is not None for s in syms) else None
                return SVal(iv, sym)
            if func.id in ("int", "abs", "float", "round"):
                if args and isinstance(args[0], SVal):
                    if func.id == "abs":
                        iv = env.iv_of(args[0])
                        lo = 0 if iv.lo < 0 else iv.lo
                        return SVal(IV(lo, max(abs(iv.lo), abs(iv.hi))),
                                    None)
                    return args[0]
                if args and isinstance(args[0], AVal):
                    return SVal(NONNEG, None)
                return unknown_sval(func.id)
            if func.id == "bool":
                return SVal(IV(0, 1), None)
        return SVal(TOP, ("call", fqn or "?", _syms(args))
                    if all(s is not None for s in _syms(args)) else None)

    def _fqn_call(self, fqn: str, node, args, kwargs, env: Env):
        """Dispatch a call by absolute dotted name; None = not handled."""
        head, tail = fqn.rsplit(".", 1) if "." in fqn else ("", fqn)
        if head in _NP_HEADS or head.endswith(".numpy"):
            return self._np_call(tail, node, args, kwargs, env)
        if tail in ("iinfo", "finfo") and (head.startswith("numpy")
                                           or head.startswith("jax")):
            return ("iinfo", args[0] if args else None)
        if head.startswith("numpy") or head.startswith("jax"):
            # np.int64(x) / np.int32(x): value-preserving casts
            if tail in ("int64", "int32", "int16", "int8") and args \
                    and isinstance(args[0], SVal):
                return args[0]
            return unknown_sval(tail)
        owner, fndef = self.index.lookup_function(fqn)
        if fndef is not None and owner is not None:
            return self.summarize(fndef, owner, args, kwargs, env)
        return None

    def _np_call(self, tail: str, node, args, kwargs, env: Env):
        if tail in _CTORS:
            shape = args[0] if args else None
            dims = _as_dims(shape)
            if dims is not None:
                return AVal(tuple(dims), fresh_atom(f"np.{tail}"))
            return unknown_aval(f"np.{tail}")
        if tail == "pad":
            arr = args[0] if args else None
            pads = node.args[1] if len(node.args) > 1 else None
            if isinstance(arr, AVal) and arr.dims and \
                    isinstance(pads, (ast.Tuple, ast.List)) and \
                    len(pads.elts) == len(arr.dims):
                dims = []
                for d, p in zip(arr.dims, pads.elts):
                    if isinstance(p, (ast.Tuple, ast.List)) and \
                            len(p.elts) == 2:
                        lo = self.eval(p.elts[0], env, hook=False)
                        hi = self.eval(p.elts[1], env, hook=False)
                        if isinstance(lo, SVal) and isinstance(hi, SVal) \
                                and d.sym and lo.sym and hi.sym:
                            iv = env.iv_of(d).add(env.iv_of(lo)) \
                                .add(env.iv_of(hi))
                            dims.append(SVal(iv.meet(NONNEG),
                                             s_add(d.sym,
                                                   s_add(lo.sym, hi.sym))))
                            continue
                    dims.append(unknown_sval("paddim"))
                return AVal(tuple(dims), fresh_atom("np.pad"))
            return unknown_aval("np.pad")
        if tail in _PASSTHRU:
            if args and isinstance(args[0], AVal):
                return args[0]
            if args and isinstance(args[0], SVal):
                base = args[0].sym or fresh_atom("asarray")
                return AVal((), ("call", "asarray", (base,)))
            return unknown_aval(tail)
        if tail in _ELEMWISE:
            for a in args:
                if isinstance(a, AVal):
                    return AVal(a.dims, fresh_atom(f"np.{tail}"))
            return unknown_sval(tail)
        if tail in ("iinfo", "finfo"):
            return ("iinfo", args[0] if args else None)
        if tail in ("int64", "int32"):
            return args[0] if args and isinstance(args[0], SVal) \
                else unknown_sval(tail)
        if tail in ("searchsorted", "cumsum", "arange", "nonzero",
                    "bincount", "concatenate", "stack"):
            return unknown_aval(f"np.{tail}")
        return unknown_sval(f"np.{tail}")

    def _attribute(self, node: ast.Attribute, env: Env, hook: bool):
        # np.iinfo(np.int32).max -> 2**31 - 1
        if node.attr in ("max", "min") and isinstance(node.value, ast.Call):
            inner = self.eval(node.value, env, hook=False)
            if isinstance(inner, tuple) and len(inner) == 2 and \
                    inner[0] == "iinfo":
                bits = _dtype_bits(node.value)
                if bits:
                    v = 2 ** (bits - 1) - 1 if node.attr == "max" \
                        else -(2 ** (bits - 1))
                    return SVal(const_iv(v), s_const(v))
        base = self.eval(node.value, env, hook=False)
        if node.attr == "shape":
            return ShapeRef(base, node.value.id
                            if isinstance(node.value, ast.Name) else None)
        if isinstance(base, AVal):
            if node.attr == "size":
                if base.dims and all(isinstance(d, SVal) and d.sym
                                     for d in base.dims):
                    sym = s_const(1)
                    iv = const_iv(1)
                    for d in base.dims:
                        sym = s_mul(sym, d.sym)
                        iv = iv.mul(env.iv_of(d).meet(NONNEG))
                    return SVal(iv, sym)
                return SVal(NONNEG, s_atom(("size", base.sym)))
            if node.attr == "T":
                return base
            if node.attr == "at":
                return AtRef(base)
            if node.attr == "dtype":
                return unknown_sval("dtype")
        # module-level constant via import (e.g. other_mod._I32_MAX)
        from .modules import dotted
        parts = dotted(node)
        if parts is not None:
            fqn = self.index.resolve(self.module, ".".join(parts))
            owner, cexpr = self.index.lookup_constant(fqn)
            if cexpr is not None and owner is not None and \
                    owner is not self.module:
                sub = FlowInterp(self.index, owner, max_paths=8,
                                 depth=self.depth + 1)
                return sub.eval(cexpr, sub.module_env(), hook=False)
        if isinstance(base, SVal) and base.sym:
            return SVal(TOP, s_atom(("attr", base.sym, node.attr)))
        return unknown_sval(f"attr:{node.attr}")

    def _subscript(self, node: ast.Subscript, env: Env, hook: bool):
        base = self.eval(node.value, env, hook)
        sl = node.slice
        if isinstance(base, AtRef):
            return base.aval
        if isinstance(base, ShapeRef):
            rank = None
            if isinstance(sl, ast.Constant) and isinstance(sl.value, int):
                rank = sl.value + 1
            if rank is not None:
                aval = self._materialize(node.value.value, env, rank=rank) \
                    if isinstance(node.value, ast.Attribute) else None
                if aval is not None and len(aval.dims) >= rank:
                    return aval.dims[rank - 1]
            return unknown_sval("shape")
        if isinstance(base, AVal) and base.dims:
            if isinstance(sl, ast.Slice):
                return AVal((self._slice_dim(base.dims[0], sl, env),)
                            + base.dims[1:], fresh_atom("slice"))
            if isinstance(sl, ast.Tuple):
                dims = list(base.dims)
                out = []
                for i, s in enumerate(sl.elts):
                    if i >= len(dims):
                        break
                    if isinstance(s, ast.Slice):
                        out.append(self._slice_dim(dims[i], s, env))
                    # plain index drops the dim
                return AVal(tuple(out) + tuple(dims[len(sl.elts):]),
                            fresh_atom("slice"))
            if isinstance(sl, ast.Constant) or isinstance(sl, ast.Name):
                return AVal(base.dims[1:], fresh_atom("index")) \
                    if len(base.dims) > 1 else unknown_sval("elt")
        if isinstance(base, tuple) and not isinstance(base, (SVal, AVal)) \
                and isinstance(sl, ast.Constant) and \
                isinstance(sl.value, int) and sl.value < len(base):
            return base[sl.value]
        return unknown_sval("sub")

    def _slice_dim(self, dim: SVal, sl: ast.Slice, env: Env) -> SVal:
        if sl.lower is None and sl.step is None and sl.upper is not None:
            up = self.eval(sl.upper, env, hook=False)
            if isinstance(up, SVal):
                upi = env.iv_of(up).meet(NONNEG)
                # x[:n] has dim min(len, n); equals n when 0 <= n <= len
                if up.sym is not None and dim.sym is not None:
                    diff = env.sym_iv(s_sub(dim.sym, up.sym))
                    if diff.lo >= 0 and upi.lo >= 0:
                        return SVal(upi, up.sym)
                return SVal(IV(0, min(env.iv_of(dim).hi, upi.hi)),
                            ("min", tuple(sorted((dim.sym, up.sym),
                                                 key=repr)))
                            if dim.sym and up.sym else None)
        if sl.lower is None and sl.upper is None and sl.step is None:
            return dim
        return unknown_sval("dim")

    # --- condition refinement ----------------------------------------------

    def refine_cond(self, test, env: Env, truth: bool) -> None:
        try:
            self._refine(test, env, truth)
        except Exception:
            pass

    def _refine(self, test, env: Env, truth: bool) -> None:
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return self._refine(test.operand, env, not truth)
        if isinstance(test, ast.BoolOp):
            if (isinstance(test.op, ast.And) and truth) or \
                    (isinstance(test.op, ast.Or) and not truth):
                for v in test.values:
                    self._refine(v, env, truth)
            return
        if isinstance(test, ast.Name):
            val = env.vars.get(test.id)
            if isinstance(val, SVal):
                iv = IV(0, 0) if not truth else (
                    IV(1, INF) if env.iv_of(val).lo >= 0 else TOP)
                nv = SVal(env.iv_of(val).meet(iv), val.sym)
                env.vars[test.id] = nv
                if val.sym:
                    env.meet_sym(val.sym, nv.iv)
            return
        if not isinstance(test, ast.Compare) or len(test.ops) != 1:
            return
        op = test.ops[0]
        left, right = test.left, test.comparators[0]
        if not truth:
            op = _NEG.get(type(op))
            if op is None:
                return
        else:
            op = type(op)
        if op in (ast.Eq,) and self._refine_shape_eq(left, right, env):
            return
        lv = self.eval(left, env, hook=False)
        rv = self.eval(right, env, hook=False)
        if isinstance(lv, SVal) and isinstance(rv, SVal):
            self._refine_rel(lv, op, env.iv_of(rv), env)
            self._refine_rel(rv, _FLIP[op], env.iv_of(lv), env)

    def _refine_rel(self, val: SVal, op, other: IV, env: Env) -> None:
        if op is ast.Lt and other.hi != INF:
            bound = IV(-INF, other.hi - 1)
        elif op is ast.LtE and other.hi != INF:
            bound = IV(-INF, other.hi)
        elif op is ast.Gt and other.lo != -INF:
            bound = IV(other.lo + 1, INF)
        elif op is ast.GtE and other.lo != -INF:
            bound = IV(other.lo, INF)
        elif op is ast.Eq:
            bound = other
        else:
            return
        if val.sym is None:
            return
        env.meet_sym(val.sym, bound)
        self._record_bound(val.sym, bound, env)

    def _record_bound(self, sym, bound: IV, env: Env,
                      depth: int = 0) -> None:
        """Product bounds + max-splitting: ``max(a, b) <= H`` bounds both;
        ``a * b <= H`` is recorded as a factor-multiset bound."""
        if depth > 4 or not bound.hi < INF:
            return
        if sym[0] == "max":
            for a in sym[1]:
                env.meet_sym(a, IV(-INF, bound.hi))
                self._record_bound(a, bound, env, depth + 1)
            return
        coeff, fs = s_factors(sym)
        if len(fs) >= 2 and coeff >= 1:
            env.prods.append((fs, bound.hi // coeff))

    def _refine_shape_eq(self, left, right, env: Env) -> bool:
        """x.shape == (a, b, ...) and x.shape == y.shape refinements."""
        if isinstance(right, ast.Attribute) and right.attr == "shape" and \
                not (isinstance(left, ast.Attribute)
                     and left.attr == "shape"):
            left, right = right, left
        if not (isinstance(left, ast.Attribute) and left.attr == "shape"):
            return False
        if isinstance(right, (ast.Tuple, ast.List)):
            dims = []
            ok = True
            for el in right.elts:
                v = self.eval(el, env, hook=False)
                if isinstance(v, SVal):
                    if v.sym is not None:
                        env.meet_sym(v.sym, NONNEG)
                    dims.append(SVal(env.iv_of(v).meet(NONNEG), v.sym))
                else:
                    ok = False
                    break
            if ok and isinstance(left.value, ast.Name):
                prev = env.vars.get(left.value.id)
                sym = prev.sym if isinstance(prev, (AVal, SVal)) and \
                    prev.sym else fresh_atom("arr")
                env.vars[left.value.id] = AVal(tuple(dims), sym)
                return True
        if isinstance(right, ast.Attribute) and right.attr == "shape":
            rv = self.eval(right.value, env, hook=False)
            if isinstance(rv, AVal) and rv.dims and \
                    isinstance(left.value, ast.Name):
                prev = env.vars.get(left.value.id)
                sym = prev.sym if isinstance(prev, (AVal, SVal)) and \
                    prev.sym else fresh_atom("arr")
                env.vars[left.value.id] = AVal(rv.dims, sym)
                return True
        return False


_NEG = {ast.Lt: ast.GtE, ast.LtE: ast.Gt, ast.Gt: ast.LtE,
        ast.GtE: ast.Lt, ast.Eq: ast.NotEq, ast.NotEq: ast.Eq}
_FLIP = {ast.Lt: ast.Gt, ast.LtE: ast.GtE, ast.Gt: ast.Lt,
         ast.GtE: ast.LtE, ast.Eq: ast.Eq, ast.NotEq: ast.NotEq}


def _params(fn) -> list[str]:
    return _param_list(fn)


def _param_list(fn) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _dtype_bits(call: ast.Call) -> Optional[int]:
    """Bit width named by an ``iinfo(np.int32)``-style argument."""
    if not call.args:
        return None
    arg = call.args[0]
    name = None
    if isinstance(arg, ast.Attribute):
        name = arg.attr
    elif isinstance(arg, ast.Name):
        name = arg.id
    elif isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        name = arg.value
    if name and name.startswith("int") and name[3:].isdigit():
        return int(name[3:])
    if name and name.startswith("uint") and name[4:].isdigit():
        return int(name[4:]) + 1
    return None


def _syms(args: list) -> tuple:
    return tuple(a.sym if isinstance(a, (SVal, AVal)) else None
                 for a in args)


def _as_dims(shape) -> Optional[list]:
    if isinstance(shape, tuple) and not isinstance(shape, (SVal, AVal)):
        dims = []
        for d in shape:
            if not isinstance(d, SVal):
                return None
            dims.append(SVal(d.iv.meet(NONNEG), d.sym))
        return dims
    if isinstance(shape, SVal):
        return [SVal(shape.iv.meet(NONNEG), shape.sym)]
    return None


def _join_values(vals: list):
    vals = [v for v in vals if isinstance(v, (SVal, AVal))]
    if not vals:
        return unknown_sval("ret")
    if all(isinstance(v, AVal) for v in vals):
        first = vals[0]
        if all(len(v.dims) == len(first.dims) for v in vals):
            dims = []
            for i, d in enumerate(first.dims):
                ds = [v.dims[i] for v in vals]
                iv = ds[0].iv
                for x in ds[1:]:
                    iv = iv.join(x.iv)
                sym = d.sym if all(x.sym == d.sym for x in ds) else None
                dims.append(SVal(iv, sym))
            return AVal(tuple(dims), first.sym)
        return unknown_aval("ret")
    if all(isinstance(v, SVal) for v in vals):
        iv = vals[0].iv
        sym = vals[0].sym
        for v in vals[1:]:
            iv = iv.join(v.iv)
            if v.sym != sym:
                sym = None
        return SVal(iv, sym)
    return unknown_sval("ret")


def _join_envs(envs: list[Env]) -> Env:
    out = envs[0]
    for e in envs[1:]:
        for k, v in list(out.vars.items()):
            ov = e.vars.get(k)
            if isinstance(v, SVal) and isinstance(ov, SVal):
                out.vars[k] = SVal(v.iv.join(ov.iv),
                                   v.sym if v.sym == ov.sym else None)
            elif isinstance(v, AVal) and isinstance(ov, AVal) and \
                    v.dims == ov.dims:
                pass
            elif ov is not v:
                out.vars[k] = unknown_sval(f"join:{k}")
        out.refine = {k: iv.join(e.refine[k])
                      for k, iv in out.refine.items() if k in e.refine}
        out.prods = [p for p in out.prods if p in e.prods]
    return out
