"""No-new-findings ratchet against a checked-in baseline.

The baseline file (``analysis_baseline.json`` at the repo root) records
the accepted findings as ``(rule, path, message)`` triples — deliberately
*line-insensitive*, so unrelated edits that shift a known finding do not
trip CI, while any new finding (or a message change, which means the
analysis got more precise) does.

``diff`` is a two-sided ratchet:

* **new** — unsuppressed findings not in the baseline: the gate CI fails
  on.
* **stale** — baseline entries no longer reported: the finding was fixed
  (or the rule tightened) but the baseline was not refreshed.  CI fails
  on these too, so the baseline can only ever shrink to match reality,
  never accumulate dead entries that would mask a regression at the same
  location later.

Refresh with ``python -m repro.analysis --update-baseline`` after fixing
findings (the normal direction) or after accepting a new finding with a
written rationale in review (the exceptional one).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from . import Finding, Report

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analysis_baseline.json"

__all__ = ["BaselineDiff", "diff", "load", "write", "DEFAULT_BASELINE"]


def _key(entry: dict) -> tuple[str, str, str]:
    return (str(entry.get("rule", "")), str(entry.get("path", "")),
            str(entry.get("message", "")))


def _finding_key(f: "Finding") -> tuple[str, str, str]:
    return (f.rule, f.path, f.message)


def load(path: str | Path) -> list[dict]:
    """Baseline entries; [] for a missing file, error on a malformed one."""
    p = Path(path)
    if not p.exists():
        return []
    data = json.loads(p.read_text())
    if not isinstance(data, dict) or "findings" not in data:
        raise ValueError(f"malformed baseline {p}: expected an object with "
                         "a 'findings' list")
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {p} has version {data.get('version')!r};"
                         f" this checker writes version {BASELINE_VERSION}")
    return list(data["findings"])


def write(path: str | Path, report: "Report") -> None:
    """Record the report's unsuppressed findings as the new baseline."""
    entries = sorted(
        ({"rule": f.rule, "path": f.path, "message": f.message}
         for f in report.unsuppressed),
        key=_key)
    Path(path).write_text(json.dumps(
        {"version": BASELINE_VERSION, "findings": entries},
        indent=2, sort_keys=True) + "\n")


@dataclass
class BaselineDiff:
    """Ratchet outcome: both lists must be empty for CI to pass."""

    new: list = field(default_factory=list)      # Finding
    stale: list = field(default_factory=list)    # baseline entry dicts

    def ok(self) -> bool:
        return not self.new and not self.stale


def diff(report: "Report", entries: list[dict]) -> BaselineDiff:
    """Compare unsuppressed findings against baseline entries.

    Matching is multiset-aware: two identical findings in the report
    consume two identical baseline entries, so a duplicated regression
    at a second call site still surfaces as new.
    """
    budget: dict[tuple[str, str, str], int] = {}
    for e in entries:
        k = _key(e)
        budget[k] = budget.get(k, 0) + 1
    out = BaselineDiff()
    for f in report.unsuppressed:
        k = _finding_key(f)
        if budget.get(k, 0) > 0:
            budget[k] -= 1
        else:
            out.new.append(f)
    for e in entries:
        k = _key(e)
        if budget.get(k, 0) > 0:
            budget[k] -= 1
            out.stale.append(e)
    return out
