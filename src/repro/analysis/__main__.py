"""CLI: ``PYTHONPATH=src python -m repro.analysis [--strict] [paths...]``.

Prints one block per finding (``path:line: rule: message`` + fix hint)
and a summary line; ``--strict`` exits 1 on any unsuppressed finding not
recorded in the baseline, and on stale baseline entries (the
no-new-findings ratchet the ``static-analysis`` CI job enforces).
``--sarif`` writes a SARIF 2.1.0 log, ``--github`` prints GitHub Actions
workflow annotations.  Default paths: ``src benchmarks``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import available, names, scan_paths
from . import baseline as baseline_mod
from .baseline import DEFAULT_BASELINE
from .sarif import to_sarif


def _github_annotation(f) -> str:
    # newlines are %0A-escaped per the workflow-command grammar
    msg = (f.message + (f" [fix: {f.hint}]" if f.hint else "")).replace(
        "%", "%25").replace("\r", "%0D").replace("\n", "%0A")
    return (f"::error file={f.path},line={f.line},"
            f"title=repro-analysis {f.rule}::{msg}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-aware static contract checker: syntactic rules "
                    "(rng-discipline, backend-dispatch, overflow-guard, "
                    "jit-purity, frozen-core-types, registry-consistency, "
                    "pragma-discipline) plus whole-program dataflow rules "
                    "(overflow-range, tracer-taint, cache-key)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to scan (default: src "
                         "benchmarks)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on findings above the baseline or stale "
                         "baseline entries")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array")
    ap.add_argument("--sarif", metavar="FILE", default=None,
                    help="write a SARIF 2.1.0 log to FILE ('-' for stdout)")
    ap.add_argument("--github", action="store_true",
                    help="print GitHub Actions ::error annotations for "
                         "findings above the baseline")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE} "
                         "under --root when present)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run's findings "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="RULE",
                    help="run only this rule (repeatable; default: all)")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths / pragma lookup "
                         "(default: cwd)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print pragma-suppressed findings")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, doc in available().items():
            print(f"{name:22s} {doc}")
        return 0
    if args.rule:
        unknown = sorted(set(args.rule) - set(names()))
        if unknown:
            ap.error(f"unknown rule(s) {unknown}; see --list-rules")

    report = scan_paths(args.paths or ["src", "benchmarks"],
                        root=args.root, rules=args.rule)

    root = Path(args.root or ".")
    baseline_path = Path(args.baseline) if args.baseline \
        else root / DEFAULT_BASELINE
    if args.update_baseline:
        baseline_mod.write(baseline_path, report)
        print(f"baseline {baseline_path} updated: "
              f"{len(report.unsuppressed)} finding(s) recorded")
        return 0
    try:
        entries = baseline_mod.load(baseline_path)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    bdiff = baseline_mod.diff(report, entries)

    if args.sarif:
        log = to_sarif(report, available())
        text = json.dumps(log, indent=2, sort_keys=True)
        if args.sarif == "-":
            print(text)
        else:
            Path(args.sarif).write_text(text + "\n")
    if args.github:
        for f in bdiff.new:
            print(_github_annotation(f))
        for e in bdiff.stale:
            print("::error title=repro-analysis stale-baseline::baseline "
                  f"entry no longer reproduces: {e.get('rule')} at "
                  f"{e.get('path')}; run --update-baseline")

    if args.json:
        print(json.dumps([f.to_dict() for f in report.findings], indent=2))
    else:
        shown = report.findings if args.show_suppressed \
            else report.unsuppressed
        for f in shown:
            print(f.render())
        for e in bdiff.stale:
            print(f"stale baseline entry (no longer reproduces): "
                  f"{e.get('rule')}: {e.get('path')}: {e.get('message')}")
        baselined = len(report.unsuppressed) - len(bdiff.new)
        extra = f", {baselined} baselined" if baselined else ""
        print(f"checked {report.n_files} files: "
              f"{len(report.unsuppressed)} finding(s){extra}, "
              f"{len(report.suppressed)} suppressed")

    return 1 if (args.strict and not bdiff.ok()) else 0


if __name__ == "__main__":
    sys.exit(main())
