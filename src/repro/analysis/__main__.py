"""CLI: ``PYTHONPATH=src python -m repro.analysis [--strict] [paths...]``.

Prints one block per finding (``path:line: rule: message`` + fix hint)
and a summary line; ``--strict`` exits 1 on any unsuppressed finding
(the contract the ``static-analysis`` CI job enforces).  Default paths:
``src benchmarks``.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import available, names, scan_paths


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-aware static contract checker (rng-discipline, "
                    "backend-dispatch, overflow-guard, jit-purity, "
                    "frozen-core-types, registry-consistency, "
                    "pragma-discipline)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to scan (default: src "
                         "benchmarks)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unsuppressed finding")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="RULE",
                    help="run only this rule (repeatable; default: all)")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths / pragma lookup "
                         "(default: cwd)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print pragma-suppressed findings")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, doc in available().items():
            print(f"{name:22s} {doc}")
        return 0
    if args.rule:
        unknown = sorted(set(args.rule) - set(names()))
        if unknown:
            ap.error(f"unknown rule(s) {unknown}; see --list-rules")

    report = scan_paths(args.paths or ["src", "benchmarks"],
                        root=args.root, rules=args.rule)
    if args.json:
        print(json.dumps([f.to_dict() for f in report.findings], indent=2))
    else:
        shown = report.findings if args.show_suppressed \
            else report.unsuppressed
        for f in shown:
            print(f.render())
        print(f"checked {report.n_files} files: "
              f"{len(report.unsuppressed)} finding(s), "
              f"{len(report.suppressed)} suppressed")
    return 1 if (args.strict and not report.ok()) else 0


if __name__ == "__main__":
    sys.exit(main())
