"""Repo-aware static contract checker (`python -m repro.analysis`).

Every guarantee this reproduction ships — bit-identical batched BNA,
jit-vs-python transcript identity, group-granular repair certification —
rests on conventions nothing in the type system enforces: seeded RNG
streams, kernels reached only through ``core/backend.py`` dispatch, int32
overflow guards with numpy fallbacks, side-effect-free jitted stage
bodies, and core result types treated as immutable outside their defining
modules.  This package machine-checks those conventions the same way the
scheduler and scenario registries machine-check their options: a
string-keyed **rule registry** (`register` / `get` / `names` /
`available`, mirroring ``core/engine.py``), an AST scan engine, and a CLI
(``__main__.py``) that exits non-zero under ``--strict`` on any
unsuppressed finding — the ``static-analysis`` CI job keeps the tree at
zero forever.

Rules ship in ``rules/`` (one module per contract); adding one is one
decorator::

    from repro.analysis import Finding, register_rule

    @register_rule("my-rule", "one-line contract description")
    def _my_rule(ctx):                  # ctx: FileContext
        for node in ast.walk(ctx.tree):
            ...
            yield ctx.finding("my-rule", node, "message", hint="fix hint")

Intentional exceptions are annotated inline and MUST carry a one-line
justification (the ``pragma-discipline`` rule rejects bare pragmas)::

    from repro.kernels.bna_step.ops import bna_step_batch  # repro: allow(backend-dispatch): this IS the resolved dispatch site

File-scope rules like the one above see one ``FileContext`` at a time.
Rules registered with ``scope="program"`` instead receive a
:class:`ProgramContext` — every scanned file plus a lazily-built
whole-program symbol index — and run on the dataflow layer in
:mod:`repro.analysis.flow` (interval/shape abstract interpretation,
interprocedural taint, call-graph reachability).  The
``repro.analysis.flow`` package docstring is the step-by-step guide to
writing one.  ``--strict`` gates against the checked-in
``analysis_baseline.json`` ratchet (new findings fail, stale entries
fail, the baseline only shrinks); ``--sarif`` / ``--github`` emit
machine-readable output for CI.

See the README "Static analysis" section for the rule table.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterable, Iterator

from .pragmas import PRAGMA_RE, parse_allows

__all__ = [
    "Finding",
    "FileContext",
    "ProgramContext",
    "Rule",
    "Report",
    "register_rule",
    "get",
    "names",
    "available",
    "scan_paths",
    "iter_python_files",
]


@dataclass(frozen=True)
class Finding:
    """One contract violation: rule id, location, message, fix hint."""

    rule: str
    path: str        # scan-root-relative posix path
    line: int
    message: str
    hint: str = ""
    suppressed: bool = False

    def render(self) -> str:
        s = " [suppressed]" if self.suppressed else ""
        out = f"{self.path}:{self.line}: {self.rule}: {self.message}{s}"
        if self.hint:
            out += f"\n    fix: {self.hint}"
        return out

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "hint": self.hint,
                "suppressed": self.suppressed}


@dataclass
class FileContext:
    """Everything a file-scope rule sees: source, AST, and the repo-relative
    path the repo-aware rules key their applicability on (``tests/...``,
    ``src/repro/kernels/<k>/ops.py``, ...)."""

    path: Path                 # absolute
    rel: str                   # scan-root-relative posix path
    source: str
    tree: ast.AST
    lines: list[str] = field(default_factory=list)

    # --- repo-aware path classification (shared by the rules) -------------
    def in_testing(self) -> bool:
        """tests/ and the repro.testing shim package are test code."""
        return (self.rel.startswith("tests/") or "/tests/" in self.rel
                or "repro/testing/" in self.rel)

    def in_benchmarks(self) -> bool:
        return self.rel.startswith("benchmarks/") or "/benchmarks/" in self.rel

    def in_kernels(self) -> bool:
        return "repro/kernels/" in self.rel

    def in_core(self) -> bool:
        return "repro/core/" in self.rel

    def basename(self) -> str:
        return self.rel.rsplit("/", 1)[-1]

    def finding(self, rule: str, node: ast.AST | int, message: str,
                hint: str = "") -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 1)
        return Finding(rule, self.rel, line, message, hint)


@dataclass
class ProgramContext:
    """Everything a program-scope (dataflow) rule sees: every scanned
    FileContext plus a lazily-built whole-program symbol index
    (:class:`repro.analysis.flow.modules.ProjectIndex`).  See the
    :mod:`repro.analysis.flow` docstring for the rule-writing guide."""

    files: list[FileContext]
    _index: object = None

    @property
    def index(self):
        if self._index is None:
            from .flow.modules import ProjectIndex
            self._index = ProjectIndex(self.files)
        return self._index


_CheckFn = Callable[..., "Iterable[Finding]"]


@dataclass(frozen=True)
class Rule:
    """A registry entry: named contract + its checker.

    scope="file" checkers receive a FileContext per scanned file;
    scope="project" checkers run once per scan (inspect-based rules that
    import the live registries) and receive no arguments;
    scope="program" checkers run once per scan over a ProgramContext
    (whole-program dataflow rules)."""

    name: str
    doc: str
    check: _CheckFn
    scope: str = "file"


_REGISTRY: dict[str, Rule] = {}


def register_rule(name: str, doc: str = "", scope: str = "file"):
    """Register ``check(ctx) -> Iterable[Finding]`` under ``name``
    (decorator) — the scheduler-registry idiom applied to lint rules."""
    if scope not in ("file", "project", "program"):
        raise ValueError(
            f"rule scope must be file|project|program, got {scope!r}")

    def deco(check: _CheckFn) -> _CheckFn:
        if name in _REGISTRY:
            raise ValueError(f"rule {name!r} already registered")
        _REGISTRY[name] = Rule(name, doc or (check.__doc__ or "").strip(),
                               check, scope)
        return check

    return deco


def get(name: str) -> Rule:
    _load_rules()
    if name not in _REGISTRY:
        raise KeyError(f"unknown rule {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    _load_rules()
    return sorted(_REGISTRY)


def available() -> dict[str, str]:
    """name -> one-line description, for the CLI and reports."""
    _load_rules()
    return {name: r.doc for name, r in sorted(_REGISTRY.items())}


def _load_rules() -> None:
    from . import rules  # noqa: F401  (registers on import)


@dataclass
class Report:
    """A whole scan: every finding (suppressed ones flagged, not dropped)
    plus the file count, so callers can render totals."""

    findings: list[Finding]
    n_files: int

    @property
    def unsuppressed(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> list[Finding]:
        return [f for f in self.findings if f.suppressed]

    def ok(self) -> bool:
        return not self.unsuppressed


_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules"}


def iter_python_files(paths: Iterable[str | Path]) -> Iterator[Path]:
    """Every .py file under `paths` (files taken verbatim), deterministic
    order, hidden/cache dirs skipped."""
    seen: set[Path] = set()
    for p in paths:
        p = Path(p)
        if p.is_file():
            files: Iterable[Path] = [p]
        elif p.is_dir():
            files = sorted(q for q in p.rglob("*.py")
                           if not any(part in _SKIP_DIRS or
                                      part.startswith(".")
                                      for part in q.parts))
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
        for f in files:
            f = f.resolve()
            if f not in seen:
                seen.add(f)
                yield f


def _relativize(path: Path, root: Path) -> str:
    try:
        return path.relative_to(root).as_posix()
    except ValueError:
        return path.as_posix()


class _AllowIndex:
    """Lazy per-file pragma index; project-rule findings may land in files
    outside the scanned set (registration sites), so allows are loaded on
    demand from disk."""

    def __init__(self) -> None:
        self._by_path: dict[str, dict[int, set[str]]] = {}

    def seed(self, rel: str, source: str) -> None:
        self._by_path[rel] = parse_allows(source)

    def allows(self, root: Path, rel: str) -> dict[int, set[str]]:
        if rel not in self._by_path:
            p = root / rel
            try:
                self._by_path[rel] = parse_allows(
                    p.read_text(encoding="utf-8"))
            except OSError:
                self._by_path[rel] = {}
        return self._by_path[rel]


def scan_paths(paths: Iterable[str | Path], root: str | Path | None = None,
               rules: Iterable[str] | None = None,
               project: bool | None = None) -> Report:
    """Run the rule registry over `paths`.

    `root` anchors the repo-relative paths the rules classify on (default:
    the current working directory).  `rules` restricts to a subset of rule
    names.  `project` forces project-scope rules on/off; by default they run
    only when the scan actually covers this repo's own source (so scanning a
    fixture tree does not drag the live registries in).
    """
    _load_rules()
    root = Path(root).resolve() if root is not None else Path.cwd().resolve()
    if rules is None:
        active = list(_REGISTRY.values())
    else:
        active = [get(n) for n in rules]
    file_rules = [r for r in active if r.scope == "file"]
    project_rules = [r for r in active if r.scope == "project"]
    program_rules = [r for r in active if r.scope == "program"]

    allow_index = _AllowIndex()
    findings: list[Finding] = []
    contexts: list[FileContext] = []
    n_files = 0
    scanned_repro = False
    for path in iter_python_files(paths):
        n_files += 1
        rel = _relativize(path, root)
        if "repro/core/engine.py" in rel:
            scanned_repro = True
        source = path.read_text(encoding="utf-8")
        allow_index.seed(rel, source)
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as exc:
            findings.append(Finding(
                "parse-error", rel, exc.lineno or 1,
                f"file does not parse: {exc.msg}",
                "fix the syntax error; no rule can check an unparsable file"))
            continue
        ctx = FileContext(path, rel, source, tree, source.splitlines())
        contexts.append(ctx)
        for rule in file_rules:
            findings.extend(rule.check(ctx))

    if program_rules and contexts:
        prog = ProgramContext(contexts)
        for rule in program_rules:
            findings.extend(rule.check(prog))

    if project is None:
        project = scanned_repro
    if project:
        for rule in project_rules:
            findings.extend(rule.check())

    out: list[Finding] = []
    for f in findings:
        allowed = allow_index.allows(root, f.path).get(f.line, set())
        if f.rule in allowed and f.rule != "pragma-discipline":
            f = Finding(f.rule, f.path, f.line, f.message, f.hint,
                        suppressed=True)
        out.append(f)
    return Report(out, n_files)
