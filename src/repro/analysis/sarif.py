"""SARIF 2.1.0 emitter for the contract checker.

One run, one driver ("repro-analysis"), one reportingDescriptor per
registered rule, one result per finding.  Pragma-suppressed findings are
emitted with an ``inSource`` suppression object so SARIF viewers (and
the GitHub code-scanning upload) show them as reviewed, not open.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from . import Report

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")

__all__ = ["to_sarif"]


def to_sarif(report: "Report", rule_docs: dict[str, str]) -> dict:
    """SARIF log dict for `report`; ``rule_docs`` maps rule id -> doc."""
    used = sorted({f.rule for f in report.findings} | set(rule_docs))
    rule_index = {rid: i for i, rid in enumerate(used)}
    descriptors = [{
        "id": rid,
        "shortDescription": {"text": rule_docs.get(rid, rid)},
        "defaultConfiguration": {"level": "error"},
    } for rid in used]
    results = []
    for f in report.findings:
        res = {
            "ruleId": f.rule,
            "ruleIndex": rule_index[f.rule],
            "level": "error",
            "message": {"text": f.message + (f"\nfix: {f.hint}"
                                             if f.hint else "")},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {"uri": f.path,
                                         "uriBaseId": "SRCROOT"},
                    "region": {"startLine": max(int(f.line), 1)},
                },
            }],
        }
        if f.suppressed:
            res["suppressions"] = [{"kind": "inSource",
                                    "justification": "repro: allow pragma"}]
        results.append(res)
    return {
        "version": SARIF_VERSION,
        "$schema": SARIF_SCHEMA,
        "runs": [{
            "tool": {"driver": {
                "name": "repro-analysis",
                "informationUri":
                    "https://example.invalid/repro/analysis",
                "rules": descriptors,
            }},
            "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
            "results": results,
            "columnKind": "utf16CodeUnits",
        }],
    }
