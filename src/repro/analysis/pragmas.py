"""Inline suppression pragmas: ``# repro: allow(<rule>[, <rule>...]): why``.

A pragma on a code line suppresses those rules on that line; a pragma on a
comment-only line covers the next non-blank source line (so long imports
can carry the annotation above them).  The justification after the pragma
is mandatory — ``parse_allows`` still indexes unjustified pragmas so the
``pragma-discipline`` rule can point at them, but rule findings are only
suppressed through justified entries (see ``iter_pragmas``).
"""
from __future__ import annotations

import re
from typing import Iterator, NamedTuple

# group(1): comma list of rule ids; group(2): trailing text (justification)
PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\(([a-zA-Z0-9_,\s-]*)\)(.*)$")


class Pragma(NamedTuple):
    line: int          # 1-based line the pragma is written on
    target: int        # 1-based line it applies to
    rules: tuple[str, ...]
    justification: str


def _is_comment_only(line: str, match_start: int) -> bool:
    return line[:match_start].strip() == ""


def iter_pragmas(source: str) -> Iterator[Pragma]:
    lines = source.splitlines()
    for i, line in enumerate(lines, start=1):
        m = PRAGMA_RE.search(line)
        if m is None:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        just = m.group(2).strip().lstrip(":—–-").strip()
        target = i
        if _is_comment_only(line, m.start()):
            # standalone comment: applies to the next non-blank line
            for j in range(i, len(lines)):
                if lines[j].strip():
                    target = j + 1
                    break
        yield Pragma(i, target, rules, just)


def parse_allows(source: str) -> dict[int, set[str]]:
    """target line -> set of rule ids suppressed there (justified pragmas
    only — an unjustified pragma suppresses nothing)."""
    allows: dict[int, set[str]] = {}
    for p in iter_pragmas(source):
        if not p.justification:
            continue
        allows.setdefault(p.target, set()).update(p.rules)
    return allows
