from .checkpoint import (CheckpointManager, latest_step, restore,  # noqa: F401
                         save)
