"""Checkpointing: atomic, manifest-driven, elastic-restorable.

Layout:  <dir>/step_<N>/
             manifest.json   — step, leaf paths, shapes, dtypes, extra meta
             <leaf>.npy      — one array per pytree leaf (full, host-gathered)

Writes go to step_<N>.tmp/ and are renamed into place, so a crash mid-save
never corrupts the latest checkpoint (restart resumes from the previous
step — the fault-tolerance tests exercise exactly this). An async mode
hands the serialized arrays to a writer thread so the train loop does not
block on disk.

Elastic restore: leaves are stored as FULL arrays (host-gathered), so a
checkpoint written under one mesh restores onto ANY mesh/sharding — the
restore path just device_puts with the new NamedShardings. On a multi-host
deployment each host would write its addressable shards plus a shard index
(same manifest format, `shards` field); the gather/scatter logic below is
the single-controller specialization of that.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SAFE.sub("_", ".".join(parts))


def save(state, directory: str | Path, step: int, extra: dict | None = None,
         _sync: bool = True) -> Path:
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            steps.append(int(m.group(1)))
    return max(steps, default=None)


def restore(state_like, directory: str | Path, step: int | None = None,
            shardings=None):
    """Restore into the structure of `state_like` (abstract or concrete).
    `shardings`: optional matching pytree of NamedShardings — THIS is the
    elastic path: any mesh works regardless of the mesh at save time."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    leaves_meta = {m["name"]: m for m in manifest["leaves"]}
    paths_leaves = jax.tree_util.tree_flatten_with_path(state_like)
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else None)
    out = []
    for i, (path, like) in enumerate(paths_leaves[0]):
        name = _leaf_name(path)
        if name not in leaves_meta:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(d / f"{name}.npy")
        want_shape = tuple(like.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != {want_shape}")
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], out), manifest


class CheckpointManager:
    """save-every-N with bounded retention and optional async writes."""

    def __init__(self, directory: str | Path, every: int = 50, keep: int = 3,
                 async_write: bool = False):
        self.directory = Path(directory)
        self.every = every
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None

    def maybe_save(self, state, step: int, extra: dict | None = None) -> bool:
        if step % self.every != 0:
            return False
        self.wait()
        if self.async_write:
            # serialize on the caller side (device_get) happens inside save;
            # hand the whole state off — leaves are immutable jax arrays.
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(state, step, extra), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(state, step, extra)
        return True

    def _save_and_gc(self, state, step, extra):
        save(state, self.directory, step, extra)
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if re.fullmatch(r"step_\d+", p.name))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
