from .ops import ssd_scan  # noqa: F401
