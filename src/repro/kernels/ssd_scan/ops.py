"""Public wrapper for the SSD scan kernel: chunk-padding, interpret switch,
ref fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import default_interpret
from .ref import ssd_ref
from .ssd_scan import ssd_scan_padded

_I32_MAX = int(np.iinfo(np.int32).max)


def ssd_scan(
    x: jax.Array,    # (B, S, H, P)
    a: jax.Array,    # (B, S, H) decay in (0, 1]
    b: jax.Array,    # (B, S, G, N)
    c: jax.Array,    # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return ssd_ref(x, a, b, c)
    if interpret is None:
        interpret = default_interpret()
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    if a.shape != (B, S, H) or b.shape != (B, S, G, N) or c.shape != b.shape:
        raise ValueError(
            f"ssd_scan operand shapes disagree: x {x.shape}, a {a.shape}, "
            f"b {b.shape}, c {c.shape}")
    L = min(chunk, S)
    pad = (-S) % L
    # Pallas indexes the padded operands with int32 arithmetic; past that
    # the associative-scan reference is the only correct path.  loga is
    # (B, Sp, H), so its count needs covering too (P may be 0).
    Sp = S + pad
    if max(B * Sp * H * P, B * Sp * G * N, B * Sp * H) >= _I32_MAX:
        return ssd_ref(x, a, b, c)
    if pad:
        # padded steps use decay 1 (log 0) and zero inputs: state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    loga = jnp.log(jnp.maximum(a.astype(jnp.float32), 1e-37))
    out = ssd_scan_padded(x, loga, b, c, chunk=L, interpret=interpret)
    return out[:, :S]
