"""Pure-jnp oracle for the Mamba2 SSD scan: the exact sequential recurrence.

State h_t (N, P) per (batch, head):
    h_t = a_t * h_{t-1} + b_t (N,) outer x_t (P,)
    y_t = c_t . h_t   (contract N)

a: per-head scalar decay in (0, 1]; b, c shared across heads within a state
group (n_groups, GQA-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, a, b, c):
    """x: (B, S, H, P); a: (B, S, H); b, c: (B, S, G, N). Returns (B, S, H, P)."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=2)  # (B, S, H, N)
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        xt, at, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        h = at[..., None, None] * h + bt[..., :, None] * xt[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def ssd_decode_step(h, x_t, a_t, b_t, c_t):
    """Single-token recurrence for serving. h: (B, H, N, P)."""
    rep = h.shape[1] // b_t.shape[1]
    bt = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)
    ct = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    h = a_t.astype(jnp.float32)[..., None, None] * h \
        + bt[..., :, None] * x_t.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", ct, h)
    return h, y.astype(x_t.dtype)
