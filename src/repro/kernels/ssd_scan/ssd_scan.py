"""Mamba2 SSD chunked-scan kernel (state-space duality).

TPU mapping: grid = (batch, heads, chunks) with the chunk axis sequential
("arbitrary") so the inter-chunk state h (N, P) persists in VMEM scratch.
Per chunk of length L the kernel computes, all in f32 on MXU-aligned tiles:

  intra:  Y += ((C B^T) .* M) X      M_ij = exp(cum_i - cum_j) for i >= j
  inter:  Y += exp(cum_i) * (C_i h)
  state:  h  = exp(cum_L) h + (B .* exp(cum_L - cum))^T X

where cum is the in-chunk cumulative sum of log a. log-space segsum keeps
the decay products stable for long chunks. VMEM per step: L*P (x, y) +
2*L*N (b, c) + L*L (mask) + N*P (state) floats; with L=128, N=128, P<=256
that is < 1 MiB.

State groups (n_groups < heads) are expressed in the b/c index_maps, same
trick as GQA in flash_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params


def _ssd_kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, h_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    la = loga_ref[0, :, 0].astype(jnp.float32)       # (L,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)        # (L, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)        # (L, N)

    cum = jnp.cumsum(la)                              # (L,)
    # intra-chunk: masked decay matrix in log space
    seg = cum[:, None] - cum[None, :]                 # (L, L): sum_{j<k<=i} la_k
    L = la.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * mask
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    h = h_ref[...]                                    # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update for the next chunk
    wb = b * jnp.exp(cum[-1] - cum)[:, None]          # (L, N)
    h_ref[...] = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        wb, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_padded(
    x: jax.Array,      # (B, S, H, P), S % chunk == 0
    loga: jax.Array,   # (B, S, H)  log decay (<= 0)
    b: jax.Array,      # (B, S, G, N)
    c: jax.Array,      # (B, S, G, N)
    *,
    chunk: int,
    interpret: bool,
) -> jax.Array:
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert S % chunk == 0
    rep = H // G
    grid = (B, H, S // chunk)

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, ic: (bb, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, h, ic: (bb, ic, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda bb, h, ic: (bb, ic, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda bb, h, ic: (bb, ic, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda bb, h, ic: (bb, ic, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, loga, b, c)
