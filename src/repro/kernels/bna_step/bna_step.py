"""bna_step kernel — the batched BNA inner loop (core of Algorithm 1 at
batch scale, the matching analogue of coflow_merge).

One invocation performs one lock-step iteration of the filled-matrix BNA
decomposition for a whole (B, w, w) stack of demand matrices: the matched
demands are gathered through a one-hot of the current matching, the step
length is the three-term min of line 5 (matched demand, idle-sender slack
D - row, idle-receiver slack D - col), the transmissions are applied, and
the matched-edge invalidation mask for the host-side augmenting-path repair
is emitted.  Everything is elementwise/reduction int32 arithmetic — the
kernel is BIT-IDENTICAL to the numpy oracle (`ref.bna_step_ref`), which is
what lets `REPRO_BNA_BACKEND=pallas` keep plans byte-for-byte equal.

TPU mapping: grid over B-blocks ("parallel" — matrices are independent),
each step loading a (block_b, w, w) demand tile plus its (block_b, w) state
rows into VMEM.  The gather is realized as a one-hot broadcast-compare
(match index vs a receiver iota) followed by a masked reduction — the
standard TPU trick for small-axis gathers, keeping the whole body on the
VPU.  Arithmetic intensity is ~3 ops/byte over the w*w tile: memory-bound,
like coflow_merge; the roofline section of `benchmarks.roofline_report`
reports the memory term at K -> 1e5.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import tpu_compiler_params

_NO_MATCH = -1
_BIG = jnp.iinfo(jnp.int32).max


def _bna_step_kernel(d_ref, row_ref, col_ref, D_ref, match_ref,
                     t_ref, piece_ref, dn_ref, rown_ref, coln_ref,
                     Dn_ref, inv_ref):
    d = d_ref[...]                     # (Bb, w, w) int32
    row = row_ref[...]                 # (Bb, w)
    col = col_ref[...]
    Dv = D_ref[...]                    # (Bb, 1)
    match = match_ref[...]             # (Bb, w)

    r_ids = jax.lax.broadcasted_iota(jnp.int32, d.shape, dimension=2)
    onehot = (r_ids == match[:, :, None]) & (match[:, :, None] != _NO_MATCH)
    dm = jnp.sum(jnp.where(onehot, d, 0), axis=2)          # (Bb, w)
    real = (match != _NO_MATCH) & (dm > 0)

    t = jnp.min(jnp.where(real, dm, _BIG), axis=1, keepdims=True)
    t = jnp.minimum(t, jnp.min(jnp.where(~real, Dv - row, _BIG),
                               axis=1, keepdims=True))
    recv = jnp.any(onehot & real[:, :, None], axis=1)      # (Bb, w)
    t = jnp.minimum(t, jnp.min(jnp.where(~recv, Dv - col, _BIG),
                               axis=1, keepdims=True))

    served = onehot & real[:, :, None]
    dn = d - jnp.where(served, t[:, :, None], 0)
    rown = row - jnp.where(real, t, 0)
    coln = col - jnp.where(recv, t, 0)
    Dn = Dv - t

    dmn = dm - jnp.where(real, t, 0)
    colm = jnp.sum(jnp.where(onehot, coln[:, None, :], 0), axis=2)
    invalid = (match != _NO_MATCH) & (dmn == 0) \
        & ((rown >= Dn) | (colm >= Dn)) & (Dn > 0)

    t_ref[...] = t
    piece_ref[...] = jnp.where(real, match, _NO_MATCH)
    dn_ref[...] = dn
    rown_ref[...] = rown
    coln_ref[...] = coln
    Dn_ref[...] = Dn
    inv_ref[...] = invalid.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def bna_step_padded(
    d: jax.Array,       # (B_pad, w_pad, w_pad) int32, B_pad % block_b == 0
    row: jax.Array,     # (B_pad, w_pad) int32
    col: jax.Array,
    D: jax.Array,       # (B_pad, 1) int32
    match: jax.Array,   # (B_pad, w_pad) int32
    *,
    block_b: int,
    interpret: bool,
):
    B, w, _ = d.shape
    assert B % block_b == 0
    grid = (B // block_b,)
    i32 = jnp.int32
    return pl.pallas_call(
        _bna_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, w, w), lambda ib: (ib, 0, 0)),
            pl.BlockSpec((block_b, w), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, w), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, 1), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, w), lambda ib: (ib, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, w), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, w, w), lambda ib: (ib, 0, 0)),
            pl.BlockSpec((block_b, w), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, w), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, 1), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, w), lambda ib: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), i32),       # t
            jax.ShapeDtypeStruct((B, w), i32),       # piece
            jax.ShapeDtypeStruct((B, w, w), i32),    # d'
            jax.ShapeDtypeStruct((B, w), i32),       # row'
            jax.ShapeDtypeStruct((B, w), i32),       # col'
            jax.ShapeDtypeStruct((B, 1), i32),       # D'
            jax.ShapeDtypeStruct((B, w), i32),       # invalid
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(d, row, col, D, match)
