"""Pure-numpy oracle for the batched BNA step (one lock-step iteration of
Algorithm 1 in filled-matrix form, across a (B, w, w) demand stack).

Unlike the other kernels' refs this one is numpy, not jnp — and it is not
a re-implementation: it wraps ``core.matching.bna_step_inplace`` (the
single numpy source of the step formulas, the code the numpy backend
actually runs) on copies, so the kernel parity sweep transitively pins the
kernel against the production step.  All-integer ops, so "allclose" is
equality.  Padded ports (zero load, match == -1) are neutral by
construction: they are never real-matched and constrain the step length
only by D - 0 = D, which never binds because the step is always <= the
minimum matched demand <= D.
"""
from __future__ import annotations

import numpy as np


def bna_step_ref(
    d: np.ndarray,      # (B, w, w) int64 remaining demands
    row: np.ndarray,    # (B, w) int64 row loads
    col: np.ndarray,    # (B, w) int64 col loads
    D: np.ndarray,      # (B,) int64 remaining effective sizes
    match: np.ndarray,  # (B, w) int64 match_sr (-1 = unmatched)
) -> tuple[np.ndarray, ...]:
    """One batched step: ``(t, piece, d', row', col', D', invalid)``.

    t: (B,) step lengths (0 for drained matrices); piece: (B, w) the real
    matched edges transmitted this step (-1 elsewhere); primed arrays are
    the post-transmission state; invalid: (B, w) bool, matched edges that
    left the filled graph (the scalar repair()'s ``bad`` mask, already
    masked to matrices with D' > 0).
    """
    from repro.core.matching import bna_step_inplace

    d2 = np.array(d, dtype=np.int64, copy=True)
    row2 = np.array(row, dtype=np.int64, copy=True)
    col2 = np.array(col, dtype=np.int64, copy=True)
    t, piece, D2, invalid = bna_step_inplace(
        d2, row2, col2, np.asarray(D, dtype=np.int64),
        np.asarray(match, dtype=np.int64))
    return t, piece, d2, row2, col2, D2, invalid
