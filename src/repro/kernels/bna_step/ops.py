"""Public wrapper for bna_step: int64 <-> int32 marshalling with an
overflow guard, padding to kernel tiles, dispatch (interpret on CPU).

Padding is semantics-transparent: padded matrices (batch axis) carry zero
demand and an empty matching, so their step length is 0 and their state is
a fixed point; padded ports (width axis) have zero load and match == -1, so
they are never real-matched and never bind the step length (their slack is
D - 0 = D >= t always).  The int32 narrowing is exact under the guard —
every input is bounded by the effective size, so all intermediates fit.
"""
from __future__ import annotations

import numpy as np

from .. import default_interpret
from .bna_step import bna_step_padded

_I32_MAX = np.iinfo(np.int32).max


def bna_step_batch(
    d: np.ndarray,      # (B, w, w) int64
    row: np.ndarray,    # (B, w) int64
    col: np.ndarray,    # (B, w) int64
    D: np.ndarray,      # (B,) int64
    match: np.ndarray,  # (B, w) int64
    *,
    block_b: int | None = None,
    interpret: bool | None = None,
) -> tuple[np.ndarray, ...]:
    """One batched BNA step through the Pallas kernel; numpy int64 in/out,
    bit-identical to ``ref.bna_step_ref`` on the same state."""
    if interpret is None:
        interpret = default_interpret()
    B, w, _ = d.shape
    if int(D.max(initial=0)) >= _I32_MAX:
        raise ValueError("demand too large for the int32 bna_step kernel "
                         f"(effective size {int(D.max())} >= 2^31-1); "
                         "use the numpy backend")
    # pad the batch to a power of two (>= 8) so the shrinking active set
    # revisits at most O(log B) compiled shapes; lanes to the VPU multiple
    b_pad = max(8, 1 << max(B - 1, 0).bit_length())
    lane = 8 if interpret else 128
    w_pad = max(lane, ((w + lane - 1) // lane) * lane)
    if b_pad * w_pad * w_pad >= _I32_MAX:
        raise ValueError(
            "batch too large for the int32 bna_step kernel "
            f"(padded element count {b_pad} * {w_pad}^2 >= 2^31-1); "
            "use the numpy backend")
    bb = min(block_b or 128, b_pad)

    def pad2(a, fill=0):
        out = np.full((b_pad, w_pad), fill, dtype=np.int32)
        out[:B, :w] = a
        return out

    d32 = np.zeros((b_pad, w_pad, w_pad), dtype=np.int32)
    d32[:B, :w, :w] = d
    D32 = np.zeros((b_pad, 1), dtype=np.int32)
    D32[:B, 0] = D
    outs = bna_step_padded(
        d32, pad2(row), pad2(col), D32, pad2(match, fill=-1),
        block_b=bb, interpret=interpret)
    t, piece, dn, rown, coln, Dn, inv = (np.asarray(o) for o in outs)
    return (
        t[:B, 0].astype(np.int64),
        piece[:B, :w].astype(np.int64),
        dn[:B, :w, :w].astype(np.int64),
        rown[:B, :w].astype(np.int64),
        coln[:B, :w].astype(np.int64),
        Dn[:B, 0].astype(np.int64),
        inv[:B, :w].astype(bool),
    )
