from .ops import bna_step_batch  # noqa: F401
