"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three files:
  <name>/<name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling
  <name>/ops.py    — jit'd public wrapper (padding, layout, interpret switch)
  <name>/ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  flash_attention — blocked online-softmax GQA attention (train/prefill)
  ssd_scan        — Mamba2 state-space-duality chunked scan
  coflow_merge    — the paper's DMA merge hot loop: per-interval per-port
                    packet counts and alpha_t via running prefix sums
  bna_step        — the batched matching hot loop: one lock-step iteration
                    of the multi-coflow BNA decomposition (step lengths,
                    transmissions, matched-edge invalidation) over a
                    (B, w, w) demand stack; bit-identical to its numpy ref

TPU is the *target*; on this CPU-only container every kernel runs in
interpret mode (the kernel body executes in Python), which is how the test
suite validates them against the refs.
"""


def default_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kwargs):
    """Version-portable pltpu compiler params: the class is named
    `CompilerParams` on current jax and `TPUCompilerParams` on the 0.4.x
    series this container ships."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
