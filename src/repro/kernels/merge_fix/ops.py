"""merge_fix — the fused merge_and_fix tail reusing the coflow_merge kernel.

One call takes the raw merged edge activations and produces both the
per-interval alphas AND the expanded interval durations
``len_i * max(alpha_i, 1)`` (Lemma 6), keeping the binning, the delta
scatter, the prefix-sum/max (the coflow_merge Pallas kernel), and the
duration product in a single device round-trip instead of the
searchsorted → kernel → host → numpy product chain the classic path runs.

Exactness: everything is integer arithmetic.  The duration product runs
in-graph in int32 only when ``max(len) * E`` provably fits (activation
counts bound every alpha by E); otherwise it falls back to a host-side
int64 product — never an error, always bit-identical to
``ref.merge_fix_ref``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .. import default_interpret
from ..coflow_merge.coflow_merge import coflow_merge_padded
from ..coflow_merge.ref import alphas_ref, build_delta

_INT32_MAX = np.int64(2**31 - 1)


def merge_fix_step(
    events: np.ndarray,  # (K+1,) sorted unique interval boundaries
    t0: np.ndarray,      # (E,) edge activation start times
    t1: np.ndarray,      # (E,) edge activation end times (exclusive)
    s: np.ndarray,       # (E,) sender port
    r: np.ndarray,       # (E,) receiver port
    m: int,
    *,
    block_k: int = 1024,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (alphas (K,) int64, deltas (K,) int64); deltas cumsum to
    merge_and_fix's ``exp`` (before the origin shift)."""
    K = int(events.size) - 1
    if K < 1:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    if interpret is None:
        interpret = default_interpret()
    E = int(np.asarray(t0).size)
    if E >= int(_INT32_MAX):
        # delta entries and alphas are activation counts bounded by E, and
        # the kernel accumulates them in int32
        raise ValueError("too many edge activations for the int32 "
                         f"coflow_merge accumulator ({E} >= 2^31-1)")
    si = np.searchsorted(events, t0)
    ei = np.searchsorted(events, t1)
    delta = build_delta(jnp.asarray(si), jnp.asarray(ei), jnp.asarray(s),
                        jnp.asarray(r), K, m)
    if use_kernel:
        bk = min(block_k, max(8, 1 << (K - 1).bit_length()))
        k_pad = (-K) % bk
        p_pad = (-delta.shape[1]) % 128
        if (K + k_pad) * (delta.shape[1] + p_pad) >= int(_INT32_MAX):
            # padded index space would wrap int32 inside the kernel
            al = alphas_ref(delta)
        else:
            dpad = jnp.pad(delta, ((0, k_pad), (0, p_pad)))
            al = coflow_merge_padded(
                dpad, block_k=bk, interpret=interpret)[:K, 0]
    else:
        al = alphas_ref(delta)
    lens = np.asarray(events[1:] - events[:-1], dtype=np.int64)
    max_len = int(lens.max(initial=0))
    if max_len * max(E, 1) < int(_INT32_MAX):
        # alphas <= E (each activation contributes at most one count per
        # port), so every product fits int32: fuse it in-graph
        deltas = np.asarray(
            jnp.asarray(lens, dtype=jnp.int32)
            * jnp.maximum(al.astype(jnp.int32), 1),
            dtype=np.int64)
        return np.asarray(al, dtype=np.int64), deltas
    alphas = np.asarray(al, dtype=np.int64)
    return alphas, lens * np.maximum(alphas, 1)
