from .ops import merge_fix_step  # noqa: F401
