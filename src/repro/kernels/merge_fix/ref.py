"""Numpy oracle for merge_fix: the classic merge_and_fix tail — alphas from
edge activations, then per-interval expanded durations ``len * max(alpha, 1)``
(Lemma 6).  The fused step in ops.py must match this exactly."""
from __future__ import annotations

import numpy as np


def merge_fix_ref(
    events: np.ndarray,  # (K+1,) sorted unique interval boundaries
    t0: np.ndarray,      # (E,) edge activation start times
    t1: np.ndarray,      # (E,) edge activation end times (exclusive)
    s: np.ndarray,       # (E,) sender port
    r: np.ndarray,       # (E,) receiver port
    m: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (alphas (K,) int64, deltas (K,) int64) — deltas are the
    expanded interval durations ``(events[i+1]-events[i]) * max(alpha_i, 1)``
    whose cumsum is merge_and_fix's ``exp`` (before the origin shift)."""
    K = int(events.size) - 1
    if K < 1:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    si = np.searchsorted(events, t0)
    ei = np.searchsorted(events, t1)
    counts = np.zeros((K + 1, 2 * m), dtype=np.int64)
    np.add.at(counts, (si, s), 1)
    np.add.at(counts, (ei, s), -1)
    np.add.at(counts, (si, m + r), 1)
    np.add.at(counts, (ei, m + r), -1)
    alphas = np.cumsum(counts[:K], axis=0).max(axis=1).astype(np.int64)
    lens = (events[1:] - events[:-1]).astype(np.int64)
    return alphas, lens * np.maximum(alphas, 1)
