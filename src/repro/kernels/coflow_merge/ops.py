"""Public wrapper for coflow_merge: scatter the edge activations into the
delta array, pad to kernel tiles, dispatch (interpret on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import default_interpret
from .coflow_merge import coflow_merge_padded
from .ref import alphas_ref, build_delta

_I32_MAX = int(np.iinfo(np.int32).max)


def interval_alphas(
    si: np.ndarray,   # (E,) start interval index per edge activation
    ei: np.ndarray,   # (E,) end interval index (exclusive)
    s: np.ndarray,    # (E,) sender port
    r: np.ndarray,    # (E,) receiver port
    K: int,
    m: int,
    *,
    block_k: int = 1024,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> np.ndarray:
    """alpha_t per merged interval (DMA Steps 3-4)."""
    if K <= 0:
        return np.zeros(0, dtype=np.int64)
    if interpret is None:
        interpret = default_interpret()
    if int(np.asarray(si).size) >= _I32_MAX:
        # per-port activation counts are bounded by E, and the delta
        # accumulators are int32 — past this nothing (kernel or ref) is exact
        raise ValueError(
            f"coflow_merge: {np.asarray(si).size} edge activations overflow "
            "the int32 delta accumulators")
    delta = build_delta(jnp.asarray(si), jnp.asarray(ei), jnp.asarray(s),
                        jnp.asarray(r), K, m)
    if not use_kernel:
        return np.asarray(alphas_ref(delta), dtype=np.int64)
    bk = min(block_k, max(8, 1 << (K - 1).bit_length()))
    k_pad = (-K) % bk
    p_pad = (-delta.shape[1]) % 128
    # Pallas indexes the padded delta with int32 arithmetic; past that the
    # jnp reference (64-bit indexing) is the only correct path.
    if (K + k_pad) * (delta.shape[1] + p_pad) >= _I32_MAX:
        return np.asarray(alphas_ref(delta), dtype=np.int64)
    dpad = jnp.pad(delta, ((0, k_pad), (0, p_pad)))
    out = coflow_merge_padded(dpad, block_k=bk, interpret=interpret)
    return np.asarray(out[:K, 0], dtype=np.int64)


def edge_interval_alphas(
    events: np.ndarray,  # (K+1,) sorted unique interval boundaries
    t0: np.ndarray,      # (E,) edge activation start times
    t1: np.ndarray,      # (E,) edge activation end times (exclusive)
    s: np.ndarray,
    r: np.ndarray,
    m: int,
    **kw,
) -> np.ndarray:
    """interval_alphas from raw edge-interval times: the merge_and_fix entry
    point used by the engine's backend dispatch (core/backend.py).  Bins the
    activation times into interval indices, then runs the kernel."""
    si = np.searchsorted(events, t0)
    ei = np.searchsorted(events, t1)
    return interval_alphas(si, ei, np.asarray(s), np.asarray(r),
                           int(events.size) - 1, m, **kw)
