"""Public wrapper for coflow_merge: scatter the edge activations into the
delta array, pad to kernel tiles, dispatch (interpret on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import default_interpret
from .coflow_merge import coflow_merge_padded
from .ref import alphas_ref, build_delta


def interval_alphas(
    si: np.ndarray,   # (E,) start interval index per edge activation
    ei: np.ndarray,   # (E,) end interval index (exclusive)
    s: np.ndarray,    # (E,) sender port
    r: np.ndarray,    # (E,) receiver port
    K: int,
    m: int,
    *,
    block_k: int = 1024,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> np.ndarray:
    """alpha_t per merged interval (DMA Steps 3-4)."""
    if K <= 0:
        return np.zeros(0, dtype=np.int64)
    if interpret is None:
        interpret = default_interpret()
    delta = build_delta(jnp.asarray(si), jnp.asarray(ei), jnp.asarray(s),
                        jnp.asarray(r), K, m)
    if not use_kernel:
        return np.asarray(alphas_ref(delta), dtype=np.int64)
    bk = min(block_k, max(8, 1 << (K - 1).bit_length()))
    k_pad = (-K) % bk
    p_pad = (-delta.shape[1]) % 128
    dpad = jnp.pad(delta, ((0, k_pad), (0, p_pad)))
    out = coflow_merge_padded(dpad, block_k=bk, interpret=interpret)
    return np.asarray(out[:K, 0], dtype=np.int64)
