"""coflow_merge kernel — the hot inner loop of the paper's DMA fix-up.

Given the (K, 2m) array of per-interval per-port packet-count *deltas*
(+1 where an edge activation enters a merged interval, -1 where it leaves),
compute alpha_t for every interval: the running per-port count, maxed over
ports. This is Steps 3-4 of DMA at scale: K is the number of merged
intervals (hundreds of thousands for the full Facebook-trace workload).

TPU mapping: grid over K-blocks, sequential ("arbitrary"), carrying the
running port counts (1, 2m) in VMEM scratch. Each step loads a
(block_k, 2m) delta tile into VMEM (2m padded to a 128 multiple by ops.py),
does a cumsum down the time axis plus the carry, and writes the per-row max.
Memory-bound by design: one pass over the delta array, arithmetic intensity
~2 ops/byte — the roofline benchmark for this kernel reports the memory
term, matching the analysis in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params


def _merge_kernel(delta_ref, alpha_ref, carry_ref):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    delta = delta_ref[...].astype(jnp.int32)          # (Bk, 2m)
    counts = carry_ref[...] + jnp.cumsum(delta, axis=0)
    alpha_ref[...] = counts.max(axis=1, keepdims=True)
    carry_ref[...] = counts[-1:, :]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def coflow_merge_padded(
    delta: jax.Array,   # (K_pad, ports_pad) int32, K_pad % block_k == 0
    *,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    K, ports = delta.shape
    assert K % block_k == 0
    grid = (K // block_k,)
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_k, ports), lambda ib: (ib, 0))],
        out_specs=pl.BlockSpec((block_k, 1), lambda ib: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((K, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, ports), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(delta)
