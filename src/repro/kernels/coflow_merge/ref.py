"""Pure-jnp oracle for coflow_merge: running prefix-sum of per-port count
deltas down the interval axis, then the per-interval max over ports —
alpha_t of DMA Steps 3-4 (the quantity Lemma 4 bounds)."""
from __future__ import annotations

import jax.numpy as jnp


def alphas_ref(delta: jnp.ndarray) -> jnp.ndarray:
    """delta: (K, 2m) int32 count deltas (+1 at interval where an edge-port
    activation starts, -1 where it ends). Returns (K,) int32 alphas."""
    counts = jnp.cumsum(delta, axis=0)
    return counts.max(axis=1).astype(jnp.int32)


def build_delta(si, ei, s, r, K: int, m: int) -> jnp.ndarray:
    """Scatter edge activations into the (K, 2m) delta array."""
    delta = jnp.zeros((K + 1, 2 * m), dtype=jnp.int32)
    delta = delta.at[si, s].add(1).at[ei, s].add(-1)
    delta = delta.at[si, m + r].add(1).at[ei, m + r].add(-1)
    return delta[:K]
