from .ops import interval_alphas  # noqa: F401
