from .ops import edge_interval_alphas, interval_alphas  # noqa: F401
