"""Pure-jnp oracle for flash_attention: exact softmax GQA attention in f32."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d); GQA by head repetition."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), dtype=bool), k=Sk - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
