"""Public wrapper for the flash_attention kernel: padding to MXU-aligned
block shapes, block-size selection, interpret-mode dispatch, ref fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import default_interpret
from .flash_attention import flash_attention_padded
from .ref import attention_ref

_I32_MAX = int(np.iinfo(np.int32).max)


def _round_up(x: int, k: int) -> int:
    return (x + k - 1) // k * k


def flash_attention(
    q: jax.Array,   # (B, Hq, Sq, d)
    k: jax.Array,   # (B, Hkv, Sk, d)
    v: jax.Array,   # (B, Hkv, Sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Blocked attention; exact (same math as ref, different blocking)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    if k.shape != (B, Hkv, Sk, d) or v.shape != k.shape:
        raise ValueError(
            f"flash_attention operand shapes disagree: q {q.shape}, "
            f"k {k.shape}, v {v.shape}")
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    if scale is None:
        scale = float(d) ** -0.5
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, scale=scale)
    if interpret is None:
        interpret = default_interpret()

    bq = min(block_q, _round_up(Sq, 8))
    bk = min(block_k, _round_up(Sk, 8))
    sq_p = _round_up(Sq, bq)
    sk_p = _round_up(Sk, bk)
    d_p = _round_up(d, 128)
    # Pallas indexes the padded q/k/v with int32 arithmetic; past that the
    # blocked kernel would wrap, so take the exact reference instead.
    if max(B * Hq * sq_p * d_p, B * Hkv * sk_p * d_p) >= _I32_MAX:
        return attention_ref(q, k, v, causal=causal, scale=scale)

    def pad(x, s_to, d_to):
        return jnp.pad(x, ((0, 0), (0, 0), (0, s_to - x.shape[2]), (0, d_to - x.shape[3])))

    qp = pad(q, sq_p, d_p)
    kp = pad(k, sk_p, d_p)
    vp = pad(v, sk_p, d_p)
    # NOTE on causal + padded queries: padded query rows attend to key block 0
    # after masking (all-masked rows produce zeros via the l==0 guard).
    out = flash_attention_padded(
        qp, kp, vp, scale=scale, causal=causal, block_q=bq, block_k=bk,
        seq_k=Sk, causal_offset=Sk - Sq, interpret=interpret)
    return out[:, :, :Sq, :d]
