"""Blocked GQA attention kernel (FlashAttention-style online softmax).

TPU mapping: grid = (batch, q_heads, q_blocks, k_blocks) with the k-block
axis sequential ("arbitrary") so the f32 accumulators live in VMEM scratch
across k steps. Block shapes are (block_q, head_dim) / (block_k, head_dim);
head_dim is padded to a multiple of 128 by ops.py so the (Bq x d) @ (d x Bk)
products land on MXU-aligned shapes. VMEM working set per step:
Bq*d (q) + 2*Bk*d (k, v) + Bq*Bk (scores) + Bq*d (acc) floats — with the
default 128/128 blocks and d<=256 this is well under 1 MiB.

GQA is expressed in the k/v index_maps (q head h reads kv head
h // (Hq // Hkv)) — no materialized K/V repetition anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, block_q: int, block_k: int,
                 seq_k: int, causal_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (Bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (Bk, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (Bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # mask: causal + key padding
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        # queries are aligned to the END of the key sequence (decode/prefill
        # convention): query i attends keys <= i + causal_offset
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        mask = mask & (qpos + causal_offset >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (Bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # (Bq, Bk)
    correction = jnp.exp(m_prev - m_new)         # (Bq, 1)
    l_ref[...] = l_ref[...] * correction + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "seq_k",
                     "causal_offset", "interpret"),
)
def flash_attention_padded(
    q: jax.Array,   # (B, Hq, Sq_pad, d_pad)
    k: jax.Array,   # (B, Hkv, Sk_pad, d_pad)
    v: jax.Array,   # (B, Hkv, Sk_pad, d_pad)
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    seq_k: int,     # true (unpadded) key length, for masking
    causal_offset: int,
    interpret: bool,
) -> jax.Array:
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Sq % block_q == 0 and Sk % block_k == 0
    group = Hq // Hkv
    grid = (B, Hq, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_k=seq_k, causal_offset=causal_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
