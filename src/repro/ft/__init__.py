from .runner import FTConfig, StragglerMonitor, TrainRunner  # noqa: F401
