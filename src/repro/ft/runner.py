"""Fault-tolerant training runner.

Production posture for 1000+ nodes, specialized to this container's single
process:
  * checkpoint-every-N with atomic writes + bounded retention (ckpt/)
  * auto-resume: on (re)start the runner scans the checkpoint dir and
    continues from the newest valid step — a crashed/restarted worker needs
    zero coordination beyond the shared store
  * deterministic data: batches are a pure function of step (data/), so
    resume/elastic-reshard never replays or skips tokens
  * failure injection hooks (tests crash the loop mid-run and assert
    bit-exact continuation)
  * straggler monitor: per-step wall-time EWMA; steps slower than
    `straggler_factor` x EWMA are flagged and counted. On a real fleet this
    feeds the scheduler (drain/replace the slow host); here it drives tests
    and metrics.
  * elastic restore: checkpoints are mesh-agnostic (see ckpt/) — restore
    onto a different device count, re-lower, continue.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.ckpt import CheckpointManager, latest_step, restore
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.common import ArchConfig
from repro.train.optim import OptConfig
from repro.train.step import TrainState, build_train_step, init_train_state

__all__ = ["FTConfig", "TrainRunner", "StragglerMonitor"]


@dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 20
    keep: int = 3
    async_ckpt: bool = False
    straggler_factor: float = 3.0


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ewma: float | None = None
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.flagged.append((step, dt))
        else:  # stragglers do not poison the baseline
            self.ewma = dt if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class TrainRunner:
    def __init__(self, cfg: ArchConfig, opt: OptConfig, data: DataConfig,
                 ft: FTConfig, seed: int = 0,
                 fault_hook: Callable[[int], None] | None = None,
                 bucket_order: list[list[str]] | None = None):
        self.cfg = cfg
        self.opt = opt
        self.data = SyntheticTokens(cfg, data)
        self.ft = ft
        self.seed = seed
        self.fault_hook = fault_hook
        self.monitor = StragglerMonitor(ft.straggler_factor)
        self.ckpt = CheckpointManager(ft.ckpt_dir, every=ft.ckpt_every,
                                      keep=ft.keep, async_write=ft.async_ckpt)
        # bucket_order: the coflow planner's gradient-bucket launch order
        # (repro.dist.planner.bucket_order_from_plan), realized as HLO
        # dependency chains in the train step
        self.bucket_order = bucket_order
        self.step_fn = jax.jit(
            build_train_step(cfg, opt, bucket_order=bucket_order))
        self.metrics_log: list[dict] = []

    def init_or_resume(self) -> tuple[TrainState, int]:
        step = latest_step(self.ft.ckpt_dir)
        state = init_train_state(self.cfg, jax.random.PRNGKey(self.seed))
        if step is None:
            return state, 0
        restored, manifest = restore(state, self.ft.ckpt_dir, step)
        return restored, int(manifest["step"])

    def run(self, n_steps: int) -> TrainState:
        state, start = self.init_or_resume()
        for step in range(start, n_steps):
            if self.fault_hook is not None:
                self.fault_hook(step)  # tests raise here to simulate a crash
            t0 = time.time()
            batch = self.data.batch_at(step)
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            slow = self.monitor.observe(step, dt)
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]),
                 "time_s": dt, "straggler": bool(slow)})
            self.ckpt.maybe_save(state, step + 1)
        self.ckpt.wait()
        return state
