"""The workload zoo: every scenario the registry ships with.

The paper's evaluation (§VII) is calibrated to a single Facebook
Hive/MapReduce trace; relative scheduler performance is known to shift
dramatically across trace shapes (experimental coflow-scheduler analyses,
and follow-up work on coflows with precedence constraints).  Each scenario
here stresses a different axis — port skew, coflow width, DAG depth/width,
arrival model — and declares instance-checkable bounds so the cross-product
test harness can hold every scheduler to the same invariants on every
shape.

All builders follow the registry conventions: ``m`` (ports, None = scenario
default), ``seed``, and ``scale`` (shrinks job/coflow counts — tests pass
tiny values).  Everything is built on the generalized ``core/traces.py``
primitives; ``dist_collectives`` additionally routes through the
``repro.dist`` collective->coflow planner.
"""
from __future__ import annotations

import numpy as np

from repro.core.traces import (build_jobs, paper_workload, poisson_releases,
                               port_skew, sample_coflows, sample_sizes,
                               theta0)
from .registry import BuiltScenario, ScenarioMeta, register

__all__: list[str] = []    # scenarios are reached through the registry


def _count(base: int, scale: float, lo: int = 2) -> int:
    return max(lo, int(round(base * scale)))


# --------------------------------------------------------------------------
# the paper's calibrated trace (general DAGs, and the rooted-tree variant)
# --------------------------------------------------------------------------

@register("fb_like", "paper §VII FB-trace-calibrated workload, general DAGs")
def _fb_like(*, m: int | None = None, seed: int = 0, scale: float = 1.0,
             mu_bar: int = 5, weights: str = "equal") -> BuiltScenario:
    m = m or 50
    inst = paper_workload(m=m, mu_bar=mu_bar, seed=seed, scale=scale,
                          rooted=False, weights=weights)
    return BuiltScenario(inst, _fb_meta("fb_like", "general", m, scale,
                                        mu_bar, weights))


@register("fb_like_rt", "FB-trace-calibrated workload, rooted-tree DAGs "
                        "(Hive/MapReduce stage trees)")
def _fb_like_rt(*, m: int | None = None, seed: int = 0, scale: float = 1.0,
                mu_bar: int = 5, weights: str = "equal") -> BuiltScenario:
    m = m or 50
    inst = paper_workload(m=m, mu_bar=mu_bar, seed=seed, scale=scale,
                          rooted=True, weights=weights)
    return BuiltScenario(inst, _fb_meta("fb_like_rt", "rooted_tree", m, scale,
                                        mu_bar, weights))


def _fb_meta(name: str, family: str, m: int, scale: float, mu_bar: int,
             weights: str, arrival: str = "offline") -> ScenarioMeta:
    n = max(1, int(round(267 * scale)))
    wmax = min(max(max(10, int(round(21170 * scale))), 11), m * (m - 1))
    return ScenarioMeta(name, family, arrival, weights, bounds=dict(
        flow_min=1, width_max=wmax, entry_max=2472 * wmax,
        mu_max=max(2 * mu_bar - 1, 1), n_jobs_max=n))


# --------------------------------------------------------------------------
# non-FB trace shapes
# --------------------------------------------------------------------------

@register("alibaba_sparse", "alibaba-style sparse fan-in: narrow coflows, "
                            "zipf-skewed receivers, fan-in trees")
def _alibaba_sparse(*, m: int | None = None, seed: int = 0,
                    scale: float = 1.0) -> BuiltScenario:
    m = m or 50
    n = _count(60, scale)
    w_hi = max(2, m // 2)
    demands = sample_coflows(
        m, n, seed=seed,
        width_dist=("loguniform", 1, w_hi),
        size_dist=("lognormal", 4.0, 2.0), size_clip=(1, 4096),
        dst_skew=port_skew(m, "zipf", a=1.5))
    inst = build_jobs(demands, mu_bar=4, seed=seed, dag="tree")
    wmax = min(w_hi, m * (m - 1))
    meta = ScenarioMeta("alibaba_sparse", "rooted_tree", "offline", "equal",
                        bounds=dict(flow_min=1, width_max=wmax,
                                    entry_max=4096 * wmax, mu_max=7,
                                    n_jobs_max=n))
    return BuiltScenario(inst, meta)


@register("incast", "incast-heavy: many senders converge on a few hot "
                    "receivers (95% of traffic on m/8 ports)")
def _incast(*, m: int | None = None, seed: int = 0,
            scale: float = 1.0) -> BuiltScenario:
    m = m or 48
    n = _count(40, scale)
    w_lo, w_hi = max(2, m // 2), min(2 * m, m * (m - 1))
    demands = sample_coflows(
        m, n, seed=seed,
        width_dist=("uniform", w_lo, w_hi),
        size_dist=("uniform", 1, 64), size_clip=(1, 64),
        dst_skew=port_skew(m, "hotspot", hot=max(1, m // 8), hot_mass=0.95))
    inst = build_jobs(demands, mu_bar=3, seed=seed, dag="tree")
    meta = ScenarioMeta("incast", "rooted_tree", "offline", "equal",
                        bounds=dict(flow_min=1, width_max=w_hi,
                                    entry_max=64 * w_hi, mu_max=5,
                                    n_jobs_max=n))
    return BuiltScenario(inst, meta)


@register("shuffle_heavy", "shuffle-heavy all-to-all: dense demand on every "
                           "port pair, 3-stage map/shuffle/reduce chains")
def _shuffle_heavy(*, m: int | None = None, seed: int = 0,
                   scale: float = 1.0) -> BuiltScenario:
    m = m or 32
    n_jobs = _count(12, scale, lo=1)
    rng = np.random.default_rng(seed)
    off_diag = ~np.eye(m, dtype=bool)
    demands = []
    for _ in range(3 * n_jobs):
        d = np.zeros((m, m), dtype=np.int64)
        d[off_diag] = sample_sizes(rng, m * (m - 1),
                                   ("lognormal", 2.0, 1.0), clip=(1, 256))
        demands.append(d)
    inst = build_jobs(demands, seed=seed, dag="chain", mu_fixed=3)
    meta = ScenarioMeta("shuffle_heavy", "chain", "offline", "equal",
                        bounds=dict(flow_min=1, width_max=m * (m - 1),
                                    entry_max=256, mu_max=3,
                                    n_jobs_max=3 * n_jobs))
    return BuiltScenario(inst, meta)


@register("wide_shallow", "wide-and-shallow map-reduce: many parallel map "
                          "coflows feeding one reduce (depth-1 star)")
def _wide_shallow(*, m: int | None = None, seed: int = 0,
                  scale: float = 1.0, mu: int = 6) -> BuiltScenario:
    m = m or 40
    n_jobs = _count(10, scale, lo=1)
    demands = sample_coflows(
        m, mu * n_jobs, seed=seed,
        width_dist=("uniform", 1, m),
        size_dist=("uniform", 1, 128), size_clip=(1, 128))
    inst = build_jobs(demands, seed=seed, dag="star", mu_fixed=mu)
    meta = ScenarioMeta("wide_shallow", "rooted_tree", "offline", "equal",
                        bounds=dict(flow_min=1, width_max=m,
                                    entry_max=128 * m, mu_max=mu,
                                    n_jobs_max=mu * n_jobs))
    return BuiltScenario(inst, meta)


@register("deep_chain", "deep-chain DAGs: 10-stage sequential pipelines "
                        "(stresses dependency depth)")
def _deep_chain(*, m: int | None = None, seed: int = 0,
                scale: float = 1.0, depth: int = 10) -> BuiltScenario:
    m = m or 24
    n_jobs = _count(8, scale, lo=1)
    demands = sample_coflows(
        m, depth * n_jobs, seed=seed,
        width_dist=("uniform", 1, m),
        size_dist=("lognormal", 2.0, 1.2), size_clip=(1, 128))
    inst = build_jobs(demands, seed=seed, dag="chain", mu_fixed=depth)
    meta = ScenarioMeta("deep_chain", "chain", "offline", "equal",
                        bounds=dict(flow_min=1, width_max=m,
                                    entry_max=128 * m, mu_max=depth,
                                    n_jobs_max=depth * n_jobs))
    return BuiltScenario(inst, meta)


@register("online_poisson", "weighted Poisson online arrivals over the "
                            "FB-calibrated trace (paper §VII-B.2)")
def _online_poisson(*, m: int | None = None, seed: int = 0,
                    scale: float = 1.0, mu_bar: int = 4,
                    load: float = 4.0) -> BuiltScenario:
    m = m or 50
    base = paper_workload(m=m, mu_bar=mu_bar, seed=seed, scale=scale,
                          rooted=False, weights="random")
    inst = poisson_releases(base, theta=theta0(base) * load, seed=seed)
    meta = _fb_meta("online_poisson", "general", m, scale, mu_bar, "random",
                    arrival="poisson")
    return BuiltScenario(inst, meta)


@register("dist_collectives", "collective->coflow planner workload: a "
                              "synthetic compiled-step collective program "
                              "on a 2 x m/2 fabric (repro.dist; m must be "
                              "even and >= 4)")
def _dist_collectives(*, m: int | None = None, seed: int = 0,
                      scale: float = 1.0, max_mb: int = 8) -> BuiltScenario:
    from repro.dist.planner import coflows_from_step, synthetic_collective_ops

    m = m or 16
    if m < 4 or m % 2:
        raise ValueError(f"dist_collectives needs an even m >= 4 "
                         f"(2 x m/2 fabric, both axes >= 2), got {m}")
    rows, cols = 2, m // 2
    n_ops = _count(16, scale)
    ops = synthetic_collective_ops(n_ops=n_ops, seed=seed, max_mb=max_mb)
    n_buckets = max(1, n_ops // 4)
    inst = coflows_from_step(ops, rows, cols, n_buckets)
    meta = ScenarioMeta("dist_collectives", "chain", "offline", "equal",
                        bounds=dict(flow_min=1, width_max=m * (m - 1),
                                    entry_max=max_mb,
                                    mu_max=-(-n_ops // n_buckets),
                                    n_jobs_max=n_buckets))
    return BuiltScenario(inst, meta)
