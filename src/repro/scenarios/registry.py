"""String-keyed scenario registry, mirroring the scheduler registry in
``core/engine.py``.

A *scenario* is a named, seeded workload generator: ``build()`` returns a
:class:`BuiltScenario` — the concrete :class:`~repro.core.types.Instance`
plus :class:`ScenarioMeta` describing what the generator guarantees (DAG
family, arrival model, weight model, and instance-checkable bounds on flow
sizes / widths / job shapes).  The cross-product test harness
(``tests/test_scenarios.py``) runs every registered scenario against every
registered scheduler and asserts the repo's core invariants;
``check_bounds`` is the metadata half of that contract.

Adding a scenario is one decorator::

    @register("my_trace", "one-line description")
    def _my_trace(*, m=None, seed=0, scale=1.0, **kw) -> BuiltScenario:
        ...

Builder keyword conventions (every scenario accepts them): ``m`` — port
count (None = scenario default), ``seed`` — RNG seed, ``scale`` — shrinks
job/coflow counts proportionally (tests and fast benchmarks pass small
values).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core.types import Instance, is_rooted_tree, topological_order

__all__ = [
    "ScenarioMeta",
    "BuiltScenario",
    "Scenario",
    "register",
    "get",
    "names",
    "available",
    "build",
    "check_bounds",
    "scheduler_opts",
    "strip_releases",
]

#: DAG families a scenario may declare (checked by ``check_bounds``).
DAG_FAMILIES = ("general", "rooted_tree", "chain", "independent")
#: Arrival models a scenario may declare.
ARRIVALS = ("offline", "poisson")


@dataclass(frozen=True)
class ScenarioMeta:
    """What a scenario's generator guarantees about every built instance.

    ``bounds`` keys (all optional, all instance-checkable):
      flow_min   — every positive demand entry >= flow_min
      entry_max  — every demand entry <= entry_max (a safe upper bound;
                   exact for collision-free generators)
      width_max  — nnz of every coflow demand <= width_max
      mu_max     — every job has <= mu_max coflows
      n_jobs_max — the instance has <= n_jobs_max jobs
    """

    name: str
    dag_family: str            # one of DAG_FAMILIES
    arrival: str               # one of ARRIVALS
    weights: str = "equal"     # "equal" | "random"
    bounds: dict = field(default_factory=dict)


@dataclass
class BuiltScenario:
    """A concrete instance plus the metadata it was generated under."""

    instance: Instance
    meta: ScenarioMeta


@dataclass(frozen=True)
class Scenario:
    """A registry entry: named, seeded generator + description."""

    name: str
    doc: str
    builder: Callable[..., BuiltScenario]

    def build(self, **kw) -> BuiltScenario:
        return self.builder(**kw)


_REGISTRY: dict[str, Scenario] = {}


def register(name: str, doc: str = ""):
    """Register ``builder(**kw) -> BuiltScenario`` under ``name``
    (decorator)."""

    def deco(builder):
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = Scenario(name, doc or (builder.__doc__ or "").strip(),
                                   builder)
        return builder

    return deco


def get(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def available() -> dict[str, str]:
    """name -> one-line description, for CLIs and reports."""
    return {name: s.doc for name, s in sorted(_REGISTRY.items())}


def build(name: str, **kw) -> BuiltScenario:
    """One-shot: build scenario ``name`` with the given parameters."""
    return get(name).build(**kw)


def _is_chain(n: int, edges: list[tuple[int, int]]) -> bool:
    return sorted(edges) == [(k, k + 1) for k in range(n - 1)]


def check_bounds(built: BuiltScenario) -> None:
    """Assert the built instance satisfies everything its metadata declares.

    Property tests run this over many seeds; a failure means the generator
    broke its own contract, not that a scheduler misbehaved."""
    inst, meta = built.instance, built.meta
    assert meta.dag_family in DAG_FAMILIES, meta.dag_family
    assert meta.arrival in ARRIVALS, meta.arrival
    b = meta.bounds

    if "n_jobs_max" in b:
        assert inst.n <= b["n_jobs_max"], f"{inst.n} jobs > {b['n_jobs_max']}"
    releases = [j.release for j in inst.jobs]
    if meta.arrival == "offline":
        assert all(r == 0 for r in releases), "offline scenario has releases"
    else:
        assert all(r >= 0 for r in releases)
        assert releases == sorted(releases), "arrivals not in job order"

    for j in inst.jobs:
        # DAG family shape (acyclicity re-checked explicitly)
        topological_order(j.mu, j.edges)
        if meta.dag_family == "rooted_tree" and j.mu > 1:
            assert is_rooted_tree(j), f"job {j.jid} not a rooted tree"
        elif meta.dag_family == "chain":
            assert _is_chain(j.mu, j.edges), f"job {j.jid} not a chain"
        elif meta.dag_family == "independent":
            assert not j.edges, f"job {j.jid} has edges"
        if meta.weights == "equal":
            assert j.weight == 1.0
        else:
            assert 0.0 < j.weight <= 1.0
        if "mu_max" in b:
            assert j.mu <= b["mu_max"], f"job {j.jid}: mu {j.mu}"
        for c in j.coflows:
            pos = c.demand[c.demand > 0]
            assert pos.size > 0, f"coflow ({j.jid},{c.cid}) has zero demand"
            if "flow_min" in b:
                assert int(pos.min()) >= b["flow_min"]
            if "entry_max" in b:
                assert int(c.demand.max()) <= b["entry_max"]
            if "width_max" in b:
                assert int((c.demand > 0).sum()) <= b["width_max"]


def scheduler_opts(scheduler: str, meta: ScenarioMeta) -> dict:
    """Extra engine options a scheduler needs to run on this scenario.

    G-DM-RT's tree machinery needs ``require_tree=False`` on general-DAG
    workloads (DMA-SRT then falls back to precedence-exact start times);
    every other (scheduler, scenario) pair runs with defaults."""
    if scheduler.startswith("gdm_rt") and meta.dag_family == "general":
        return {"require_tree": False}
    return {}


def strip_releases(inst: Instance) -> Instance:
    """The release-0 (offline) view of an instance — the online/offline
    agreement invariant compares schedules on this."""
    import dataclasses

    return Instance(inst.m, [dataclasses.replace(j, release=0)
                             for j in inst.jobs])
