"""Scenario registry + workload zoo (mirrors the scheduler registry).

    from repro import scenarios

    scenarios.names()                       # ['alibaba_sparse', ..., 'fb_like', ...]
    built = scenarios.build("incast", m=48, seed=0, scale=0.5)
    built.instance                          # repro.core Instance
    built.meta                              # DAG family, arrival model, bounds
    scenarios.check_bounds(built)           # generator kept its contract

See ``registry.py`` for the machinery and ``zoo.py`` for the scenarios.
"""
from .registry import (BuiltScenario, Scenario, ScenarioMeta, available,
                       build, check_bounds, get, names, register,
                       scheduler_opts, strip_releases)
from . import zoo  # noqa: F401  (imports populate the registry)

__all__ = [
    "BuiltScenario",
    "Scenario",
    "ScenarioMeta",
    "available",
    "build",
    "check_bounds",
    "get",
    "names",
    "register",
    "scheduler_opts",
    "strip_releases",
]
