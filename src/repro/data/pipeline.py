"""Deterministic synthetic data pipeline.

Tokens are a pure function of (seed, step, row) via JAX's threefry — so any
worker can regenerate any batch: resume-after-failure and elastic re-sharding
need no data-loader state beyond the step counter, and straggler
re-assignment is a pure re-index. Host-sharded feeding: each dp shard asks
for rows [lo, hi) of the global batch.

For the paper's kind of multi-stage data-parallel jobs this mirrors the
deterministic shuffle+shard stage of a production loader; real corpora plug
in behind the same `batch_at(step)` interface.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig

__all__ = ["DataConfig", "SyntheticTokens", "make_batch_specs"]


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    """Deterministic LM token stream (documents of geometric length packed
    with an EOS separator, so the distribution is not trivially uniform)."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def batch_at(self, step: int, lo: int = 0, hi: int | None = None) -> dict:
        d = self.data
        hi = d.global_batch if hi is None else hi
        rows = hi - lo
        key = jax.random.fold_in(jax.random.PRNGKey(d.seed), step)
        keys = jax.random.split(key, d.global_batch)[lo:hi]
        toks = jax.vmap(self._row)(keys)
        batch = {"tokens": toks, "labels": self._labels(toks)}
        if self.cfg.family == "vlm":
            n_img = self.cfg.n_image_tokens
            pk = jax.random.fold_in(key, 7)
            batch = {
                "patches": jax.random.normal(
                    pk, (rows, n_img, self.cfg.d_model), jnp.float32) * 0.02,
                "tokens": toks,
                "labels": self._labels(toks),
            }
        elif self.cfg.family == "encdec":
            fk = jax.random.fold_in(key, 9)
            batch = {
                "frames": jax.random.normal(
                    fk, (rows, self.cfg.encoder_seq, self.cfg.d_model),
                    jnp.float32) * 0.02,
                "tokens": toks,
                "labels": self._labels(toks),
            }
        return batch

    def _row(self, key: jax.Array) -> jax.Array:
        """Markov-structured stream: with prob. 1/2 the next token is a fixed
        affine function of the current one, else fresh — so the corpus has
        ~0.5 bit/token of learnable structure (loss visibly decreases in
        integration tests) while staying a pure function of (seed, step, row).
        EOS(0) at ~1/64 emulates packed short documents."""
        d, v = self.data, self.cfg.vocab
        fresh = jax.random.randint(key, (d.seq_len,), 1, v)
        copy_gate = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5,
                                         (d.seq_len,))

        def step(prev, inp):
            f, g = inp
            nxt = jnp.where(g, (prev * 31 + 7) % (v - 1) + 1, f)
            return nxt, nxt

        _, toks = jax.lax.scan(step, fresh[0], (fresh, copy_gate))
        gates = jax.random.bernoulli(jax.random.fold_in(key, 1),
                                     1.0 / 64, (d.seq_len,))
        return jnp.where(gates, 0, toks)

    @staticmethod
    def _labels(tokens: jax.Array) -> jax.Array:
        return jnp.concatenate(
            [tokens[:, 1:], jnp.full((tokens.shape[0], 1), -1, tokens.dtype)],
            axis=1)


def make_batch_specs(cfg: ArchConfig, seq_len: int, global_batch: int) -> dict:
    """ShapeDtypeStruct stand-ins for a training batch (dry-run input)."""
    f = jax.ShapeDtypeStruct
    base = {
        "tokens": f((global_batch, seq_len), jnp.int32),
        "labels": f((global_batch, seq_len), jnp.int32),
    }
    if cfg.family == "vlm":
        text = seq_len - cfg.n_image_tokens
        base = {
            "patches": f((global_batch, cfg.n_image_tokens, cfg.d_model), jnp.float32),
            "tokens": f((global_batch, text), jnp.int32),
            "labels": f((global_batch, text), jnp.int32),
        }
    elif cfg.family == "encdec":
        base = {
            "frames": f((global_batch, cfg.encoder_seq, cfg.d_model), jnp.float32),
            "tokens": f((global_batch, seq_len), jnp.int32),
            "labels": f((global_batch, seq_len), jnp.int32),
        }
    return base
