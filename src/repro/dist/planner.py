"""Coflow collective planner: schedule a compiled step's collectives with
the paper's engine.

Pipeline (benchmarks/planner_ab.py and the dry-run harness drive it):

  1. `extract_collectives(hlo)` — parse the post-SPMD HLO for collective
     ops: kind, payload bytes (result tensor), and which mesh axis the
     replica groups span (consecutive device ids -> the minor "model" axis,
     strided -> "data").
  2. `coflows_from_step(ops, rows, cols, n_buckets)` — translate to a
     coflow Instance on the rows x cols pod fabric: ops are bucketed into
     jobs (contiguous program order, one job per gradient bucket); each op
     becomes one coflow whose demand matrix is the op's traffic pattern
     (ring over the axis its groups span; all-to-all is dense within
     groups); program order within a bucket becomes Starts-After edges.
  3. `plan(inst)` — submit the bucket jobs to a live
     `repro.core.session.SchedulerSession`, drain it under G-DM, and
     compare with the naive program-order one-at-a-time makespan.
  4. `bucket_order_from_plan(res, leaf_paths)` — translate the planned job
     permutation back into gradient-bucket launch order for
     `build_train_step(bucket_order=...)` (HLO dependency chains pin the
     collective launch order — the knob the paper's schedule turns).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from repro.core.types import Coflow, Instance, Job

__all__ = ["CollectiveOp", "extract_collectives", "coflows_from_step",
           "synthetic_collective_ops", "plan", "PlanOutcome",
           "bucket_order_from_plan"]

_BYTES_PER_UNIT = float(2 ** 20)   # one demand unit == 1 MiB on the fabric

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\]\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


@dataclass
class CollectiveOp:
    """One collective in program order: kind, payload bytes, index, and the
    mesh axis its replica groups span ("model" = minor/consecutive ids)."""

    kind: str
    bytes: float
    idx: int
    axis: str = "model"


def extract_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Collectives of a compiled (post-SPMD) HLO module, program order."""
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        numel = int(np.prod([int(d) for d in dims.split(",")])) if dims else 1
        nbytes = float(numel * _DTYPE_BYTES.get(dtype, 4))
        axis = "model"
        g = _GROUPS_RE.search(line)
        if g:
            ids = [int(x) for x in g.group(1).split(",")]
            consecutive = all(b - a == 1 for a, b in zip(ids, ids[1:]))
            axis = "model" if consecutive or len(ids) < 2 else "data"
        ops.append(CollectiveOp(kind, nbytes, len(ops), axis))
    return ops


def synthetic_collective_ops(
    n_ops: int = 12,
    seed: int = 0,
    max_mb: int = 8,
    kinds: tuple[str, ...] = ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all"),
) -> list[CollectiveOp]:
    """A seeded synthetic collective program (no HLO needed): `n_ops` ops in
    program order with payloads in [1, max_mb] MiB and random mesh axes.
    Feeds `coflows_from_step` when no compiled step is at hand — the
    `dist_collectives` scenario in `repro.scenarios` is built on this."""
    rng = np.random.default_rng(seed)
    ops: list[CollectiveOp] = []
    for i in range(max(1, n_ops)):
        kind = kinds[int(rng.integers(0, len(kinds)))]
        mb = int(rng.integers(1, max(1, max_mb) + 1))
        axis = "model" if rng.random() < 0.5 else "data"
        ops.append(CollectiveOp(kind, mb * _BYTES_PER_UNIT, i, axis))
    return ops


def _op_demand(op: CollectiveOp, rows: int, cols: int) -> np.ndarray:
    """Traffic pattern of one collective on the rows x cols fabric.

    "model"-axis groups are the rows (consecutive device ids); "data"-axis
    groups are the columns.  Ring algorithms move ~bytes per hop, so each
    directed ring edge carries the op's unit count; all-to-all is dense
    within each group at units/(k-1) per pair."""
    m = rows * cols
    d = np.zeros((m, m), dtype=np.int64)
    units = max(1, int(round(op.bytes / _BYTES_PER_UNIT)))
    if op.axis == "model":
        groups = [np.arange(r * cols, (r + 1) * cols) for r in range(rows)]
    else:
        groups = [np.arange(c, m, cols) for c in range(cols)]
    for g in groups:
        k = g.size
        if k < 2:
            continue
        if op.kind == "all-to-all":
            per = max(1, units // (k - 1))
            for i in range(k):
                for j in range(k):
                    if i != j:
                        d[g[i], g[j]] = per
        else:  # ring: all-reduce / all-gather / reduce-scatter / permute
            for i in range(k):
                d[g[i], g[(i + 1) % k]] = units
    return d


def coflows_from_step(
    ops: list[CollectiveOp], rows: int, cols: int, n_buckets: int,
) -> Instance:
    """Bucket the step's collectives into `n_buckets` chained jobs."""
    m = rows * cols
    ordered = sorted(ops, key=lambda o: o.idx)
    chunks = [c for c in np.array_split(np.arange(len(ordered)), n_buckets)
              if c.size]
    jobs: list[Job] = []
    for jid, chunk in enumerate(chunks):
        coflows = [Coflow(jid, k, _op_demand(ordered[i], rows, cols))
                   for k, i in enumerate(chunk)]
        edges = [(k, k + 1) for k in range(len(coflows) - 1)]
        jobs.append(Job(jid, coflows, edges, weight=1.0, release=0))
    return Instance(m, jobs)


@dataclass
class PlanOutcome:
    """Planned collective phase: job order + makespans vs naive."""

    order: list[int]                  # planned job (bucket) permutation
    planner_makespan: float
    naive_makespan: float             # program-order one-at-a-time
    schedule: object = None           # the engine PlanResult
    session: object = None            # the SchedulerSession it was planned on

    @property
    def makespan_gain(self) -> float:
        if self.naive_makespan <= 0:
            return 0.0
        return 1.0 - self.planner_makespan / self.naive_makespan


def plan(instance: Instance, beta: float | None = None,
         seed: int | None = None, session=None) -> PlanOutcome:
    """Plan the collective phase with G-DM against a live scheduling session.

    The step's bucket jobs are submitted to a
    :class:`repro.core.session.SchedulerSession` (a fresh one per call
    unless an existing `session` is passed) and the session is drained; the
    planned permutation and makespan are read from the session's plan.  The
    returned outcome keeps the session, so callers can keep submitting
    follow-up phases against the same live fabric state: colliding jids
    (``coflows_from_step`` numbers every phase 0..n-1) are transparently
    remapped to session-unique ids and the returned ``order`` is always in
    the CALLER's jid space, so ``bucket_order_from_plan`` keeps working
    across phases.  `beta`/`seed` configure the fresh session's scheduler
    (defaults 10.0 / 0); a shared session's scheduler options are fixed at
    its creation, so passing them together with `session` raises."""
    from repro.core.session import SchedulerSession

    if session is None:
        session = SchedulerSession(instance.m, "gdm",
                                   beta=10.0 if beta is None else beta,
                                   seed=0 if seed is None else seed)
    elif beta is not None or seed is not None:
        raise ValueError("beta/seed are fixed at session creation; do not "
                         "pass them together with an existing session")
    elif session.m != instance.m:
        raise ValueError(f"session is on {session.m} ports, "
                         f"instance on {instance.m}")
    t0 = session.now
    existing = set(session.snapshot().submitted)
    next_jid = max(existing | {j.jid for j in instance.jobs}, default=-1) + 1
    to_caller: dict[int, int] = {}
    for j in instance.jobs:
        if j.jid in existing:
            to_caller[next_jid] = j.jid
            j = j.remap(next_jid)
            next_jid += 1
        else:
            to_caller[j.jid] = j.jid
        session.submit(j)
    session.advance()
    res = session.result()
    g = session.last_plan
    if g is None:
        raise ValueError("session has no engine plan to read the order from "
                         "(transcript-only scheduler, or nothing submitted); "
                         "build the session with a registered scheduler name")
    # the last replan's Algorithm 5 permutation covers the jobs still in
    # flight at that point; jobs that drained before an earlier reschedule
    # (staggered releases) are prepended in completion order so `order` is
    # always a total permutation of this call's jobs — downstream
    # bucket_order_from_plan indexes buckets by every position
    order = [to_caller[jid] for jid in g.schedule.meta["order"]
             if jid in to_caller]
    seen = set(order)
    done_first = sorted((jid for jid in to_caller
                         if to_caller[jid] not in seen),
                        key=lambda jid: (res.job_completions[jid], jid))
    order = [to_caller[jid] for jid in done_first] + order
    makespan = max(res.job_completions[jid] for jid in to_caller) - t0
    # naive: buckets one at a time in program order; each bucket is a chain
    # of coflows, each taking exactly its effective size (BNA, Lemma 1)
    naive = float(sum(c.D for j in instance.jobs for c in j.coflows))
    return PlanOutcome(order=order, planner_makespan=float(makespan),
                       naive_makespan=naive, schedule=g, session=session)


def bucket_order_from_plan(
    res: PlanOutcome, leaf_paths: list[str],
) -> list[list[str]]:
    """Planned job permutation -> gradient-bucket launch order.

    Splits `leaf_paths` into len(res.order) contiguous buckets (bucket j
    holds job j's gradients) and emits them in the planned order, for
    build_train_step(bucket_order=...)."""
    chunks = np.array_split(np.asarray(leaf_paths, dtype=object),
                            len(res.order))
    return [list(chunks[j]) for j in res.order]
