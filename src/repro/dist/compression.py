"""Simulated gradient compression (quantize-dequantize).

Symmetric per-tensor int8 quantization applied to the gradient tree before
the optimizer update: the all-reduce payload the collective planner
schedules is the compressed one (4x smaller in bf16/f32 terms), and the
round-trip error is what training absorbs.  Runs inside jit; float leaves
only, everything else passes through untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_decompress"]


def compress_decompress(grads, bits: int = 8):
    """Quantize-dequantize every float leaf of `grads` to `bits` levels."""
    qmax = float(2 ** (bits - 1) - 1)

    def q(g):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g
        amax = jnp.max(jnp.abs(g))
        scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(g.dtype)
        return (jnp.clip(jnp.round(g / scale), -qmax, qmax) * scale).astype(g.dtype)

    return jax.tree.map(q, grads)
