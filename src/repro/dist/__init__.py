"""Distributed-systems layer: partition rules, the coflow collective
planner, and gradient compression.

``partition``   — PartitionSpec rule tables for every model family, ZeRO
                  optimizer-state sharding, batch specs, mesh dp axes.
``planner``     — the bridge between the paper's scheduler and a compiled
                  train step: extract collectives from HLO, translate them
                  to a coflow Instance on the pod fabric, plan it with the
                  core engine (G-DM), and translate the planned order back
                  into gradient-bucket launch order.
``compression`` — simulated gradient compression (quantize-dequantize),
                  shrinking the all-reduce payloads the planner schedules.
"""

__all__ = ["compression", "partition", "planner"]
