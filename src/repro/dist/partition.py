"""Parameter/batch partition rules (GSPMD PartitionSpecs).

Name-based rule table over the flattened param tree (see models/*.py for
the layouts; every leaf is stacked on a leading period dim `nP`):

  embed (V, D)            -> P("model", None)      vocab TP
  unembed (D, V)          -> P(None, "model")      vocab TP
  wq/wk/wv/w_gate/w_up    -> P(None, None, "model")   column split
  wo/w_down (3D)          -> P(None, "model", None)   row split
  moe w_gate/w_up/w_down  -> P(None, "model", None, None)  EP on experts
    (moe_ffn_tp=True instead splits the ffn dim: the TP-over-experts
     alternative layout the dry-run sweeps A/B)
  ssm in_proj / out_proj  -> column / row split
  norm scales, biases, router, ssm scalars -> replicated

`zero_pspecs` upgrades the param specs for ZeRO optimizer state: each
leaf's first still-unsharded, dp-divisible dimension is additionally
sharded over the data axes.

PartitionSpec subclasses tuple, so all tree construction here goes through
flatten/unflatten with explicit paths — never tree-mapping over spec trees
without `is_leaf`.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_pspecs", "zero_pspecs", "shardings", "batch_pspecs",
           "dp_axes"]

_DP_AXIS_ORDER = ("pod", "data")


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel mesh axes, outermost first."""
    return tuple(a for a in _DP_AXIS_ORDER if a in mesh.axis_names)


def _path_str(path) -> str:
    """'/'-joined tree path ('stack/l0/attn/wq') — the bucket-order key."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _leaf_rule(pathstr: str, name: str, nd: int, moe_ffn_tp: bool) -> P:
    if name == "embed":
        return P("model", None)
    if name == "unembed":
        return P(None, "model")
    if name == "scale" or name == "router" or "norm" in pathstr:
        return P()
    if "moe" in pathstr and name in ("w_gate", "w_up", "w_down") and nd == 4:
        if moe_ffn_tp:  # TP on the ffn dim instead of EP on experts
            if name == "w_down":
                return P(None, None, "model", None)
            return P(None, None, None, "model")
        return P(None, "model", None, None)
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj") and nd == 3:
        return P(None, None, "model")
    if name in ("wo", "w_down", "out_proj") and nd == 3:
        return P(None, "model", None)
    return P(*([None] * nd))


def param_pspecs(params, moe_ffn_tp: bool = False):
    """PartitionSpec tree mirroring `params` (abstract or concrete)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in leaves:
        pathstr = _path_str(path)
        name = pathstr.rsplit("/", 1)[-1]
        specs.append(_leaf_rule(pathstr, name, len(leaf.shape), moe_ffn_tp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero_pspecs(params, mesh: Mesh):
    """ZeRO: param specs + data-axis sharding of the first free divisible
    dim of each leaf (optimizer moments live fully sharded)."""
    dp = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp], dtype=np.int64)) \
        if dp else 1
    base = param_pspecs(params)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    base_specs = jax.tree_util.tree_leaves(
        base, is_leaf=lambda x: isinstance(x, P))
    out = []
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    for (path, leaf), spec in zip(leaves, base_specs):
        nd = len(leaf.shape)
        full = tuple(spec) + (None,) * (nd - len(tuple(spec)))
        if dp_entry is None:
            out.append(P(*full))
            continue
        upgraded = list(full)
        for i, ax in enumerate(full):
            if ax is None and leaf.shape[i] % max(dp_total, 1) == 0 \
                    and leaf.shape[i] > 0:
                upgraded[i] = dp_entry
                break
        out.append(P(*upgraded))
    return jax.tree_util.tree_unflatten(treedef, out)


def shardings(pspecs, mesh: Mesh):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(batch, mesh: Mesh):
    """Batch tree: leading dim sharded over the dp axes, rest replicated."""
    dp = dp_axes(mesh)
    entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    leaves, treedef = jax.tree_util.tree_flatten(batch)
    return jax.tree_util.tree_unflatten(treedef, [P(entry)] * len(leaves))
