"""Shared model configuration and primitives.

One `ArchConfig` describes every assigned architecture through a *layer
pattern*: a period of LayerSpecs repeated n_periods times. The stack is
executed as jax.lax.scan over stacked period parameters, so HLO size is
O(period), not O(layers) — essential for compiling 64-94-layer models in
the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LayerSpec", "MoESpec", "SSMSpec", "ArchConfig", "DTYPES"]

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # qwen3/granite renormalize top-k probs
    impl: str = "scatter"  # "scatter" (global routing, GSPMD) |
    #                        "shard_map" (per-dp-shard routing; the token
    #                        gather/scatter is provably shard-local, only the
    #                        expert all-to-all crosses the fabric — §Perf 6.3)


@dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    d_head: int = 64        # P
    expand: int = 2         # d_inner = expand * d_model
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"       # "attn" | "mamba"
    mlp: str = "dense"       # "dense" | "moe" | "none"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # "lm" | "encdec" | "vlm"
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    period: tuple[LayerSpec, ...]
    n_periods: int
    d_head: int = 128
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # encoder (enc-dec family only)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30 s of mel frames -> 1500
    # vlm family only
    n_image_tokens: int = 0          # anyres patch-embedding prefix (stub)
    # execution policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "auto"          # "auto" | "ref" | "chunked" | "pallas"
    attn_chunk: int = 1024
    remat: str = "none"              # "none" | "full" | "dots"
    loss_chunk: int = 2048           # 0 = unchunked (loop-free) loss
    scan_unroll: bool = False        # unroll layer scans (cost probes only)
    decode_cache_layout: str = "heads"  # "heads" | "dh" (see decode_attention)
    seq_parallel: bool = False       # Megatron-SP residual sharding on seq
    max_seq: int = 32768             # decode cache capacity default

    @property
    def n_layers(self) -> int:
        return len(self.period) * self.n_periods

    @property
    def sub_quadratic(self) -> bool:
        """True if the stack has no dense full-attention bottleneck at 500k
        (SSM or hybrid): the long_500k cell runs only for these."""
        kinds = {s.kind for s in self.period}
        return "mamba" in kinds

    def param_count(self) -> int:
        """Approximate parameter count (used for 6*N*D roofline math)."""
        from . import lm as _lm

        params = jax.eval_shape(lambda: _lm.init_lm(self, jax.random.PRNGKey(0)))
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding tables are padded to a multiple of 128 so
        the vocab dim shards cleanly on any mesh (production practice; the
        loss masks the padding columns)."""
        return (self.vocab + 127) // 128 * 128

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            d_model=64, n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128, vocab=256, n_periods=min(self.n_periods, 2), d_head=16,
            param_dtype="float32", compute_dtype="float32", max_seq=64,
            n_image_tokens=min(self.n_image_tokens, 8),
        )
        if self.moe:
            # capacity_factor high enough that smoke tests never drop tokens
            # (keeps prefill/decode exactly consistent with lm_forward)
            kw["moe"] = dataclasses.replace(self.moe, n_experts=4, top_k=2,
                                            d_ff_expert=32, capacity_factor=8.0)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, d_head=8,
                                            n_groups=1, chunk=16)
        if self.family == "encdec":
            kw["n_encoder_layers"] = 2
            kw["encoder_seq"] = 16
        return self.replace(name=self.name + "-smoke", **kw)
