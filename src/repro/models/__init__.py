"""Pure-JAX model zoo: one layer-pattern abstraction covers dense GQA
transformers, MoE, Mamba2 SSD, and hybrid (Jamba) stacks; encoder-decoder
(Whisper) and VLM (LLaVA) wrap the same building blocks."""

from .common import ArchConfig, LayerSpec, MoESpec, SSMSpec  # noqa: F401
from .lm import (decode_step, init_lm, init_decode_cache, lm_loss,  # noqa: F401
                 lm_forward, prefill)
from .encdec import (encdec_forward, encdec_loss, init_encdec,  # noqa: F401
                     encdec_prefill, encdec_decode_step, init_encdec_cache)
from .vlm import init_vlm, vlm_loss, vlm_prefill  # noqa: F401
