"""Decoder-only LM over the layer-pattern abstraction.

The stack is jax.lax.scan over `n_periods` copies of the period (stacked
params), so a 94-layer MoE model lowers to one period body — this keeps the
512-device dry-run compile tractable and is also how production frameworks
keep HLO size bounded.

Three entry points per architecture:
  lm_forward / lm_loss      — training (chunked vocab-sharded cross-entropy)
  prefill                   — build KV/SSM caches for a prompt
  decode_step               — one token against the cache (serve_step)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .common import ArchConfig, DTYPES
from .layers import (attn_block, decode_attention, init_attn, init_mlp,
                     init_norm, mlp_block, rms_norm, rope, _qkv)
from .moe import init_moe, moe_block
from .sharding import shard
from .ssm import (init_mamba, init_mamba_state, mamba_block,
                  mamba_decode_step)

__all__ = ["init_lm", "lm_forward", "lm_loss", "prefill", "decode_step",
           "init_decode_cache"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_period(cfg: ArchConfig, key: jax.Array) -> dict:
    p: dict[str, Any] = {}
    keys = jax.random.split(key, 2 * len(cfg.period))
    for i, spec in enumerate(cfg.period):
        lp: dict[str, Any] = {}
        if spec.kind == "attn":
            lp["attn"] = init_attn(cfg, keys[2 * i])
        elif spec.kind == "mamba":
            lp["mamba"] = init_mamba(cfg, keys[2 * i])
        else:
            raise ValueError(spec.kind)
        if spec.mlp == "dense":
            lp["mlp"] = init_mlp(cfg, keys[2 * i + 1])
        elif spec.mlp == "moe":
            lp["moe"] = init_moe(cfg, keys[2 * i + 1])
        p[f"l{i}"] = lp
    return p


def init_lm(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = DTYPES[cfg.param_dtype]
    k_embed, k_stack, k_out = jax.random.split(key, 3)
    period_keys = jax.random.split(k_stack, cfg.n_periods)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dt),
        "stack": jax.vmap(lambda k: _init_period(cfg, k))(period_keys),
        "final_norm": init_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(k_out, (cfg.d_model, cfg.padded_vocab))
            * cfg.d_model ** -0.5).astype(dt)
    return params


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------

def _apply_period(cfg: ArchConfig, pp: dict, x: jax.Array,
                  positions: jax.Array, causal: bool = True):
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.period):
        lp = pp[f"l{i}"]
        if spec.kind == "attn":
            x = attn_block(cfg, lp["attn"], x, positions, causal=causal)
        else:
            x = mamba_block(cfg, lp["mamba"], x)
        if spec.mlp == "dense":
            x = mlp_block(cfg, lp["mlp"], x)
        elif spec.mlp == "moe":
            x, a = moe_block(cfg, lp["moe"], x)
            aux = aux + a
        # Megatron-SP: keep the residual stream sequence-sharded on the TP
        # axis between blocks — norms/elementwise run sharded, and the TP
        # boundary collectives become all-gather/reduce-scatter pairs over
        # 1/TP of the activation bytes
        x = shard(x, ("dp", "model" if cfg.seq_parallel else None, None))
    return x, aux


def _maybe_remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)  # "full": save nothing


def hidden_states(cfg: ArchConfig, params: dict, x: jax.Array,
                  positions: jax.Array, causal: bool = True):
    """Run the stack on embedded inputs x: (B, S, d) -> (h, aux)."""

    def body(carry, pp):
        h, aux = carry
        h, a = _apply_period(cfg, pp, h, positions, causal=causal)
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(
        _maybe_remat(cfg, body),
        (x, jnp.zeros((), jnp.float32)), params["stack"],
        unroll=cfg.n_periods if cfg.scan_unroll else 1)
    return rms_norm(h, params["final_norm"], cfg.norm_eps), aux


def embed_tokens(cfg: ArchConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    return shard(x, ("dp", None, None))


def unembed_matrix(cfg: ArchConfig, params: dict) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def lm_forward(cfg: ArchConfig, params: dict, tokens: jax.Array,
               positions: jax.Array | None = None):
    """tokens: (B, S) -> (logits (B, S, V), aux)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, aux = hidden_states(cfg, params, embed_tokens(cfg, params, tokens), positions)
    logits = h @ unembed_matrix(cfg, params)
    return shard(logits, ("dp", None, "model")), aux


def lm_loss(cfg: ArchConfig, params: dict, tokens: jax.Array,
            labels: jax.Array, aux_weight: float = 0.01,
            loss_chunk: int | None = None, inputs_embeds: jax.Array | None = None):
    """Chunked vocab-sharded cross-entropy: logits are materialized one
    sequence chunk at a time, sharded on the vocab ("model") axis, so the
    (B, S, 152k) tensor never exists."""
    B, S = labels.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = inputs_embeds if inputs_embeds is not None else embed_tokens(cfg, params, tokens)
    h, aux = hidden_states(cfg, params, x, positions)
    w = unembed_matrix(cfg, params)

    if loss_chunk is None:
        loss_chunk = cfg.loss_chunk
    C = min(loss_chunk, S) if loss_chunk > 0 else S
    pad = (-S) % C
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nC = h.shape[1] // C
    hc = jnp.moveaxis(h.reshape(B, nC, C, cfg.d_model), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nC, C), 1, 0)

    vocab_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab

    def chunk_loss(carry, inp):
        hb, lb = inp
        logits = shard(hb @ w, ("dp", None, "model")).astype(jnp.float32)
        logits = jnp.where(vocab_mask, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        valid = lb >= 0
        nll = jnp.where(valid, logz - gold, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hc, lc))
    return total / jnp.maximum(count, 1) + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def _attn_prefill(cfg: ArchConfig, lp: dict, x, positions):
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, h, positions)
    from .layers import attention
    o = attention(cfg, q, k, v, causal=True)
    B, S, _, _ = o.shape
    x = x + o.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["wo"]
    return x, {"k": k, "v": v}


def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array,
            inputs_embeds: jax.Array | None = None):
    """Returns (last-position logits (B, V), cache pytree). Cache leaves are
    stacked per period (scan layout)."""
    B, S = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = inputs_embeds if inputs_embeds is not None else embed_tokens(cfg, params, tokens)

    def body(carry, pp):
        h = carry
        cache_p = {}
        for i, spec in enumerate(cfg.period):
            lp = pp[f"l{i}"]
            if spec.kind == "attn":
                h, kv = _attn_prefill(cfg, lp["attn"], h, positions)
                cache_p[f"l{i}"] = kv
            else:
                h, st = mamba_block(cfg, lp["mamba"], h, return_state=True)
                cache_p[f"l{i}"] = st
            if spec.mlp == "dense":
                h = mlp_block(cfg, lp["mlp"], h)
            elif spec.mlp == "moe":
                h, _ = moe_block(cfg, lp["moe"], h)
        return h, cache_p

    h, cache = jax.lax.scan(body, x, params["stack"],
                            unroll=cfg.n_periods if cfg.scan_unroll else 1)
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (h @ unembed_matrix(cfg, params))[:, 0, :cfg.vocab]
    return shard(logits, ("dp", None)), {"layers": cache, "length": jnp.full((), S, jnp.int32)}


def init_decode_cache(cfg: ArchConfig, batch: int, capacity: int) -> dict:
    """Empty cache at a given KV capacity (the decode_* dry-run cells)."""
    dt = DTYPES[cfg.compute_dtype]
    per = {}
    for i, spec in enumerate(cfg.period):
        if spec.kind == "attn":
            kv = lambda: shard(
                jnp.zeros((cfg.n_periods, batch, capacity, cfg.n_kv_heads, cfg.d_head), dt),
                (None, "dp", "sp", "model", None))
            per[f"l{i}"] = {"k": kv(), "v": kv()}
        else:
            st = init_mamba_state(cfg, batch, dt)
            per[f"l{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), st)
    return {"layers": per, "length": jnp.zeros((), jnp.int32)}


def decode_step(cfg: ArchConfig, params: dict, cache: dict, token: jax.Array):
    """token: (B, 1) -> (logits (B, V), new cache). One serve_step."""
    B = token.shape[0]
    length = cache["length"]
    positions = jnp.broadcast_to(length[None, None], (B, 1))
    x = embed_tokens(cfg, params, token)
    scale = cfg.d_head ** -0.5

    def body(h, inp):
        pp, cache_p = inp
        new_cache_p = {}
        for i, spec in enumerate(cfg.period):
            lp = pp[f"l{i}"]
            if spec.kind == "attn":
                ap = lp["attn"]
                hn = rms_norm(h, ap["norm"], cfg.norm_eps)
                q, k, v = _qkv(cfg, ap, hn, positions)
                kc = jax.lax.dynamic_update_slice_in_dim(
                    cache_p[f"l{i}"]["k"], k.astype(cache_p[f"l{i}"]["k"].dtype),
                    length, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    cache_p[f"l{i}"]["v"], v.astype(cache_p[f"l{i}"]["v"].dtype),
                    length, axis=1)
                o = decode_attention(q, kc, vc, length + 1, scale,
                                     layout=cfg.decode_cache_layout)
                h = h + o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ ap["wo"]
                new_cache_p[f"l{i}"] = {"k": kc, "v": vc}
            else:
                st, h = mamba_decode_step(cfg, lp["mamba"], cache_p[f"l{i}"], h)
                new_cache_p[f"l{i}"] = st
            if spec.mlp == "dense":
                h = mlp_block(cfg, lp["mlp"], h)
            elif spec.mlp == "moe":
                h, _ = moe_block(cfg, lp["moe"], h)
        return h, new_cache_p

    h, new_layers = jax.lax.scan(
        body, x, (params["stack"], cache["layers"]),
        unroll=cfg.n_periods if cfg.scan_unroll else 1)
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (h @ unembed_matrix(cfg, params))[:, 0, :cfg.vocab]
    return shard(logits, ("dp", None)), {"layers": new_layers, "length": length + 1}
