"""Encoder-decoder stack (Whisper backbone). The audio conv frontend is a
STUB per the assignment: `input_specs()` feeds precomputed mel-frame
embeddings (B, T_enc, d_model); the encoder is a non-causal transformer,
the decoder adds cross-attention. Positions are sinusoidal (stateless)
instead of Whisper's learned absolute tables — documented adaptation that
keeps 32k-length decoder stress shapes table-free."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, DTYPES
from .layers import (attention, decode_attention, init_attn, init_mlp,
                     init_norm, mlp_block, rms_norm, _qkv)
from .lm import unembed_matrix
from .sharding import shard

__all__ = ["init_encdec", "encdec_forward", "encdec_loss", "encdec_prefill",
           "encdec_decode_step", "init_encdec_cache", "sinusoidal"]


def sinusoidal(positions: jax.Array, d: int, dtype) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_enc_layer(cfg: ArchConfig, key: jax.Array) -> dict:
    k1, k2 = jax.random.split(key)
    return {"attn": init_attn(cfg, k1), "mlp": init_mlp(cfg, k2)}


def _init_dec_layer(cfg: ArchConfig, key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"attn": init_attn(cfg, k1), "cross": init_attn(cfg, k2),
            "mlp": init_mlp(cfg, k3)}


def init_encdec(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = DTYPES[cfg.param_dtype]
    ke, kd, kt, ko = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_periods)
    p = {
        "embed": (jax.random.normal(kt, (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dt),
        "enc_stack": jax.vmap(lambda k: _init_enc_layer(cfg, k))(enc_keys),
        "dec_stack": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dec_keys),
        "enc_norm": init_norm(cfg.d_model, dt),
        "final_norm": init_norm(cfg.d_model, dt),
        "unembed": (jax.random.normal(ko, (cfg.d_model, cfg.padded_vocab))
                    * cfg.d_model ** -0.5).astype(dt),
    }
    return p


def encode(cfg: ArchConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, d) precomputed embeddings (conv frontend stub)."""
    B, T, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = frames + sinusoidal(pos, cfg.d_model, frames.dtype)
    x = shard(x, ("dp", None, None))

    def body(h, lp):
        hn = rms_norm(h, lp["attn"]["norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp["attn"], hn, pos, rope_on=False)
        o = attention(cfg, q, k, v, causal=False)
        h = h + o.reshape(B, T, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"]
        h = mlp_block(cfg, lp["mlp"], h)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_stack"],
                        unroll=cfg.n_encoder_layers if cfg.scan_unroll else 1)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg: ArchConfig, lp: dict, enc_out: jax.Array):
    B, T, _ = enc_out.shape
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    k = (enc_out @ lp["wk"] + lp.get("bk", 0)).reshape(B, T, hkv, dh)
    v = (enc_out @ lp["wv"] + lp.get("bv", 0)).reshape(B, T, hkv, dh)
    return k, v


def _dec_layer(cfg: ArchConfig, lp: dict, h: jax.Array, pos: jax.Array,
               enc_out: jax.Array) -> jax.Array:
    B, S, _ = h.shape
    hn = rms_norm(h, lp["attn"]["norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp["attn"], hn, pos, rope_on=False)
    o = attention(cfg, q, k, v, causal=True)
    h = h + o.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"]
    # cross attention
    hn = rms_norm(h, lp["cross"]["norm"], cfg.norm_eps)
    qc = (hn @ lp["cross"]["wq"] + lp["cross"].get("bq", 0)).reshape(
        B, S, cfg.n_heads, cfg.d_head)
    kc, vc = _cross_kv(cfg, lp["cross"], enc_out)
    o = attention(cfg, qc, kc, vc, causal=False)
    h = h + o.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["cross"]["wo"]
    return mlp_block(cfg, lp["mlp"], h)


def encdec_forward(cfg: ArchConfig, params: dict, frames: jax.Array,
                   tokens: jax.Array):
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens] + sinusoidal(pos, cfg.d_model,
                                             params["embed"].dtype)
    x = shard(x, ("dp", None, None))

    def body(h, lp):
        return _dec_layer(cfg, lp, h, pos, enc_out), None

    x, _ = jax.lax.scan(body, x, params["dec_stack"],
                        unroll=cfg.n_periods if cfg.scan_unroll else 1)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]
    return shard(logits, ("dp", None, "model"))


def encdec_loss(cfg: ArchConfig, params: dict, frames: jax.Array,
                tokens: jax.Array, labels: jax.Array) -> jax.Array:
    logits = encdec_forward(cfg, params, frames, tokens).astype(jnp.float32)
    logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    valid = labels >= 0
    return jnp.where(valid, logz - gold, 0.0).sum() / jnp.maximum(valid.sum(), 1)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ArchConfig, batch: int, capacity: int) -> dict:
    dt = DTYPES[cfg.compute_dtype]
    L = cfg.n_periods
    kv = lambda s: jnp.zeros((L, batch, s, cfg.n_kv_heads, cfg.d_head), dt)
    return {
        "self_k": kv(capacity), "self_v": kv(capacity),
        "cross_k": kv(cfg.encoder_seq), "cross_v": kv(cfg.encoder_seq),
        "length": jnp.zeros((), jnp.int32),
    }


def encdec_prefill(cfg: ArchConfig, params: dict, frames: jax.Array,
                   tokens: jax.Array, capacity: int | None = None):
    """Encode + run the decoder prompt, building self- and cross-caches."""
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    cap = capacity or cfg.max_seq
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens] + sinusoidal(pos, cfg.d_model,
                                             params["embed"].dtype)

    def body(h, lp):
        hn = rms_norm(h, lp["attn"]["norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp["attn"], hn, pos, rope_on=False)
        o = attention(cfg, q, k, v, causal=True)
        h = h + o.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"]
        hn = rms_norm(h, lp["cross"]["norm"], cfg.norm_eps)
        qc = (hn @ lp["cross"]["wq"] + lp["cross"].get("bq", 0)).reshape(
            B, S, cfg.n_heads, cfg.d_head)
        kc, vc = _cross_kv(cfg, lp["cross"], enc_out)
        o = attention(cfg, qc, kc, vc, causal=False)
        h = h + o.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["cross"]["wo"]
        h = mlp_block(cfg, lp["mlp"], h)
        kpad = jnp.pad(k, ((0, 0), (0, cap - S), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, cap - S), (0, 0), (0, 0)))
        return h, {"self_k": kpad, "self_v": vpad, "cross_k": kc, "cross_v": vc}

    h, caches = jax.lax.scan(body, x, params["dec_stack"],
                             unroll=cfg.n_periods if cfg.scan_unroll else 1)
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (h @ params["unembed"])[:, 0, :cfg.vocab]
    cache = dict(caches, length=jnp.full((), S, jnp.int32))
    return shard(logits, ("dp", None)), cache


def encdec_decode_step(cfg: ArchConfig, params: dict, cache: dict,
                       token: jax.Array):
    B = token.shape[0]
    length = cache["length"]
    pos = jnp.broadcast_to(length[None, None], (B, 1))
    x = params["embed"][token] + sinusoidal(pos, cfg.d_model,
                                            params["embed"].dtype)
    scale = cfg.d_head ** -0.5

    def body(h, inp):
        lp, sk, sv, ck, cv = inp
        hn = rms_norm(h, lp["attn"]["norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp["attn"], hn, pos, rope_on=False)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), length, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), length, axis=1)
        o = decode_attention(q, sk, sv, length + 1, scale,
                             layout=cfg.decode_cache_layout)
        h = h + o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ lp["attn"]["wo"]
        hn = rms_norm(h, lp["cross"]["norm"], cfg.norm_eps)
        qc = (hn @ lp["cross"]["wq"] + lp["cross"].get("bq", 0)).reshape(
            B, 1, cfg.n_heads, cfg.d_head)
        o = decode_attention(qc, ck, cv, jnp.full((), ck.shape[1], jnp.int32),
                             scale, layout=cfg.decode_cache_layout)
        h = h + o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ lp["cross"]["wo"]
        h = mlp_block(cfg, lp["mlp"], h)
        return h, (sk, sv)

    h, (nsk, nsv) = jax.lax.scan(
        body, x, (params["dec_stack"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]),
        unroll=cfg.n_periods if cfg.scan_unroll else 1)
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (h @ params["unembed"])[:, 0, :cfg.vocab]
    new_cache = dict(cache, self_k=nsk, self_v=nsv, length=length + 1)
    return shard(logits, ("dp", None)), new_cache
