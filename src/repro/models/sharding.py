"""Logical sharding annotations for model code.

Models call `shard(x, ("dp", None, "model"))` with *logical* axis names;
outside a mesh context this is a no-op, inside one it becomes
with_sharding_constraint under the active rules. The rules map logical
names to mesh axes:

    dp    -> ("pod", "data") or ("data",)   batch / data parallel
    model -> ("model",)                      tensor / expert parallel
    sp    -> ("data",)                       sequence parallel (long decode)
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()

DEFAULT_RULES = {
    "dp": ("data",),
    "model": ("model",),
    "sp": ("data",),
}


@contextmanager
def mesh_context(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    rules = dict(rules or {})
    for k, v in DEFAULT_RULES.items():
        rules.setdefault(k, v)
    # drop rules referencing axes the mesh does not have
    rules = {k: tuple(a for a in v if a in mesh.axis_names)
             for k, v in rules.items()}
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def current_mesh() -> Mesh | None:
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def logical_spec(axes: tuple) -> P | None:
    st = getattr(_ctx, "state", None)
    if st is None:
        return None
    _, rules = st
    spec = []
    for a in axes:
        if a is None:
            spec.append(None)
        else:
            mapped = rules.get(a, ())
            spec.append(mapped if len(mapped) > 1 else (mapped[0] if mapped else None))
    return P(*spec)


def shard(x: jax.Array, axes: tuple) -> jax.Array:
    """Annotate x with a logical sharding; no-op outside mesh_context.
    Axes that do not divide the corresponding dimension are dropped (GSPMD
    would otherwise pad or involuntarily rematerialize — e.g. 4 kv heads
    cannot split over a 16-way model axis)."""
    st = getattr(_ctx, "state", None)
    if st is None:
        return x
    mesh, rules = st
    fixed = []
    for i, a in enumerate(axes):
        if a is None:
            fixed.append(None)
            continue
        mapped = rules.get(a, ())
        size = 1
        for ax in mapped:
            size *= mesh.shape[ax]
        if size <= 1 or i >= x.ndim or x.shape[i] % size != 0:
            fixed.append(None)
        else:
            fixed.append(mapped if len(mapped) > 1 else mapped[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
