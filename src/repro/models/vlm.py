"""VLM (LLaVA-NeXT) backbone: the assignment specifies the transformer
backbone only — the vision tower + anyres tiling is a STUB. `input_specs()`
supplies precomputed patch embeddings (B, n_image_tokens, d_model), already
projected into the LM embedding space; they occupy the first positions of
the sequence, text tokens fill the rest. Loss masks image positions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig
from .lm import embed_tokens, init_lm, lm_loss, prefill
from .sharding import shard

__all__ = ["init_vlm", "vlm_loss", "vlm_prefill"]


def init_vlm(cfg: ArchConfig, key: jax.Array) -> dict:
    return init_lm(cfg, key)


def _embeds(cfg: ArchConfig, params: dict, patches: jax.Array,
            tokens: jax.Array) -> jax.Array:
    text = embed_tokens(cfg, params, tokens)
    x = jnp.concatenate([patches.astype(text.dtype), text], axis=1)
    return shard(x, ("dp", None, None))


def vlm_loss(cfg: ArchConfig, params: dict, patches: jax.Array,
             tokens: jax.Array, labels: jax.Array) -> jax.Array:
    """patches: (B, n_img, d); tokens: (B, S_text); labels: (B, S_text).
    Total sequence length = n_img + S_text."""
    B, n_img = patches.shape[:2]
    x = _embeds(cfg, params, patches, tokens)
    full_labels = jnp.concatenate(
        [jnp.full((B, n_img), -1, labels.dtype), labels], axis=1)
    return lm_loss(cfg, params, None, full_labels, inputs_embeds=x)


def vlm_prefill(cfg: ArchConfig, params: dict, patches: jax.Array,
                tokens: jax.Array):
    x = _embeds(cfg, params, patches, tokens)
    dummy = jnp.zeros((x.shape[0], x.shape[1]), jnp.int32)
    return prefill(cfg, params, dummy, inputs_embeds=x)
