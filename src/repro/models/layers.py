"""Transformer building blocks: RMSNorm, RoPE, GQA attention (three
implementations: einsum ref, chunked online-softmax scan, Pallas flash),
SwiGLU MLP, embeddings. All functional; params are plain dict pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, DTYPES
from .sharding import shard

__all__ = ["rms_norm", "rope", "attention", "decode_attention", "swiglu",
           "init_attn", "init_mlp", "init_norm", "attn_block", "mlp_block"]


def init_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x: jax.Array, p: dict, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"].astype(x.dtype)


def _head_rms(x: jax.Array, eps: float) -> jax.Array:
    """qk_norm: RMS over the head dim (qwen3), no learned scale per-head
    position split (scale folded into the projection at init)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, d); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)                # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attn(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = DTYPES[cfg.param_dtype]
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "norm": init_norm(d, dt),
        "wq": (jax.random.normal(k1, (d, hq * dh)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, hkv * dh)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, hkv * dh)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (hq * dh, d)) * (hq * dh) ** -0.5).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dt)
        p["bk"] = jnp.zeros((hkv * dh,), dt)
        p["bv"] = jnp.zeros((hkv * dh,), dt)
    return p


def _qkv(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array,
         rope_on: bool = True):
    B, S, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"] + (p.get("bq", 0))
    k = x @ p["wk"] + (p.get("bk", 0))
    v = x @ p["wv"] + (p.get("bv", 0))
    q = q.reshape(B, S, hq, dh)
    k = k.reshape(B, S, hkv, dh)
    v = v.reshape(B, S, hkv, dh)
    if cfg.qk_norm:
        q = _head_rms(q, cfg.norm_eps)
        k = _head_rms(k, cfg.norm_eps)
    if rope_on:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q, ("dp", None, "model", None))
    k = shard(k, ("dp", None, "model", None))
    v = shard(v, ("dp", None, "model", None))
    return q, k, v


def _attn_ref(q, k, v, causal: bool, scale: float):
    """(B, S, H, d) layout einsum attention (small/smoke path)."""
    group = q.shape[2] // k.shape[2]
    kf = jnp.repeat(k, group, axis=2)
    vf = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf).astype(q.dtype)


def _attn_chunked(q, k, v, causal: bool, scale: float, chunk: int,
                  unroll: bool = False):
    """Flash-style online softmax as a pure-jnp lax.scan over key blocks:
    the memory profile of the Pallas kernel, expressible to GSPMD (used for
    long sequences in the dry-run lowering)."""
    B, Sq, Hq, d = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    C = min(chunk, Sk)
    pad = (-Sk) % C
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = k.shape[1] // C
    kb = jnp.moveaxis(k.reshape(B, nk, C, Hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, C, Hkv, d), 1, 0)
    qf = q.astype(jnp.float32)
    offs = Sk - Sq  # queries aligned to the end of keys

    def step(carry, inp):
        acc, mx, den = carry
        ik, kc, vc = inp
        kc = jnp.repeat(kc, group, axis=2).astype(jnp.float32)
        vc = jnp.repeat(vc, group, axis=2).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kc) * scale
        kpos = ik * C + jax.lax.broadcasted_iota(jnp.int32, (Sq, C), 1)
        valid = kpos < Sk
        if causal:
            qpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, C), 0) + offs
            valid = valid & (qpos >= kpos)
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(mx, s.max(-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mx - m_new)
        den = den * corr + pexp.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", pexp, vc)
        return (acc, m_new, den), None

    acc0 = jnp.zeros((B, Hq, Sq, d), jnp.float32)
    m0 = jnp.full((B, Hq, Sq), -1e30, jnp.float32)
    d0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    (acc, _, den), _ = jax.lax.scan(
        step, (acc0, m0, d0), (jnp.arange(nk), kb, vb),
        unroll=nk if unroll else 1)
    out = acc / jnp.maximum(den, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, Hq, d)


def attention(cfg: ArchConfig, q, k, v, causal: bool = True) -> jax.Array:
    """(B, S, H, d) in/out; implementation selected by cfg.attn_impl."""
    scale = cfg.d_head ** -0.5
    impl = cfg.attn_impl
    if impl == "auto":
        if jax.default_backend() == "tpu":
            impl = "pallas"
        else:
            impl = "chunked" if q.shape[1] * k.shape[1] > 1 << 22 else "ref"
    if impl == "pallas":
        # repro: allow(backend-dispatch): attn_impl="pallas" is the NN stack's own kernel switch, not scheduler backend dispatch
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                              jnp.moveaxis(v, 2, 1), causal=causal, scale=scale)
        return jnp.moveaxis(out, 1, 2)
    if impl == "chunked":
        return _attn_chunked(q, k, v, causal, scale, cfg.attn_chunk,
                             unroll=cfg.scan_unroll)
    return _attn_ref(q, k, v, causal, scale)


def decode_attention(q, k_cache, v_cache, length: jax.Array, scale: float,
                     layout: str = "heads"):
    """Single-token attention against a (B, S_max, Hkv, d) cache holding
    `length` valid entries. q: (B, 1, Hq, d).

    layout="dh": align the q/k contraction to a HEAD-DIM-sharded cache
    (TP-divisible for any kv-head count): the big cache stays put and the
    contraction emits small partial-score all-reduces — the §Perf fix for
    collective-bound decode."""
    B, Smax, Hkv, d = k_cache.shape
    group = q.shape[2] // Hkv
    # keep the big cache in its storage dtype; the dots accumulate in f32
    # (preferred_element_type) without materializing an f32 cache copy
    qf = q.reshape(B, Hkv, group, d)
    kf = k_cache
    if layout == "dh":
        qf = shard(qf, ("dp", None, None, "model"))
        kf = shard(kf, ("dp", None, None, "model"))
    elif layout == "seq":
        # flash-decode: cache sharded along the sequence; scores and the
        # softmax stats stay shard-local, only (B, Hkv, g, d)-sized partial
        # outputs cross the fabric
        kf = shard(kf, ("dp", "model", None, None))
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kf,
                   preferred_element_type=jnp.float32) * scale
    if layout == "dh":
        s = shard(s, ("dp", None, None, None))
    elif layout == "seq":
        s = shard(s, ("dp", None, None, "model"))
    valid = jnp.arange(Smax)[None, None, None, :] < length
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    vf = v_cache
    if layout == "dh":
        vf = shard(vf, ("dp", None, None, "model"))
    elif layout == "seq":
        vf = shard(vf, ("dp", "model", None, None))
        p = shard(p, ("dp", None, None, "model"))
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), vf,
                     preferred_element_type=jnp.float32)
    if layout == "dh":
        out = shard(out, ("dp", None, None, "model"))
    elif layout == "seq":
        out = shard(out, ("dp", None, None, None))
    return out.reshape(B, 1, q.shape[2], d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = DTYPES[cfg.param_dtype]
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm": init_norm(d, dt),
        "w_gate": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(k2, (d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k3, (f, d)) * f ** -0.5).astype(dt),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, ("dp", None, "model"))
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# blocks (pre-norm residual)
# ---------------------------------------------------------------------------

def attn_block(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array,
               causal: bool = True) -> jax.Array:
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h, positions)
    o = attention(cfg, q, k, v, causal=causal)
    B, S, _, _ = o.shape
    return x + o.reshape(B, S, cfg.n_heads * cfg.d_head) @ p["wo"]


def mlp_block(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    return x + swiglu(p, rms_norm(x, p["norm"], cfg.norm_eps))
