"""Mixture-of-Experts layer: top-k softmax router + sort-based capacity
dispatch (Megablocks-style, expressed with gather/scatter so GSPMD turns the
token movement into the expert all-to-all — the fan-in coflow pattern the
planner schedules).

Experts are sharded on the "model" mesh axis (EP); tokens stay sharded on
"dp". Capacity C = ceil(T * top_k / E * capacity_factor); overflowing
tokens are dropped (standard practice; smoke tests set the factor high
enough that nothing drops and the layer is exactly checkable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, DTYPES
from .layers import rms_norm
from .sharding import shard

__all__ = ["init_moe", "moe_block"]


def init_moe(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = DTYPES[cfg.param_dtype]
    spec = cfg.moe
    d, f, e = cfg.d_model, spec.d_ff_expert, spec.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm": {"scale": jnp.ones((d,), dt)},
        "router": (jax.random.normal(k1, (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(k3, (e, d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k4, (e, f, d)) * f ** -0.5).astype(dt),
    }


def moe_ffn(cfg: ArchConfig, p: dict, x: jax.Array,
            local_tokens: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). local_tokens=True runs inside
    shard_map's manual dp axes (token-dim constraints must be skipped;
    the "model" expert constraint still applies — it is an auto axis)."""
    spec = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = spec.n_experts, spec.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (T, k)
    if spec.router_norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # capacity: a single expert can receive at most T tokens (each token
    # routes to k *distinct* experts), so clamp there — this also makes
    # small-T decode steps drop-free.
    C = int(min(T, max(1, round(-(-T * k // E) * spec.capacity_factor))))

    # sort token-expert pairs by expert, rank within expert = position in
    # the sorted run; pairs beyond capacity drop.
    flat_e = idx.reshape(-1)                                 # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert = global position minus the expert's segment start
    # (arange, NOT cumsum(ones): a constant cumsum constant-folds through an
    # O(n*w) reduce-window in XLA and stalls 512-device compiles)
    cum = jnp.arange(se.size, dtype=se.dtype)
    seg_start = jnp.full((E,), T * k, cum.dtype).at[se].min(cum)
    rank = cum - seg_start[se]
    keep = rank < C
    slot = se * C + rank                                     # (T*k,) in [0, E*C)

    # scatter tokens into (E*C, d) buffers
    xbuf = jnp.zeros((E * C, d), x.dtype)
    xbuf = xbuf.at[jnp.where(keep, slot, E * C - 1)].add(
        jnp.where(keep[:, None], xt[st_], 0).astype(x.dtype))
    xbuf = xbuf.reshape(E, C, d)
    xbuf = shard(xbuf, ("model", None, None))

    h = jnp.einsum("ecd,edf->ecf", xbuf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xbuf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    y = y.reshape(E * C, d)

    # combine back to tokens with gate weights
    out = jnp.zeros((T, d), jnp.float32)
    contrib = jnp.where(keep[:, None], y[jnp.where(keep, slot, 0)].astype(jnp.float32)
                        * sg[:, None], 0.0)
    out = out.at[st_].add(contrib)
    out = out.astype(x.dtype).reshape(B, S, d)
    if local_tokens:
        return out, aux
    return shard(out, ("dp", None, None)), aux


def moe_ffn_shard_map(cfg: ArchConfig, p: dict, x: jax.Array):
    """Per-dp-shard routing under jax.shard_map (manual over the dp axes,
    auto over "model"): the token gather/scatter of the dispatch is provably
    LOCAL to each data shard — GSPMD cannot see that locality in the global
    formulation and replicates the scatters (the §Perf 6.3 pathology). The
    only cross-fabric movement left is the (E, C, d) buffer resharding onto
    the expert ("model") axis: the honest MoE all-to-all volume."""
    from .sharding import current_mesh, logical_spec
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    if mesh is None:  # single-device paths (smoke tests, serving on CPU)
        return moe_ffn(cfg, p, x)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local(xl, router, wg, wu, wd):
        lp = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        y, aux = moe_ffn(cfg, lp, xl, local_tokens=True)
        # NOTE: no pmean here — a scalar all-reduce inside manual axes trips
        # XLA:CPU's AllReducePromotion pass (crash observed at 256 devices);
        # per-shard aux values are returned sharded and averaged outside.
        return y, aux[None]

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(), P(), P(), P()),
        out_specs=(P(dp, None, None), P(dp)),
        axis_names=set(dp), check_vma=False)
    y, aux_shards = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, jnp.mean(aux_shards)


def moe_block(cfg: ArchConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    if cfg.moe.impl == "shard_map":
        y, aux = moe_ffn_shard_map(cfg, p, h)
    else:
        y, aux = moe_ffn(cfg, p, h)
    return x + y, aux
