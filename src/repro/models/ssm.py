"""Mamba2 (SSD) block: in_proj -> [z | x | B | C | dt], short depthwise
conv over (x, B, C), SSD scan (Pallas kernel on TPU, chunked/sequential jnp
elsewhere), gated RMSNorm, out_proj. Decode keeps O(1) state per layer:
(h: (B, H, N, P), conv window: (B, d_conv-1, conv_channels)) — this is what
makes the long_500k cell tractable for SSM/hybrid architectures."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, DTYPES
from .layers import rms_norm
from .sharding import shard

__all__ = ["init_mamba", "mamba_block", "mamba_decode_step", "init_mamba_state"]


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.d_head
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, H, conv_ch


def init_mamba(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = DTYPES[cfg.param_dtype]
    s, d_inner, H, conv_ch = _dims(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    return {
        "pre_norm": {"scale": jnp.ones((d,), dt)},
        "in_proj": (jax.random.normal(k1, (d, in_dim)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_ch)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((H,), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), dt)},
        "out_proj": (jax.random.normal(k3, (d_inner, d)) * d_inner ** -0.5).astype(dt),
    }


def _split(cfg: ArchConfig, proj: jax.Array):
    s, d_inner, H, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt_raw


def _conv(cfg: ArchConfig, p: dict, xbc: jax.Array) -> jax.Array:
    """Causal depthwise conv along S: xbc (B, S, C)."""
    s = cfg.ssm
    w = p["conv_w"]                                  # (K, C)
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + p["conv_b"])


def _ssd_inputs(cfg: ArchConfig, p: dict, xbc: jax.Array, dt_raw: jax.Array):
    s, d_inner, H, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    B_, S = xbc.shape[0], xbc.shape[1]
    x, b, c = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    x = x.reshape(B_, S, H, s.d_head)
    b = b.reshape(B_, S, s.n_groups, s.d_state)
    c = c.reshape(B_, S, s.n_groups, s.d_state)
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = jnp.exp(-jnp.exp(p["a_log"]) * dt_v)                            # decay (0,1]
    x_in = x * dt_v[..., None].astype(x.dtype)
    return x, x_in, a, b, c


def mamba_block(cfg: ArchConfig, p: dict, x: jax.Array,
                return_state: bool = False):
    s, d_inner, H, conv_ch = _dims(cfg)
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xbc_raw, dt_raw = _split(cfg, proj)
    xbc = _conv(cfg, p, xbc_raw)
    xs, x_in, a, b, c = _ssd_inputs(cfg, p, xbc, dt_raw)
    xs = shard(xs, ("dp", None, "model", None))
    use_kernel = (cfg.attn_impl == "pallas"
                  or (cfg.attn_impl == "auto" and jax.default_backend() == "tpu"))
    if use_kernel and not return_state:
        # repro: allow(backend-dispatch): use_kernel is the NN stack's own kernel switch, not scheduler backend dispatch
        from repro.kernels.ssd_scan import ssd_scan
        y = ssd_scan(x_in, a, b, c, chunk=s.chunk)
        hfinal = None
    else:
        y, hfinal = _ssd_chunked_jnp(x_in, a, b, c, s.chunk)
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(x.shape[0], x.shape[1], d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + y @ p["out_proj"]
    if not return_state:
        return out
    # decode handoff state: final SSD state + last (d_conv - 1) raw conv inputs
    K = s.d_conv
    S = x.shape[1]
    if S >= K - 1:
        conv_state = xbc_raw[:, S - (K - 1):, :]
    else:
        conv_state = jnp.pad(xbc_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"h": hfinal, "conv": conv_state}


def _ssd_chunked_jnp(x, a, b, c, chunk: int):
    """Chunked SSD in pure jnp — loop-free formulation: all intra-chunk
    terms are batched over chunks, and the inter-chunk state recurrence is a
    log-depth jax.lax.associative_scan. No `while` in the lowering (exact
    XLA cost accounting for the roofline) and better TPU parallelism than a
    sequential chunk scan; identical math to the Pallas kernel."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nC = Sp // L
    xf = x.reshape(B, nC, L, H, P).astype(jnp.float32)
    la = jnp.log(jnp.maximum(a, 1e-37)).reshape(B, nC, L, H).astype(jnp.float32)
    bf = jnp.repeat(b, rep, axis=2).reshape(B, nC, L, H, N).astype(jnp.float32)
    cf = jnp.repeat(c, rep, axis=2).reshape(B, nC, L, H, N).astype(jnp.float32)

    cum = jnp.cumsum(la, axis=2)                       # (B,nC,L,H)
    tot = cum[:, :, -1, :]                             # (B,nC,H) per-chunk log decay
    tri = jnp.tril(jnp.ones((L, L), bool))

    # intra-chunk (batched over chunks)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bclhn,bckhn->bclkh", cf, bf) * mask
    y = jnp.einsum("bclkh,bckhp->bclhp", scores, xf)

    # per-chunk state contribution S_c = sum_i exp(tot - cum_i) b_i x_i^T
    wb = bf * jnp.exp(tot[:, :, None, :] - cum)[..., None]
    Sc = jnp.einsum("bclhn,bclhp->bchnp", wb, xf)      # (B,nC,H,N,P)

    # inter-chunk recurrence h_{c} = A_c h_{c-1} + S_c via associative scan
    # combine: (A1,S1) o (A2,S2) = (A1*A2, S1*A2 + S2); then shift right
    def combine(lhs, rhs):
        A1, S1 = lhs
        A2, S2 = rhs
        return A1 * A2, S1 * A2[..., None, None] + S2

    A = jnp.exp(tot)                                   # (B,nC,H)
    Ah, Sh = jax.lax.associative_scan(combine, (A, Sc), axis=1)
    # state ENTERING chunk c = h_{c-1}: shift; h before chunk 0 is 0
    h_in = jnp.concatenate(
        [jnp.zeros_like(Sh[:, :1]), Sh[:, :-1]], axis=1)
    y += jnp.exp(cum)[..., None] * jnp.einsum("bclhn,bchnp->bclhp", cf, h_in)
    hf = Sh[:, -1]                                     # final state (B,H,N,P)
    y = y.reshape(B, Sp, H, P)[:, :S]
    return y.astype(x.dtype), hf


# ---------------------------------------------------------------------------
# decode (O(1) state)
# ---------------------------------------------------------------------------

def init_mamba_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    s, d_inner, H, conv_ch = _dims(cfg)
    return {
        "h": jnp.zeros((batch, H, s.d_state, s.d_head), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
    }


def mamba_decode_step(cfg: ArchConfig, p: dict, state: dict, x: jax.Array):
    """x: (B, 1, d) -> (new_state, y (B, 1, d))."""
    s, d_inner, H, conv_ch = _dims(cfg)
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xbc, dt_raw = _split(cfg, proj)
    window = jnp.concatenate([state["conv"], xbc], axis=1)     # (B, K, C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])[:, None, :]
    new_conv = window[:, 1:, :]
    xs, x_in, a, b, c = _ssd_inputs(cfg, p, conv_out, dt_raw)
    rep = H // s.n_groups
    # repro: allow(backend-dispatch): decode-step ref is pure jnp math shared with the kernel package, no dispatch layer exists for it
    from repro.kernels.ssd_scan.ref import ssd_decode_step
    hs, y = ssd_decode_step(state["h"], x_in[:, 0], a[:, 0], b[:, 0], c[:, 0])
    y = y[:, None] + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(x.shape[0], 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return {"h": hs, "conv": new_conv}, x + y @ p["out_proj"]
