"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060].

Mamba2 defaults: expand=2 (d_inner=5120), headdim=64 (80 SSD heads),
1 state group, conv width 4."""
from repro.models.common import ArchConfig, LayerSpec, SSMSpec

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="lm",
    d_model=2560,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_head=64,
    d_ff=0,             # no MLP: the mamba block is the whole layer
    vocab=50280,
    period=(LayerSpec("mamba", "none"),),
    n_periods=64,
    ssm=SSMSpec(d_state=128, d_head=64, expand=2, n_groups=1, d_conv=4),
    remat="full",
)
