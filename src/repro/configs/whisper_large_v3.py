"""whisper-large-v3 [audio]: enc-dec, 32L d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866 — conv frontend STUB [arXiv:2212.04356].

The assignment's shapes apply to the DECODER stream; the encoder runs the
standard 1500 mel-frame window as precomputed embeddings from input_specs()
(frontend stub). Positions are sinusoidal (adaptation noted in DESIGN.md)."""
from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab=51866,
    period=(LayerSpec("attn", "dense"),),
    n_periods=32,          # decoder layers
    n_encoder_layers=32,
    encoder_seq=1500,
    rope_theta=1e4,
    remat="full",
)
