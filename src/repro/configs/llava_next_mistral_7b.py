"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Vision tower + anyres tiling is a STUB: input_specs() supplies precomputed
patch embeddings (anyres 4+1 tiles x 576 = 2880 image tokens) occupying the
first positions of the sequence. Mistral's 4096 sliding window is widened
to full causal attention (adaptation noted in DESIGN.md)."""
from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=32000,
    period=(LayerSpec("attn", "dense"),),
    n_periods=32,
    n_image_tokens=2880,
    rope_theta=1e6,
    remat="full",
)
