"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(expert) vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.common import ArchConfig, LayerSpec, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="lm",
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab=151936,
    period=(LayerSpec("attn", "moe"),),
    n_periods=94,
    moe=MoESpec(n_experts=128, top_k=8, d_ff_expert=1536),
    qk_norm=True,
    rope_theta=1e6,
    remat="full",
)
