"""tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 — llama2-arch small [arXiv:2401.02385; hf]."""
from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="tinyllama-1.1b",
    family="lm",
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=64,
    d_ff=5632,
    vocab=32000,
    period=(LayerSpec("attn", "dense"),),
    n_periods=22,
    rope_theta=1e4,
    remat="full",
)
