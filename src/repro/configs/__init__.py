"""Architecture registry: the 10 assigned architectures (exact configs from
the assignment table) + the paper's own coflow-simulation config. Each
<id>.py exports CONFIG; get_config/list_configs resolve by id.

Shapes (assignment): every LM-family arch pairs with
    train_4k     seq 4096,  global batch 256   (train_step)
    prefill_32k  seq 32768, global batch 32    (serve prefill)
    decode_32k   seq 32768 KV, global batch 128 (serve decode, 1 new token)
    long_500k    seq 524288 KV, global batch 1  (long-context decode)
long_500k runs only for sub-quadratic stacks (SSM/hybrid); pure
full-attention archs skip it (recorded, per the assignment brief).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.common import ArchConfig

ARCH_IDS = [
    "qwen2_5_32b",
    "qwen3_1_7b",
    "qwen3_4b",
    "tinyllama_1_1b",
    "jamba_1_5_large",
    "mamba2_2_7b",
    "qwen3_moe_235b",
    "granite_moe_3b",
    "whisper_large_v3",
    "llava_next_mistral_7b",
]

# assignment ids use dashes/dots; map both spellings
ALIASES = {
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen3-4b": "qwen3_4b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "whisper-large-v3": "whisper_large_v3",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch_id: str) -> ArchConfig:
    arch_id = ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def list_configs() -> list[str]:
    return list(ARCH_IDS)


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k dense decode excluded (quadratic-attention rule)"
    return True, ""


def cells(arch_id: str) -> list[tuple[str, bool, str]]:
    cfg = get_config(arch_id)
    return [(s, *shape_applicable(cfg, s)) for s in SHAPES]
