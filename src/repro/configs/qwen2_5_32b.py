"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from repro.models.common import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="lm",
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=27648,
    vocab=152064,
    period=(LayerSpec("attn", "dense"),),
    n_periods=64,
    qkv_bias=True,
    qk_norm=False,
    rope_theta=1e6,
    remat="full",
)
