"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
(expert) vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.common import ArchConfig, LayerSpec, MoESpec

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="lm",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab=49155,
    period=(LayerSpec("attn", "moe"),),
    n_periods=32,
    moe=MoESpec(n_experts=40, top_k=8, d_ff_expert=512),
    rope_theta=1e4,
    remat="full",
)
