"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave
[arXiv:2403.19887; hf].

Layer pattern: period of 8 = 7 mamba + 1 attention (position 4, Jamba's
placement), MoE on every other layer (odd positions), dense MLP elsewhere.
Jamba's Mamba-1 layers are realized with our Mamba2/SSD block (the SSD
duality form — TPU-native adaptation recorded in DESIGN.md)."""
from repro.models.common import ArchConfig, LayerSpec, MoESpec, SSMSpec

_period = tuple(
    LayerSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="lm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    period=_period,
    n_periods=9,
    moe=MoESpec(n_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMSpec(d_state=128, d_head=64, expand=2, n_groups=8, d_conv=4),
    rope_theta=1e6,
    remat="full",
)
