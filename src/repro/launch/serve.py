"""Serving launcher (smoke-scale on CPU): batched requests through the
continuous-batching engine with coflow-ordered admission.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 8
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.serve import Request, ServeConfig, ServingEngine
from repro.train.step import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--admission", choices=("coflow", "fifo"), default="coflow")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    if cfg.family != "lm":
        cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                tokens=rng.integers(1, cfg.vocab, size=rng.integers(4, 17)),
                max_new=args.max_new,
                weight=float(rng.uniform(0.5, 2.0)),
                arrival=float(i // 2))
        for i in range(args.requests)
    ]
    eng = ServingEngine(cfg, params, ServeConfig(
        slots=args.slots, capacity=64, admission=args.admission))
    stats = eng.run(reqs)
    print(json.dumps({**stats, "admission": args.admission}))


if __name__ == "__main__":
    main()
