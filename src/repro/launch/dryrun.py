import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
# the production mesh (16x16 single pod / 2x16x16 multi-pod) with
# ShapeDtypeStruct inputs — zero allocation — and extract the roofline terms
# from the compiled artifact.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--out f.json]
#
# The XLA_FLAGS assignment above MUST stay the first statement: jax locks
# the device count at first backend init (hence no module docstring).

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.dist.partition import (batch_pspecs, dp_axes, param_pspecs,
                                  shardings)
from repro.launch.mesh import make_production_mesh, mesh_rules
from repro.launch.specs import abstract_cache, abstract_params, abstract_state, input_specs
from repro.models.sharding import mesh_context

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"

# --- TPU v5e roofline constants (targets; this container is CPU-only) -----
PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes of every collective op in the compiled (post-SPMD)
    module, bucketed by op kind. Post-optimization HLO annotates types on
    the RESULT, so we size the result tensor(s): exact for all-reduce /
    collective-permute / all-to-all (result == operand), the gathered size
    for all-gather, the post-reduce shard for reduce-scatter — a consistent
    per-chip traffic proxy (documented in EXPERIMENTS.md)."""
    out: dict[str, float] = {}
    n_ops = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        lhs = line[: m.start(1)]
        total = 0.0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + total
        n_ops += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["n_ops"] = n_ops
    return out


def _cache_pspecs(cfg, mesh, batch: int, seq_shard: bool,
                  layout: str = "heads"):
    """Decode-cache partition specs. seq_shard=True (long_500k, batch 1)
    shards the KV/conv sequence axis on "data" (SP) instead of batch.

    layout="heads": KV sharded on the kv-head dim (baseline; replicates
      when n_kv_heads < TP, which GSPMD then gathers — the collective-bound
      decode baseline in §Perf).
    layout="dh": KV sharded on the HEAD-DIM axis (always TP-divisible);
      q@k contracts over the sharded axis into small partial-score
      all-reduces instead of gathering the cache (§Perf hillclimb)."""
    dp = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    bdim = dp if batch % max(dp_total, 1) == 0 and batch >= dp_total else None

    def leaf_spec(path_leaf):
        path, leaf = path_leaf
        nd = len(leaf.shape)
        name = path[-1]
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            # (nP, B, S, Hkv, dh)
            if layout == "dh":
                return P(None, None if seq_shard else bdim,
                         "data" if seq_shard else None, None, "model")
            if layout == "seq":
                return P(None, None if seq_shard else bdim, "model", None, None)
            return P(None, None if seq_shard else bdim,
                     "data" if seq_shard else None, "model", None)
        if name == "h":     # (nP, B, H, N, P)
            return P(None, bdim, "model", None, None)
        if name == "conv":  # (nP, B, K-1, C)
            return P(None, bdim, None, "model")
        if name == "length":
            return P()
        return P(*([None] * nd))

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return leaf_spec((path, tree))

    return walk


def build_cell(cfg, shape_name: str, mesh, variant: dict | None = None):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    variant = variant or {}
    cfg = cfg.replace(**variant.get("config", {}))
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    rules = mesh_rules(mesh)
    params_abs = abstract_params(cfg)
    p_pspecs = param_pspecs(params_abs, moe_ffn_tp=variant.get("moe_ffn_tp", False))
    p_sh = shardings(p_pspecs, mesh)
    dp = dp_axes(mesh)

    if shape.kind == "train":
        from repro.train.optim import OptConfig
        from repro.train.step import build_train_step

        state_abs = abstract_state(cfg)
        st_pspecs = {
            "params": p_pspecs,
            "opt": {"m": p_pspecs, "v": p_pspecs, "step": P()},
            "step": P(),
        }
        if variant.get("zero"):
            from repro.dist.partition import zero_pspecs
            zp = zero_pspecs(params_abs, mesh)
            st_pspecs["opt"]["m"] = zp
            st_pspecs["opt"]["v"] = zp
        st_sh = shardings(st_pspecs, mesh)
        b_sh = jax.tree.map(lambda _: NamedSharding(mesh, P(dp)), specs["batch"])
        step = build_train_step(
            cfg, OptConfig(), micro_steps=variant.get("micro_steps", 1),
            bucket_order=variant.get("bucket_order"),
            grad_compression=variant.get("grad_compression", False))

        def fn(state, batch):
            with mesh_context(mesh, rules):
                return step(state, batch)

        # TrainState is a pytree; pass shardings via matching pytree
        from repro.train.step import TrainState
        st_sh_tree = TrainState(params=st_sh["params"], opt=st_sh["opt"],
                                step=st_sh["step"])
        return fn, (state_abs, specs["batch"]), (st_sh_tree, b_sh), (st_sh_tree, None)

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            from repro.models import encdec_prefill

            def fn(params, frames, tokens):
                with mesh_context(mesh, rules):
                    return encdec_prefill(cfg, params, frames, tokens,
                                          capacity=shape.seq_len)
            args = (params_abs, specs["frames"], specs["tokens"])
            in_sh = (p_sh, NamedSharding(mesh, P(dp)), NamedSharding(mesh, P(dp)))
        elif cfg.family == "vlm":
            from repro.models import vlm_prefill

            def fn(params, patches, tokens):
                with mesh_context(mesh, rules):
                    return vlm_prefill(cfg, params, patches, tokens)
            args = (params_abs, specs["patches"], specs["tokens"])
            in_sh = (p_sh, NamedSharding(mesh, P(dp)), NamedSharding(mesh, P(dp)))
        else:
            from repro.models import prefill

            def fn(params, tokens):
                with mesh_context(mesh, rules):
                    return prefill(cfg, params, tokens)
            args = (params_abs, specs["tokens"])
            in_sh = (p_sh, NamedSharding(mesh, P(dp)))
        return fn, args, in_sh, None

    # decode
    seq_shard = shape.global_batch == 1
    cache_abs = specs["cache"]
    c_pspecs = _cache_pspecs(cfg, mesh, shape.global_batch, seq_shard,
                             layout=variant.get("cache_layout", "heads"))(cache_abs)
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(
        mesh, P(dp if shape.global_batch > 1 else None, None))
    if cfg.family == "encdec":
        from repro.models import encdec_decode_step
        fn_raw = lambda params, cache, token: encdec_decode_step(cfg, params, cache, token)
    else:
        from repro.models import decode_step
        fn_raw = lambda params, cache, token: decode_step(cfg, params, cache, token)

    def fn(params, cache, token):
        with mesh_context(mesh, rules):
            return fn_raw(params, cache, token)

    return (fn, (params_abs, cache_abs, specs["token"]),
            (p_sh, c_sh, tok_sh), (None, c_sh))


def _sanitize_shardings(sh_tree, abs_tree, mesh):
    """Drop sharding axes that do not divide the corresponding dim (jit arg
    shardings require exact divisibility; e.g. 4 kv-head caches cannot split
    a 16-way model axis — those dims fall back to replication)."""
    def fix(sh, ab):
        if not isinstance(sh, NamedSharding):
            return sh
        dims = list(sh.spec) + [None] * (len(ab.shape) - len(sh.spec))
        out = []
        for i, ax in enumerate(dims):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            out.append(ax if ab.shape[i] % size == 0 else None)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, sh_tree, abs_tree)


def _compile_cell(cfg, shape_name, mesh, variant):
    fn, args, in_sh, out_sh = build_cell(cfg, shape_name, mesh, variant)
    in_sh = tuple(_sanitize_shardings(s, a, mesh) for s, a in zip(in_sh, args))
    if out_sh is not None:
        out_eval = jax.eval_shape(fn, *args)
        out_sh = tuple(
            _sanitize_shardings(s, a, mesh) if s is not None else None
            for s, a in zip(out_sh, out_eval))
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*args)
    return lowered.compile()


def _extract_cost(compiled) -> dict:
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in ca:
                cost[k] = float(ca[k])
    except Exception as e:
        cost["error"] = str(e)
    return cost


def _numeric_extrapolate(base: dict, plus: list[tuple[dict, int]]) -> dict:
    """base = depth-1 metrics; plus = [(depth-2 metrics, extra_repeats)]:
    result = base + sum(extra_repeats * (d2 - base)) per numeric key.
    Per-kind values are clamped at 0 (the partitioner can legitimately swap
    e.g. an all-gather at depth 1 for a reduce-scatter at depth 2; only the
    clamped per-kind split and the recomputed total are reported)."""
    out = dict(base)
    for d2, extra in plus:
        for k, v in d2.items():
            if isinstance(v, (int, float)) and isinstance(base.get(k), (int, float)):
                out[k] = out.get(k, 0.0) + extra * (v - base[k])
    out = {k: max(v, 0.0) for k, v in out.items()
           if isinstance(v, (int, float))}
    if "total" in out:
        out["total"] = sum(v for k, v in out.items()
                           if k not in ("total", "n_ops"))
    return out


def cost_probe(cfg, shape_name: str, mesh, variant: dict | None) -> tuple[dict, dict]:
    """Loop-aware HLO cost: XLA's cost_analysis counts a `while` body once,
    so lowering the same step at stack depth 1 and 2 and extrapolating
    linearly reconstructs the full-depth cost EXACTLY for scan-structured
    programs (validated in tests against an unrolled small model). Cost
    probes force loop-free attention (einsum ref — same FLOPs as the
    blocked kernel) and unchunked loss; memory/HLO text still come from the
    full production compile in run_cell."""
    base_over = {"attn_impl": "chunked", "loss_chunk": 0, "scan_unroll": True}
    variant = dict(variant or {})
    variant.pop("micro_steps", None)  # same total flops; avoids the acc loop

    def probe(npd, nenc):
        c = cfg.replace(n_periods=npd, **base_over)
        if nenc is not None:
            c = c.replace(n_encoder_layers=nenc)
        compiled = _compile_cell(c, shape_name, mesh, variant)
        return _extract_cost(compiled), collective_bytes(compiled.as_text())

    if cfg.family == "encdec":
        (c11, k11) = probe(1, 1)
        (c21, k21) = probe(2, 1)
        (c12, k12) = probe(1, 2)
        cost = _numeric_extrapolate(
            c11, [(c21, cfg.n_periods - 1), (c12, cfg.n_encoder_layers - 1)])
        coll = _numeric_extrapolate(
            k11, [(k21, cfg.n_periods - 1), (k12, cfg.n_encoder_layers - 1)])
    else:
        (c1, k1) = probe(1, None)
        (c2, k2) = probe(2, None)
        cost = _numeric_extrapolate(c1, [(c2, cfg.n_periods - 1)])
        coll = _numeric_extrapolate(k1, [(k2, cfg.n_periods - 1)])
    return cost, coll


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             variant: dict | None = None, verbose: bool = True,
             probe_cost: bool = True) -> dict:
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    if variant and "config" in variant:
        cfg = cfg.replace(**variant["config"])
    t0 = time.time()
    compiled = _compile_cell(cfg, shape_name, mesh, variant)
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem[k] = int(getattr(ma, k, 0) or 0)
        mem["per_device_total_gib"] = round(
            (mem.get("argument_size_in_bytes", 0)
             + mem.get("temp_size_in_bytes", 0)) / 2 ** 30, 3)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    cost_raw = _extract_cost(compiled)
    text = compiled.as_text()
    coll_raw = collective_bytes(text)

    if probe_cost and cfg.n_periods > 1:
        t0 = time.time()
        cost, coll = cost_probe(cfg, shape_name, mesh, variant)
        t_probe = time.time() - t0
    else:
        cost, coll, t_probe = cost_raw, coll_raw, 0.0

    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes accessed", 0.0)
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "variant": {k: v for k, v in (variant or {}).items() if k != "bucket_order"},
        "compile_s": round(t_compile, 2), "probe_s": round(t_probe, 2),
        "memory": mem,
        "cost": cost, "cost_raw_loop_once": cost_raw,
        "collectives": coll, "collectives_raw_loop_once": coll_raw,
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll.get("total", 0.0) / ICI_BW,
        },
    }
    r = res["roofline"]
    r["bottleneck"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: r[k])
    if verbose:
        print(json.dumps(res, indent=None, default=str))
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR / "dryrun.json"))
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                if not args.single_pod_only:
                    cells.append((arch, shape, True))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())

    def key(r):
        return (r["arch"], r["shape"], r["mesh"], json.dumps(r.get("variant", {}), sort_keys=True))

    done = {key(r) for r in results if r.get("status") in ("ok", "skipped")}
    for arch, shape, mp in cells:
        k = (arch, shape, "2x16x16" if mp else "16x16", "{}")
        if k in done:
            print(f"cached: {k}")
            continue
        print(f"=== {arch} x {shape} x {'2x16x16' if mp else '16x16'} ===", flush=True)
        try:
            res = run_cell(arch, shape, multi_pod=mp)
        except Exception:
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "trace": traceback.format_exc()[-2000:]}
            print(res["trace"], flush=True)
        results = [r for r in results if key(r) != key({**res, "variant": {}})]
        results.append(res)
        out_path.write_text(json.dumps(results, indent=1, default=str))
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {out_path}")


if __name__ == "__main__":
    main()
