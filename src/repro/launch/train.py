"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt

--smoke trains the reduced same-family config on CPU (the end-to-end
driver used by examples/ and the integration tests); full configs are for
real accelerators (the dry-run proves they lower + fit).

--plan-buckets N wires the coflow planner end-to-end: the model's gradient
leaves become leaf-size-calibrated all-reduce collectives, bucketed into N
jobs, planned on a live SchedulerSession (repro.dist.planner.plan), and the
planned permutation is realized as the train step's gradient-bucket launch
order (build_train_step(bucket_order=...)) — numerically neutral by
construction (the ordering barriers only pin collective launch order)."""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.ft import FTConfig, TrainRunner
from repro.train.optim import OptConfig


def planned_bucket_order(cfg, n_buckets: int, rows: int = 2, cols: int = 4,
                         seed: int = 0):
    """Gradient-bucket launch order from the coflow planner (ROADMAP item:
    `bucket_order_from_plan` wired into training end-to-end).

    Builds one all-reduce CollectiveOp per gradient leaf (payload = leaf
    bytes), buckets them into `n_buckets` chained jobs on the rows x cols
    abstract fabric, plans the phase against a live SchedulerSession, and
    translates the planned job permutation back into bucket lists of leaf
    paths for `build_train_step(bucket_order=...)`.

    Returns (bucket_order, PlanOutcome)."""
    import numpy as np

    from repro.dist.partition import _path_str
    from repro.dist.planner import (CollectiveOp, bucket_order_from_plan,
                                    coflows_from_step, plan)
    from repro.launch.specs import abstract_params

    leaves = jax.tree_util.tree_flatten_with_path(abstract_params(cfg))[0]
    paths = [_path_str(p) for p, _ in leaves]
    ops = [CollectiveOp("all-reduce", float(int(np.prod(leaf.shape)) * 4),
                        i, "data")
           for i, (_, leaf) in enumerate(leaves)]
    n_buckets = max(1, min(int(n_buckets), len(ops)))
    inst = coflows_from_step(ops, rows=rows, cols=cols, n_buckets=n_buckets)
    outcome = plan(inst, seed=seed)
    return bucket_order_from_plan(outcome, paths), outcome


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-buckets", type=int, default=0,
                    help="bucket gradients into N jobs and launch their "
                         "collectives in the coflow planner's order "
                         "(0 disables)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family != "lm" and not args.smoke:
        raise SystemExit("full-size non-LM training needs accelerators; use --smoke")

    bucket_order, outcome = (None, None)
    if args.plan_buckets > 0:
        bucket_order, outcome = planned_bucket_order(
            cfg, args.plan_buckets, seed=args.seed)

    runner = TrainRunner(
        cfg,
        OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                  total_steps=args.steps),
        DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                   seed=args.seed),
        FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        seed=args.seed,
        bucket_order=bucket_order,
    )
    runner.run(args.steps)
    first = runner.metrics_log[0]["loss"] if runner.metrics_log else float("nan")
    last = runner.metrics_log[-1]["loss"] if runner.metrics_log else float("nan")
    summary = {
        "arch": cfg.name, "steps": len(runner.metrics_log),
        "first_loss": first, "last_loss": last,
        "stragglers": len(runner.monitor.flagged),
    }
    if outcome is not None:
        summary["planned_buckets"] = len(outcome.order)
        summary["bucket_order"] = outcome.order
        summary["bucket_makespan_gain_pct"] = round(
            100 * outcome.makespan_gain, 1)
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
