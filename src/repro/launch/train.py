"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt

--smoke trains the reduced same-family config on CPU (the end-to-end
driver used by examples/ and the integration tests); full configs are for
real accelerators (the dry-run proves they lower + fit)."""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.ft import FTConfig, TrainRunner
from repro.train.optim import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family != "lm" and not args.smoke:
        raise SystemExit("full-size non-LM training needs accelerators; use --smoke")

    runner = TrainRunner(
        cfg,
        OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                  total_steps=args.steps),
        DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                   seed=args.seed),
        FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        seed=args.seed,
    )
    runner.run(args.steps)
    first = runner.metrics_log[0]["loss"] if runner.metrics_log else float("nan")
    last = runner.metrics_log[-1]["loss"] if runner.metrics_log else float("nan")
    print(json.dumps({
        "arch": cfg.name, "steps": len(runner.metrics_log),
        "first_loss": first, "last_loss": last,
        "stragglers": len(runner.monitor.flagged),
    }))


if __name__ == "__main__":
    main()
