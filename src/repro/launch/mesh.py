"""Production mesh construction. A FUNCTION (not module-level state) so
importing this never touches jax device initialization."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_rules"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods of
    256 = 512 chips (pod, data, model) — the dry-run proves the "pod" axis
    shards (DP across pods over DCN-class links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_rules(mesh) -> dict:
    """Logical-axis rules for repro.models.sharding.mesh_context."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {"dp": dp, "model": ("model",), "sp": ("data",)}
