"""input_specs(): weak-type-correct ShapeDtypeStruct stand-ins for every
model input of every (arch x shape) cell — shardable, zero allocation.
Also builds the abstract TrainState / caches the dry-run lowers against."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec, SHAPES, get_config
from repro.data.pipeline import make_batch_specs
from repro.models import ArchConfig
from repro.models.common import DTYPES

__all__ = ["input_specs", "abstract_state", "abstract_cache"]


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_state(cfg: ArchConfig):
    """Abstract TrainState via eval_shape (no allocation)."""
    from repro.train.step import init_train_state

    return jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0)))


def abstract_params(cfg: ArchConfig):
    from repro.train.step import init_params

    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_cache(cfg: ArchConfig, batch: int, capacity: int):
    if cfg.family == "encdec":
        from repro.models import init_encdec_cache

        return jax.eval_shape(
            lambda: init_encdec_cache(cfg, batch, capacity))
    from repro.models import init_decode_cache

    return jax.eval_shape(lambda: init_decode_cache(cfg, batch, capacity))


def input_specs(cfg: ArchConfig, shape: ShapeSpec | str) -> dict:
    """The step inputs for one cell.

    train:   {"batch": {tokens/labels/patches/frames...}}
    prefill: {"tokens": ..., (+ "frames"/"patches")}
    decode:  {"cache": <abstract cache at seq_len capacity>, "token": (B, 1)}
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    S, B = shape.seq_len, shape.global_batch
    f = jax.ShapeDtypeStruct
    if shape.kind == "train":
        return {"batch": make_batch_specs(cfg, S, B)}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frames": f((B, cfg.encoder_seq, cfg.d_model), jnp.float32),
                "tokens": f((B, S), jnp.int32),
            }
        if cfg.family == "vlm":
            return {
                "patches": f((B, cfg.n_image_tokens, cfg.d_model), jnp.float32),
                "tokens": f((B, S - cfg.n_image_tokens), jnp.int32),
            }
        return {"tokens": f((B, S), jnp.int32)}
    # decode: one new token against a seq_len-capacity cache
    return {
        "cache": abstract_cache(cfg, B, S),
        "token": f((B, 1), jnp.int32),
    }
